"""Gauss-Jordan SDD inverse: accuracy + custom VJP, vs numpy."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.linalg import inv_sdd, inv_sdd_blocks

SETTINGS = dict(max_examples=20, deadline=None)


def sdd(seed, n, dom=1.5):
    """Random strictly diagonally dominant matrix."""
    rng = np.random.RandomState(seed)
    a = rng.randn(n, n).astype(np.float32) / n
    row = np.abs(a).sum(1) - np.abs(np.diag(a))
    np.fill_diagonal(a, dom * (row + 0.1) * np.sign(rng.randn(n) + 1e-9))
    return a


@settings(**SETTINGS)
@given(n=st.sampled_from([4, 16, 64, 128]), seed=st.integers(0, 2**16))
def test_inverse_matches_numpy(n, seed):
    a = sdd(seed, n)
    got = np.asarray(inv_sdd(jnp.asarray(a)))
    want = np.linalg.inv(a.astype(np.float64)).astype(np.float32)
    assert_allclose(got, want, rtol=2e-3, atol=2e-4)


@settings(**SETTINGS)
@given(n=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2**16))
def test_inverse_identity_residual(n, seed):
    a = jnp.asarray(sdd(seed, n))
    b = inv_sdd(a)
    resid = np.abs(np.asarray(a @ b) - np.eye(n)).max()
    assert resid < 1e-4, resid


def test_blocks_inverse():
    h, n = 4, 32
    a = np.stack([sdd(i, n) for i in range(h)])
    b = np.asarray(inv_sdd_blocks(jnp.asarray(a)))
    for i in range(h):
        assert_allclose(a[i] @ b[i], np.eye(n), atol=1e-4)


def test_vjp_matches_finite_difference():
    n = 8
    a64 = jnp.asarray(sdd(3, n), dtype=jnp.float32)
    c = jnp.asarray(np.random.RandomState(0).randn(n, n), jnp.float32)

    def loss(a):
        return jnp.sum(inv_sdd(a) * c)

    g = jax.grad(loss)(a64)
    eps = 1e-3
    for (i, j) in [(0, 0), (1, 3), (5, 5), (7, 2)]:
        ap = a64.at[i, j].add(eps)
        am = a64.at[i, j].add(-eps)
        fd = (loss(ap) - loss(am)) / (2 * eps)
        assert abs(float(g[i, j]) - float(fd)) < 5e-2 * max(1.0, abs(float(fd)))


def test_identity_inverse_is_identity():
    eye = jnp.eye(64)
    assert_allclose(np.asarray(inv_sdd(eye)), np.eye(64), atol=1e-6)


def test_diagonal_inverse():
    d = jnp.diag(jnp.array([2.0, 4.0, 0.5, 8.0]))
    got = np.asarray(inv_sdd(d))
    assert_allclose(got, np.diag([0.5, 0.25, 2.0, 0.125]), atol=1e-6)
