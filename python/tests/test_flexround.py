"""FlexRound baseline graph: learnable element-wise division rounding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import affine
from compile.configs import MODELS


def test_flex_quant_zero_ls_is_rtn():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    ls = jnp.zeros((32, 16), jnp.float32)
    got = affine.flex_quant(w, ls, 7.0, 0)
    # reference RTN with the same group stats
    wmin = jnp.min(w, axis=0, keepdims=True)
    wmax = jnp.max(w, axis=0, keepdims=True)
    scale = jnp.maximum((wmax - wmin) / 7.0, 1e-8)
    zp = jnp.round(-wmin / scale)
    want = (jnp.clip(jnp.round(w / scale) + zp, 0, 7) - zp) * scale
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_flex_quant_ls_changes_rounding():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    ls = jnp.asarray(rng.normal(size=(64, 8)) * 0.3, jnp.float32)
    a = affine.flex_quant(w, jnp.zeros_like(ls), 15.0, 0)
    b = affine.flex_quant(w, ls, 15.0, 0)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_flex_gradients_flow_to_ls_only():
    cfg = MODELS["opt-s1"]
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    ls = jnp.asarray(rng.normal(size=(16, 8)) * 0.1, jnp.float32)

    def loss(ls):
        return jnp.sum(affine.flex_quant(w, ls, 7.0, 0) ** 2)

    g = jax.grad(loss)(ls)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0
    del cfg


@pytest.mark.parametrize("model", ["opt-s1", "ll-s1"])
def test_flex_step_loss_decreases(model):
    cfg = MODELS[model]
    from compile import model as m

    gl, bl, _ = m.theta_layouts(cfg)
    step, apply_fn, playout = affine.make_flex_step(cfg, 0, bl)
    rng = np.random.default_rng(3)
    wb = jnp.asarray(rng.normal(size=(bl.size,)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)

    # target: FP block output on the same input
    from compile.blocks import block_fwd

    yfp = block_fwd(cfg, bl.unflatten(wb), x)
    phi = jnp.zeros((playout.size,), jnp.float32)
    qmax = jnp.asarray([3.0], jnp.float32)  # 2-bit: rounding matters
    loss0, g = step(x, yfp, wb, phi, qmax)
    phi2 = phi
    best = float(loss0[0])
    for _ in range(15):
        loss, g = step(x, yfp, wb, phi2, qmax)
        best = min(best, float(loss[0]))
        # normalized step: robust across families/gradient scales
        phi2 = phi2 - 0.005 * g / (jnp.max(jnp.abs(g)) + 1e-12)
    assert best < float(loss0[0])

    # apply produces a block vector of the right size, norms untouched
    out = apply_fn(wb, phi2, qmax)
    assert out.shape == (bl.size,)
    if model == "opt-s1":
        g0 = bl.slice(wb, "ln1_g")
        g1 = bl.slice(out, "ln1_g")
        np.testing.assert_allclose(np.asarray(g0), np.asarray(g1))
