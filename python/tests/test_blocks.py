"""Transformer block graphs: shapes, causality, quant-noise sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import quantize
from compile.blocks import block_fwd, block_capture
from compile.configs import MODELS
from compile.model import theta_layouts


def init_block(cfg, seed=0):
    rng = np.random.RandomState(seed)
    w = {}
    for name, shape in cfg.block_weight_names():
        if name.startswith(("ln", "rms")) and name.endswith("_g"):
            w[name] = jnp.ones(shape)
        elif name.startswith("b") or name.endswith("_b"):
            w[name] = jnp.zeros(shape)
        else:
            w[name] = jnp.asarray(rng.randn(*shape).astype(np.float32) * 0.05)
    return w


@pytest.mark.parametrize("name", ["opt-s1", "ll-s1"])
def test_block_shapes(name):
    cfg = MODELS[name]
    w = init_block(cfg)
    x = jnp.asarray(np.random.RandomState(1).randn(2, cfg.seq, cfg.d_model),
                    jnp.float32)
    y = block_fwd(cfg, w, x)
    assert y.shape == x.shape
    y2, xq, xc, x1, x2c = block_capture(cfg, w, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)
    assert xq.shape == x.shape and xc.shape == x.shape
    assert x1.shape == x.shape and x2c.shape == (2, cfg.seq, cfg.d_ff)


@pytest.mark.parametrize("name", ["opt-s1", "ll-s1"])
def test_causality(name):
    """Perturbing token t must not change outputs at positions < t."""
    cfg = MODELS[name]
    w = init_block(cfg)
    rng = np.random.RandomState(2)
    x = rng.randn(1, cfg.seq, cfg.d_model).astype(np.float32)
    y1 = np.asarray(block_fwd(cfg, w, jnp.asarray(x)))
    t = cfg.seq // 2
    x2 = x.copy()
    x2[0, t:] += 1.0
    y2 = np.asarray(block_fwd(cfg, w, jnp.asarray(x2)))
    np.testing.assert_allclose(y1[0, :t], y2[0, :t], atol=1e-5)
    assert np.abs(y1[0, t:] - y2[0, t:]).max() > 1e-3


def test_act_quant_noise_small_at_8bit():
    cfg = MODELS["opt-s1"]
    w = init_block(cfg)
    x = jnp.asarray(np.random.RandomState(3).randn(2, cfg.seq, cfg.d_model)
                    .astype(np.float32))
    y_fp = block_fwd(cfg, w, x)
    y_q8 = block_fwd(cfg, w, x, act_qmax=jnp.array([255.0]),
                     act_quant_fn=lambda t, q: quantize.fake_quant_act(t, q[0]))
    y_q4 = block_fwd(cfg, w, x, act_qmax=jnp.array([15.0]),
                     act_quant_fn=lambda t, q: quantize.fake_quant_act(t, q[0]))
    e8 = float(jnp.mean((y_q8 - y_fp) ** 2))
    e4 = float(jnp.mean((y_q4 - y_fp) ** 2))
    assert e8 < e4
    assert e8 < 1e-4


def test_theta_layout_contiguous_blocks():
    cfg = MODELS["opt-s2"]
    gl, bl, tl = theta_layouts(cfg)
    assert tl.size == gl.size + cfg.n_layers * bl.size
    # block i occupies [gl.size + i*bl.size, ...): names must line up
    name, shape, off = tl.entries[len(gl.entries)]
    assert name == "b0." + bl.entries[0][0]
    assert off == gl.size
