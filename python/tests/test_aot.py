"""Manifest/artifact consistency (requires `make artifacts` to have run)."""

import json
import os

import pytest

from compile.configs import MODELS, GROUPS
from compile import affine, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_all_models():
    m = load()
    assert set(m["models"]) == set(MODELS)


def test_files_exist_and_are_pure_hlo():
    m = load()
    for name, mm in m["models"].items():
        for entry, meta in mm["entries"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), path
            text = open(path).read()
            assert "custom-call" not in text, (name, entry)
            assert text.lstrip().startswith("HloModule"), (name, entry)


def test_layout_sizes_match_configs():
    m = load()
    for name, mm in m["models"].items():
        cfg = MODELS[name]
        gl, bl, tl = model.theta_layouts(cfg)
        assert mm["globals_size"] == gl.size
        assert mm["block_size"] == bl.size
        assert mm["theta_size"] == tl.size
        assert mm["theta_size"] == cfg.param_count()
        for g in GROUPS:
            pl = affine.phi_layout(cfg, "w", g)
            assert mm["phi_layouts"][f"w_g{g}"]["size"] == pl.size
        pa = affine.phi_layout(cfg, "a4", 0)
        assert mm["phi_layouts"]["a4"]["size"] == pa.size


def test_entry_io_shapes():
    m = load()
    for name, mm in m["models"].items():
        cfg = MODELS[name]
        e = mm["entries"]["calib_w_g0"]
        b, s, d = cfg.batch, cfg.seq, cfg.d_model
        assert e["inputs"][0]["shape"] == [b, s, d]
        assert e["inputs"][2]["shape"] == [mm["block_size"]]
        p = mm["phi_layouts"]["w_g0"]["size"]
        assert e["inputs"][3]["shape"] == [p]
        assert e["outputs"][0]["shape"] == [1]
        assert e["outputs"][1]["shape"] == [p]
        tr = mm["entries"]["train_step"]
        assert tr["inputs"][2]["shape"] == [mm["theta_size"]]
        assert tr["outputs"][1]["shape"] == [mm["theta_size"]]


def test_expected_entry_set():
    m = load()
    want = {"embed", "head_nll", "block_fp", "block_a4", "block_capture",
            "calib_w_g0", "calib_w_g64", "calib_w_g128", "calib_a4",
            "wfq_g0", "wfq_g64", "wfq_g128", "train_step",
            "calib_flex_g0", "flex_apply_g0"}
    for name, mm in m["models"].items():
        assert set(mm["entries"]) == want, name
