"""STE fake-quant twin (the calibration-graph implementation)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import quantize

SETTINGS = dict(max_examples=20, deadline=None)


def rand(seed, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 3, 4]),
    group=st.sampled_from([0, 64]),
    seed=st.integers(0, 2**16),
)
def test_weight_error_bound(bits, group, seed):
    """With no clipping, |w - Q(w)| <= scale/2 element-wise."""
    din, dout = 128, 64
    w = rand(seed, din, dout)
    g = din if group == 0 else group
    noclip = jnp.full((din // g, dout), 20.0)
    qmax = 2.0**bits - 1.0
    wdq = quantize.fake_quant_weight(w, noclip, noclip, qmax, group)
    wg, wmin, wmax = quantize.group_minmax(w, group)
    scale = (wmax - wmin) / qmax
    err = jnp.abs(wdq.reshape(wg.shape) - wg)
    assert float(jnp.max(err - scale / 2)) < 1e-5


def test_ste_grad_is_passthrough():
    x = rand(0, 32)
    g = jax.grad(lambda x: jnp.sum(quantize.ste_round(x)))(x)
    assert_allclose(np.asarray(g), np.ones(32), atol=1e-7)


def test_lwc_grads_flow():
    """Clipping logits must receive nonzero gradients through scale/zp."""
    w = rand(1, 128, 64)
    qmax = 7.0

    def loss(gamma, beta):
        wdq = quantize.fake_quant_weight(w, gamma, beta, qmax, 0)
        return jnp.mean((wdq - w) ** 2)

    gamma = jnp.full((1, 64), 2.0)
    beta = jnp.full((1, 64), 2.0)
    gg, gb = jax.grad(loss, argnums=(0, 1))(gamma, beta)
    assert float(jnp.abs(gg).max()) > 0
    assert float(jnp.abs(gb).max()) > 0


def test_lwc_clipping_shrinks_range():
    w = rand(2, 128, 64, scale=2.0)
    qmax = 15.0
    noclip = jnp.full((1, 64), 20.0)
    hardclip = jnp.full((1, 64), -1.0)  # sigmoid(-1) ~ 0.27: strong clip
    w_no = quantize.fake_quant_weight(w, noclip, noclip, qmax, 0)
    w_cl = quantize.fake_quant_weight(w, hardclip, hardclip, qmax, 0)
    assert float(jnp.max(jnp.abs(w_cl))) < float(jnp.max(jnp.abs(w_no)))


def test_act_quant_preserves_zero():
    """Rows padded with zeros must quantize zero exactly (zp on-grid)."""
    x = rand(3, 16, 64, scale=3.0)
    x = x.at[:, :8].set(0.0)
    out = quantize.fake_quant_act(x, 15.0)
    assert_allclose(np.asarray(out[:, :8]), 0.0, atol=1e-6)


@settings(**SETTINGS)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
def test_act_quant_grad_passthrough_in_range(bits, seed):
    x = rand(seed, 8, 32)
    qmax = 2.0**bits - 1.0
    g = jax.grad(lambda x: jnp.sum(quantize.fake_quant_act(x, qmax)))(x)
    # STE: gradient 1 wherever not clipped; min/max rows always in range
    assert float(jnp.mean(jnp.abs(np.asarray(g) - 1.0) < 0.5)) > 0.9


def test_quant_monotone_in_bits():
    """More bits -> lower quantization error (per-tensor average)."""
    w = rand(5, 256, 128)
    noclip = jnp.full((1, 128), 20.0)
    errs = []
    for bits in (2, 3, 4, 8):
        wdq = quantize.fake_quant_weight(w, noclip, noclip, 2.0**bits - 1, 0)
        errs.append(float(jnp.mean((wdq - w) ** 2)))
    assert errs == sorted(errs, reverse=True), errs
