"""L1 pallas kernels vs pure-jnp oracles (the CORE correctness signal).

hypothesis sweeps shapes/groups/bit-widths; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import group_fq, act_quant, affine_mm
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(**SETTINGS)
@given(
    din=st.sampled_from([64, 128, 256]),
    dout=st.sampled_from([128, 256]),
    group=st.sampled_from([0, 64]),
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_group_fq_matches_ref(din, dout, group, bits, seed):
    g = din if group == 0 else group
    w = rand(seed, din, dout)
    gamma = rand(seed + 1, din // g, dout, scale=2.0) + 4.0
    beta = rand(seed + 2, din // g, dout, scale=2.0) + 4.0
    qmax = jnp.array([2.0**bits - 1.0])
    got = np.asarray(group_fq(w, gamma, beta, qmax, group))
    want = np.asarray(ref.ref_group_fq(w, gamma, beta, qmax, group))
    # round-half ties at f32 can differ by exactly one quantization step
    # between the pallas kernel and the jnp oracle; allow that on a
    # vanishing fraction of elements, exact match elsewhere.
    diff = np.abs(got - want)
    step = (diff.max() if diff.max() > 0 else 0.0)
    mismatched = diff > 1e-6
    assert mismatched.mean() < 1e-3, f"{mismatched.mean():.2%} elements differ"
    if mismatched.any():
        # the differing elements must be single-step rounding ties
        scale_bound = (np.abs(w).max() * 2.0) / float(qmax[0])
        assert step <= scale_bound + 1e-6, f"step {step} > scale bound {scale_bound}"


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([32, 128, 384]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_act_quant_matches_ref(rows, d, bits, seed):
    x = rand(seed, rows, d, scale=3.0)
    qmax = jnp.array([2.0**bits - 1.0])
    got = act_quant(x, qmax)
    want = ref.ref_act_quant(x, qmax)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_act_quant_3d_shape():
    x = rand(0, 2, 16, 128)
    out = act_quant(x, jnp.array([15.0]))
    assert out.shape == x.shape


@settings(**SETTINGS)
@given(
    n=st.sampled_from([128, 256]),
    k=st.sampled_from([128, 384]),
    m=st.sampled_from([128, 512]),
    seed=st.integers(0, 2**16),
)
def test_affine_mm_matches_ref(n, k, m, seed):
    a = rand(seed, n, k)
    b = rand(seed + 1, k, m)
    got = affine_mm(a, b)
    want = ref.ref_mm(a, b)
    # k-tiled accumulation reorders f32 sums vs dot; tolerance scales with k
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_affine_mm_grad_is_matmul_grad():
    a = rand(7, 128, 128)
    b = rand(8, 128, 128)
    c = rand(9, 128, 128)

    def f_kernel(a, b):
        return jnp.sum(affine_mm(a, b) * c)

    def f_ref(a, b):
        return jnp.sum((a @ b) * c)

    ga_k, gb_k = jax.grad(f_kernel, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    assert_allclose(np.asarray(ga_k), np.asarray(ga_r), rtol=2e-5, atol=2e-5)
    assert_allclose(np.asarray(gb_k), np.asarray(gb_r), rtol=2e-5, atol=2e-5)


def test_group_fq_quantization_levels():
    """Every dequantized value must sit on one of the 2^n grid points."""
    w = rand(3, 128, 128)
    qmax = jnp.array([7.0])
    gamma = jnp.full((1, 128), 20.0)  # sigmoid ~ 1: no clipping
    beta = jnp.full((1, 128), 20.0)
    out = np.asarray(group_fq(w, gamma, beta, qmax, 0))
    w_np = np.asarray(w)
    scale = (w_np.max(0) - w_np.min(0)) / 7.0
    zp = np.round(-w_np.min(0) / scale)
    q = out / scale + zp
    assert_allclose(q, np.round(q), atol=1e-3)
    assert q.min() >= -0.001 and q.max() <= 7.001


def test_act_quant_error_bound():
    """|x - Q(x)| <= scale/2 per token (asymmetric, min/max covers range)."""
    x = rand(11, 64, 128, scale=2.0)
    qmax = 15.0
    out = np.asarray(act_quant(x, jnp.array([qmax])))
    x_np = np.asarray(x)
    xmin = np.minimum(x_np.min(-1), 0.0)
    xmax = np.maximum(x_np.max(-1), 0.0)
    scale = (xmax - xmin) / qmax
    err = np.abs(out - x_np).max(-1)
    assert (err <= scale / 2 + 1e-6).all()
