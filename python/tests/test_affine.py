"""Calibration graph: equivalence, mask semantics, optimization progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import affine
from compile.blocks import block_fwd
from compile.configs import MODELS
from compile.model import theta_layouts
from tests.test_blocks import init_block

HUGE_QMAX = float(2**24 - 1)


def identity_phi(cfg, mode, group):
    """phi with A = I / a = 1, shifts 0, LWC wide-open."""
    layout = affine.phi_layout(cfg, mode, group)
    phi = {}
    for name, shape, _ in layout.entries:
        if name == "A_out":
            phi[name] = jnp.broadcast_to(jnp.eye(shape[-1]), shape)
        elif name in ("A_qkv", "A_fc1"):
            phi[name] = jnp.eye(shape[0])
        elif name in ("a_qkv", "a_fc1"):
            phi[name] = jnp.ones(shape)
        elif name.startswith("delta"):
            phi[name] = jnp.zeros(shape)
        elif name.startswith("lwc"):
            phi[name] = jnp.full(shape, 20.0)
        else:
            raise KeyError(name)
    return layout, layout.flatten(phi)


@pytest.mark.parametrize("name", ["opt-s1", "ll-s1"])
@pytest.mark.parametrize("mode,group", [("w", 0), ("w", 64), ("a4", 0)])
def test_identity_transform_is_equivalent(name, mode, group):
    """With A = I and quantization effectively off, the transformed block
    must reproduce the FP block (the paper's equivalence property)."""
    cfg = MODELS[name]
    w = init_block(cfg)
    x = jnp.asarray(np.random.RandomState(0)
                    .randn(2, cfg.seq, cfg.d_model).astype(np.float32))
    y_fp = block_fwd(cfg, w, x)
    layout, phi = identity_phi(cfg, mode, group)
    p = layout.unflatten(phi)
    if mode == "w":
        y_t = affine.transformed_fwd_w(cfg, w, p, x, HUGE_QMAX, group)
    else:
        y_t = affine.transformed_fwd_a4(cfg, w, p, x, HUGE_QMAX, HUGE_QMAX, group)
    assert_allclose(np.asarray(y_t), np.asarray(y_fp), rtol=1e-3, atol=5e-4)


@pytest.mark.parametrize("name", ["opt-s1"])
def test_sdd_transform_is_equivalent_unquantized(name):
    """Any SDD A is output-invariant when quantization is off (Eq. 2)."""
    cfg = MODELS[name]
    w = init_block(cfg)
    x = jnp.asarray(np.random.RandomState(1)
                    .randn(2, cfg.seq, cfg.d_model).astype(np.float32))
    layout, phi = identity_phi(cfg, "w", 0)
    rng = np.random.RandomState(2)
    phi_d = layout.unflatten(phi)
    noise = rng.randn(cfg.d_model, cfg.d_model).astype(np.float32)
    phi_d = dict(phi_d)
    phi_d["A_qkv"] = phi_d["A_qkv"] + 0.002 * jnp.asarray(noise)
    y_fp = block_fwd(cfg, w, x)
    y_t = affine.transformed_fwd_w(cfg, w, phi_d, x, HUGE_QMAX, 0)
    assert_allclose(np.asarray(y_t), np.asarray(y_fp), rtol=1e-2, atol=2e-3)


def test_mask_zeroes_gradients_outside_band():
    cfg = MODELS["opt-s1"]
    w = init_block(cfg)
    bl = theta_layouts(cfg)[1]
    wb = bl.flatten(w)
    step, layout = affine.make_calib_step(cfg, "w", 0, bl)
    _, phi = identity_phi(cfg, "w", 0)

    # mask: diagonal-only for the A matrices, ones for LWC
    m = {}
    for name, shape, _ in layout.entries:
        if name == "A_out":
            m[name] = jnp.broadcast_to(jnp.eye(shape[-1]), shape)
        elif name in ("A_qkv", "A_fc1"):
            m[name] = jnp.eye(shape[0])
        else:
            m[name] = jnp.ones(shape)
    mphi = layout.flatten(m)

    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(cfg.batch, cfg.seq, cfg.d_model), jnp.float32)
    yfp = block_fwd(cfg, w, x)
    loss, g = step(x, yfp, wb, phi, mphi, jnp.array([7.0]))
    gA = layout.slice(g, "A_qkv")
    off_diag = np.asarray(gA) * (1 - np.eye(cfg.d_model))
    assert np.abs(off_diag).max() == 0.0
    assert np.abs(np.diag(np.asarray(gA))).max() > 0.0
    assert float(loss[0]) > 0.0


@pytest.mark.parametrize("mode", ["w", "a4"])
def test_calibration_reduces_loss(mode):
    """A few SGD steps on phi must reduce the block MSE (Fig. 3 dynamics)."""
    cfg = MODELS["opt-s1"]
    w = init_block(cfg)
    bl = theta_layouts(cfg)[1]
    wb = bl.flatten(w)
    step, layout = affine.make_calib_step(cfg, mode, 0, bl)
    _, phi = identity_phi(cfg, mode, 0)
    mphi = jnp.ones_like(phi)

    rng = np.random.RandomState(4)
    x = rng.randn(cfg.batch, cfg.seq, cfg.d_model).astype(np.float32)
    # outlier channels — the activation pathology the transform exists to fix
    x[..., ::16] *= 8.0
    x = jnp.asarray(x)
    yfp = block_fwd(cfg, w, x)
    qw = jnp.array([3.0])   # w2: strong quant noise -> clear signal
    qa = jnp.array([15.0])  # a4
    args = (qw,) if mode == "w" else (qw, qa)

    # Adam, as the rust coordinator runs it
    losses = []
    lr, b1, b2, eps = 5e-3, 0.9, 0.999, 1e-8
    m = jnp.zeros_like(phi)
    v = jnp.zeros_like(phi)
    jstep = jax.jit(step)
    for t in range(1, 41):
        loss, g = jstep(x, yfp, wb, phi, mphi, *args)
        losses.append(float(loss[0]))
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**t)
        vh = v / (1 - b2**t)
        phi = phi - lr * mh / (jnp.sqrt(vh) + eps)
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]
