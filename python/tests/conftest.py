import os
import sys

# tests run from python/ ("cd python && python -m pytest tests/"); make the
# compile package importable also when pytest is invoked from the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
