"""AffineQuant calibration graph (the paper's Eq. 4 objective).

Per transformer block we optimize, by gradient descent on the MSE between
the FP block output and the quantized block output:

  * weight-only mode (``w``): full affine matrices A_qkv (d,d) and
    A_fc1 (d,d), a per-head block-diagonal A_out (h, hd, hd), and LWC
    clipping logits for every quantized weight;
  * weight-activation mode (``a4``): diagonal affine + learnable shift at the
    LayerNorm sites (so they fold into LN gamma/beta — zero inference
    overhead, paper §3.3), the same per-head A_out, LWC, and per-token
    dynamic activation fake-quant at the four linear inputs.

All learnables live in one flat vector ``phi``; the Gradual Mask ``mphi``
(same layout, entries in {0, alpha, 1}) is element-wise multiplied in-graph,
so the returned grad d(loss)/d(phi) automatically carries the GM learning-
rate damping of paper Eq. 9. The rust coordinator owns the mask schedule,
Adam, and the SDD stability monitor.
"""

import jax
import jax.numpy as jnp

from . import quantize
from .blocks import attention, layer_norm, rms_norm
from .flat import Layout
from .kernels.affine_mm import affine_mm
from .linalg import inv_sdd, inv_sdd_blocks


def phi_layout(cfg, mode, group):
    """Layout of the flat learnable vector for one block."""
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    named = []
    if mode == "w":
        named.append(("A_qkv", (d, d)))
        named.append(("A_out", (h, hd, hd)))
        named.append(("A_fc1", (d, d)))
    else:  # a4
        named.append(("a_qkv", (d,)))
        named.append(("A_out", (h, hd, hd)))
        named.append(("a_fc1", (d,)))
        if cfg.family == "opt":  # shifts fold into biases; ll has none
            named.append(("delta_qkv", (d,)))
            named.append(("delta_fc1", (d,)))
    named.extend(quantize.lwc_shapes(cfg, group))
    return Layout(named)


def _fq(w, p, name, qmax, group):
    return quantize.fake_quant_weight(
        w, p[f"lwc_g_{name}"], p[f"lwc_b_{name}"], qmax, group)


def _out_site(cfg, p, ctx, wo, qmax_w, group, act_q):
    """Per-head block-diagonal affine at out_proj (shared by both modes)."""
    B, S, d = ctx.shape
    h, hd = cfg.n_heads, cfg.head_dim
    ao = p["A_out"]
    inv_ao = inv_sdd_blocks(ao)
    ctx_h = ctx.reshape(B, S, h, hd)
    ctx_t = jnp.einsum("bshj,hji->bshi", ctx_h, inv_ao).reshape(B, S, d)
    if act_q is not None:
        ctx_t = act_q(ctx_t)
    wo_h = wo.reshape(h, hd, d)
    wo_t = jnp.einsum("hij,hjd->hid", ao, wo_h).reshape(d, d)
    wo_q = _fq(wo_t, p, "wo", qmax_w, group)
    return ctx_t, wo_q


def transformed_fwd_w(cfg, w, p, x, qmax_w, group):
    """Weight-only transformed+quantized block forward (no act quant)."""
    a_qkv = p["A_qkv"]
    inv_a = inv_sdd(a_qkv)
    a_fc1 = p["A_fc1"]
    inv_f = inv_sdd(a_fc1)
    opt = cfg.family == "opt"

    xn = layer_norm(x, w["ln1_g"], w["ln1_b"]) if opt else rms_norm(x, w["rms1_g"])
    xt = xn @ inv_a
    names = ("wq", "wk", "wv")
    proj = [_fq(affine_mm(a_qkv, w[n]), p, n, qmax_w, group) for n in names]
    if opt:
        q = xt @ proj[0] + w["bq"]
        k = xt @ proj[1] + w["bk"]
        v = xt @ proj[2] + w["bv"]
    else:
        q, k, v = (xt @ pj for pj in proj)
    ctx = attention(cfg, q, k, v)
    ctx_t, wo_q = _out_site(cfg, p, ctx, w["wo"], qmax_w, group, act_q=None)
    x = x + ctx_t @ wo_q + (w["bo"] if opt else 0.0)

    xn2 = layer_norm(x, w["ln2_g"], w["ln2_b"]) if opt else rms_norm(x, w["rms2_g"])
    xt2 = xn2 @ inv_f
    if opt:
        w1_q = _fq(affine_mm(a_fc1, w["w1"]), p, "w1", qmax_w, group)
        w2_q = _fq(w["w2"], p, "w2", qmax_w, group)  # fc2: no affine (paper §4.1)
        hmid = jax.nn.gelu(xt2 @ w1_q + w["b1"])
        y = x + hmid @ w2_q + w["b2"]
    else:
        wg_q = _fq(affine_mm(a_fc1, w["wg"]), p, "wg", qmax_w, group)
        wu_q = _fq(affine_mm(a_fc1, w["wu"]), p, "wu", qmax_w, group)
        wd_q = _fq(w["wd"], p, "wd", qmax_w, group)
        hmid = jax.nn.silu(xt2 @ wg_q) * (xt2 @ wu_q)
        y = x + hmid @ wd_q
    return y


def transformed_fwd_a4(cfg, w, p, x, qmax_w, qmax_a, group):
    """Weight-activation transformed block: diagonal+shift at LN sites,
    per-head affine at out_proj, per-token activation fake-quant."""
    opt = cfg.family == "opt"
    act_q = lambda t: quantize.fake_quant_act(t, qmax_a)

    def diag_site(xn, wnames, a, delta, biases):
        """Transformed projections sharing one LN input."""
        xt = (xn - delta) / a
        xt_q = act_q(xt)
        outs = []
        for wn, b in zip(wnames, biases):
            wt_q = _fq(w[wn] * a[:, None], p, wn, qmax_w, group)
            weff = wt_q / a[:, None]
            bias = (b + delta @ weff) if b is not None else delta @ weff
            outs.append(xt_q @ wt_q + bias)
        return outs

    a1 = p["a_qkv"]
    d1 = p["delta_qkv"] if opt else jnp.zeros_like(a1)
    xn = layer_norm(x, w["ln1_g"], w["ln1_b"]) if opt else rms_norm(x, w["rms1_g"])
    biases = (w["bq"], w["bk"], w["bv"]) if opt else (None, None, None)
    q, k, v = diag_site(xn, ("wq", "wk", "wv"), a1, d1, biases)
    ctx = attention(cfg, q, k, v)
    ctx_t, wo_q = _out_site(cfg, p, ctx, w["wo"], qmax_w, group, act_q=act_q)
    x = x + ctx_t @ wo_q + (w["bo"] if opt else 0.0)

    a2 = p["a_fc1"]
    d2 = p["delta_fc1"] if opt else jnp.zeros_like(a2)
    xn2 = layer_norm(x, w["ln2_g"], w["ln2_b"]) if opt else rms_norm(x, w["rms2_g"])
    if opt:
        (pre1,) = diag_site(xn2, ("w1",), a2, d2, (w["b1"],))
        hmid = jax.nn.gelu(pre1)
        w2_q = _fq(w["w2"], p, "w2", qmax_w, group)
        y = x + act_q(hmid) @ w2_q + w["b2"]
    else:
        pre_g, pre_u = diag_site(xn2, ("wg", "wu"), a2, d2, (None, None))
        hmid = jax.nn.silu(pre_g) * pre_u
        wd_q = _fq(w["wd"], p, "wd", qmax_w, group)
        y = x + act_q(hmid) @ wd_q
    return y


def flex_phi_layout(cfg, group):
    """Per-element log-scales for every quantized weight (FlexRound)."""
    wshapes = dict(cfg.block_weight_names())
    named = [(f"ls_{n}", wshapes[n]) for n in cfg.quantized_weight_names()]
    return Layout(named)


def flex_quant(w, ls, qmax, group):
    """FlexRound-style quantization: learnable element-wise division.

    The base per-group scale/zero-point come from min/max statistics
    (stop-gradient); the learnable ``exp(ls)`` divides each element before
    rounding and multiplies back after — gradients flow to ``ls`` only, as
    in the FlexRound formulation."""
    din, dout = w.shape
    wg, wmin, wmax = quantize.group_minmax(w, group)
    scale = jax.lax.stop_gradient(jnp.maximum((wmax - wmin) / qmax, quantize.EPS))
    zp = jax.lax.stop_gradient(jnp.round(-wmin / scale))
    s2 = jnp.exp(ls).reshape(wg.shape)
    q = jnp.clip(quantize.ste_round(wg / (scale * s2)) + zp, 0.0, qmax)
    return ((q - zp) * scale * s2).reshape(din, dout)


def make_flex_step(cfg, group, block_layout):
    """FlexRound calibration step: fn(xq, yfp, wb, phi, qmax_w)->(loss,g)."""
    playout = flex_phi_layout(cfg, group)
    qnames = list(cfg.quantized_weight_names())

    def quantized_block(wb, phi, xq, qmax_w):
        p = playout.unflatten(phi)
        w = dict(block_layout.unflatten(wb))
        for n in qnames:
            w[n] = flex_quant(w[n], p[f"ls_{n}"], qmax_w[0], group)
        from .blocks import block_fwd
        return block_fwd(cfg, w, xq)

    def loss_fn(phi, wb, xq, yfp, qmax_w):
        y = quantized_block(wb, phi, xq, qmax_w)
        return jnp.mean((y - yfp) ** 2)

    def step(xq, yfp, wb, phi, qmax_w):
        loss, g = jax.value_and_grad(loss_fn)(phi, wb, xq, yfp, qmax_w)
        return loss.reshape(1), g

    def apply(wb, phi, qmax_w):
        p = playout.unflatten(phi)
        w = dict(block_layout.unflatten(wb))
        for n in qnames:
            w[n] = flex_quant(w[n], p[f"ls_{n}"], qmax_w[0], group)
        return block_layout.flatten(w)

    return step, apply, playout


def make_calib_step(cfg, mode, group, block_layout):
    """Returns fn(xq, yfp, wb, phi, mphi, qmax_w[, qmax_a]) -> (loss, gphi)."""
    playout = phi_layout(cfg, mode, group)

    def loss_fn(phi, mphi, wb, xq, yfp, qmax_w, qmax_a):
        phi_star = phi * mphi  # Gradual Mask, Eq. 7
        p = playout.unflatten(phi_star)
        w = block_layout.unflatten(wb)
        if mode == "w":
            y = transformed_fwd_w(cfg, w, p, xq, qmax_w[0], group)
        else:
            y = transformed_fwd_a4(cfg, w, p, xq, qmax_w[0], qmax_a[0], group)
        return jnp.mean((y - yfp) ** 2)

    if mode == "w":
        def step(xq, yfp, wb, phi, mphi, qmax_w):
            loss, g = jax.value_and_grad(loss_fn)(
                phi, mphi, wb, xq, yfp, qmax_w, qmax_w)
            return loss.reshape(1), g
    else:
        def step(xq, yfp, wb, phi, mphi, qmax_w, qmax_a):
            loss, g = jax.value_and_grad(loss_fn)(
                phi, mphi, wb, xq, yfp, qmax_w, qmax_a)
            return loss.reshape(1), g

    return step, playout
