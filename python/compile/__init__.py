"""AffineQuant compile path: L1 pallas kernels + L2 jax graphs -> AOT HLO.

This package runs only at build time (`make artifacts`). The rust coordinator
loads the emitted HLO text through PJRT and never imports python at run time.
"""
