"""Pallas fake-quantization kernels.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA fake-quant
kernels assign one threadblock per weight-group; here one pallas grid cell
covers a ``(group, 128)`` VMEM tile so the min/max reduction stays in-tile
(VPU work, no MXU). ``interpret=True`` everywhere — the CPU PJRT client
cannot run Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8
LANE = 128  # output-channel tile width (TPU lane count)


def _group_fq_kernel(w_ref, g_ref, b_ref, qmax_ref, o_ref):
    w = w_ref[...]                       # (g, LANE)
    gamma = jax.nn.sigmoid(g_ref[...])   # (1, LANE)
    beta = jax.nn.sigmoid(b_ref[...])
    qmax = qmax_ref[0]
    wmin = jnp.min(w, axis=0, keepdims=True)
    wmax = jnp.max(w, axis=0, keepdims=True)
    cmax = gamma * wmax
    cmin = beta * wmin
    scale = jnp.maximum((cmax - cmin) / qmax, EPS)
    zp = jnp.round(-cmin / scale)
    q = jnp.clip(jnp.round(w / scale) + zp, 0.0, qmax)
    o_ref[...] = (q - zp) * scale


def group_fq(w, gamma, beta, qmax, group):
    """Per-group LWC fake quantization of w: (in, out).

    gamma/beta: (n_groups, out) clipping logits; qmax: (1,) f32 (2^bits - 1);
    group == 0 -> per-output-channel. Output matches
    ``quantize.fake_quant_weight`` bit-for-bit (same op order).
    """
    din, dout = w.shape
    g = din if group == 0 else group
    n_groups = din // g
    assert dout % LANE == 0, (din, dout)
    return pl.pallas_call(
        _group_fq_kernel,
        grid=(n_groups, dout // LANE),
        in_specs=[
            pl.BlockSpec((g, LANE), lambda i, j: (i, j)),
            pl.BlockSpec((1, LANE), lambda i, j: (i, j)),
            pl.BlockSpec((1, LANE), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((g, LANE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((din, dout), w.dtype),
        interpret=True,
    )(w, gamma, beta, qmax)


def _act_quant_kernel(x_ref, qmax_ref, o_ref):
    x = x_ref[...]                       # (ROWS, d) — one token per row
    qmax = qmax_ref[0]
    xmin = jnp.minimum(jnp.min(x, axis=-1, keepdims=True), 0.0)
    xmax = jnp.maximum(jnp.max(x, axis=-1, keepdims=True), 0.0)
    scale = jnp.maximum((xmax - xmin) / qmax, EPS)
    zp = jnp.round(-xmin / scale)
    q = jnp.clip(jnp.round(x / scale) + zp, 0.0, qmax)
    o_ref[...] = (q - zp) * scale


ROWS = 8  # token rows per grid cell


def act_quant(x, qmax):
    """Per-token dynamic asymmetric fake quantization.

    x: (..., d); rows (tokens) map to grid cells, the feature reduction is a
    lane reduction within the tile. Matches ``quantize.fake_quant_act``.
    """
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    assert n % ROWS == 0, shape
    out = pl.pallas_call(
        _act_quant_kernel,
        grid=(n // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x2, qmax)
    return out.reshape(shape)
