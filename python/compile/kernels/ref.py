"""Pure-jnp oracles for the pallas kernels (pytest ties kernel == oracle).

The oracles are the (differentiable) implementations in ``compile.quantize``
— the kernels must match them bit-for-bit on the forward path, which is also
what guarantees the calibration graph (quantize.py) and the serving graph
(kernels) quantize identically.
"""

import jax.numpy as jnp

from .. import quantize


def ref_group_fq(w, gamma, beta, qmax, group):
    return quantize.fake_quant_weight(w, gamma, beta, jnp.asarray(qmax)[0], group)


def ref_act_quant(x, qmax):
    return quantize.fake_quant_act(x, jnp.asarray(qmax)[0])


def ref_mm(a, b):
    return a @ b
