"""Tiled matmul pallas kernel for the A @ W affine transform hot-spot.

TPU mapping: the canonical (i, j, k) grid with 128^3 MXU-sized tiles and an
f32 accumulator in the output block — the BlockSpec equivalent of the
paper's cuBLAS threadblock schedule. A custom_vjp makes it usable inside the
calibration graph (backward = two jnp matmuls; XLA fuses those fine).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


def _mm_pallas(a, b):
    n, k = a.shape
    k2, m = b.shape
    assert k == k2
    bn = min(TILE, n)
    bm = min(TILE, m)
    bk = min(TILE, k)
    assert n % bn == 0 and m % bm == 0 and k % bk == 0, (a.shape, b.shape)
    return pl.pallas_call(
        _mm_kernel,
        grid=(n // bn, m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def affine_mm(a, b):
    """a @ b through the pallas tiled kernel; differentiable."""
    return _mm_pallas(a, b)


def _fwd(a, b):
    return _mm_pallas(a, b), (a, b)


def _bwd(res, g):
    a, b = res
    return (g @ b.T, a.T @ g)


affine_mm.defvjp(_fwd, _bwd)
