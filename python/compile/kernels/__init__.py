"""L1 Pallas kernels (interpret=True on CPU PJRT; see DESIGN.md §8).

Kernels are the eval/serving hot path; the autodiff twin lives in
``compile.quantize``. ``ref.py`` holds the pure-jnp oracles used by pytest.
"""

from .fake_quant import group_fq, act_quant  # noqa: F401
from .affine_mm import affine_mm  # noqa: F401
