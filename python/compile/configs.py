"""Model/shape registry shared by the jax graphs and the AOT manifest.

Two families mirror the paper's model zoo:
  * ``opt`` — LayerNorm + GELU MLP + learned positional embeddings + biases
    (OPT-style; the paper's Tables 1/8/9 models).
  * ``ll``  — RMSNorm + SiLU-gated MLP + RoPE, no biases (LLaMA-style; the
    paper's Tables 3/10/11 models).

All hidden/ff dims are multiples of 128 so every paper group size
(g64/g128/per-channel) divides evenly. head_dim is 32 everywhere; the
per-head affine matrices A_out are 32x32 blocks.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str          # "opt" | "ll"
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    vocab: int = 256     # byte-level
    seq: int = 128
    # batch sizes baked into the artifacts
    batch: int = 8       # eval + calibration batch
    train_batch: int = 16

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def block_weight_names(self):
        """Ordered (name, shape) list for one transformer block."""
        d, ff = self.d_model, self.d_ff
        if self.family == "opt":
            return [
                ("ln1_g", (d,)), ("ln1_b", (d,)),
                ("wq", (d, d)), ("bq", (d,)),
                ("wk", (d, d)), ("bk", (d,)),
                ("wv", (d, d)), ("bv", (d,)),
                ("wo", (d, d)), ("bo", (d,)),
                ("ln2_g", (d,)), ("ln2_b", (d,)),
                ("w1", (d, ff)), ("b1", (ff,)),
                ("w2", (ff, d)), ("b2", (d,)),
            ]
        else:
            return [
                ("rms1_g", (d,)),
                ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
                ("rms2_g", (d,)),
                ("wg", (d, ff)), ("wu", (d, ff)),
                ("wd", (ff, d)),
            ]

    def global_weight_names(self):
        """Ordered (name, shape) list for embeddings + final norm.

        The LM head is tied to ``tok_emb`` (as in OPT)."""
        d, v, s = self.d_model, self.vocab, self.seq
        if self.family == "opt":
            return [
                ("tok_emb", (v, d)), ("pos_emb", (s, d)),
                ("lnf_g", (d,)), ("lnf_b", (d,)),
            ]
        return [("tok_emb", (v, d)), ("rmsf_g", (d,))]

    def quantized_weight_names(self):
        """Weight matrices that get quantized (paper: all linear layers)."""
        if self.family == "opt":
            return ["wq", "wk", "wv", "wo", "w1", "w2"]
        return ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]

    def affine_site_weights(self):
        """site -> weights sharing that transform's input."""
        if self.family == "opt":
            return {"qkv": ["wq", "wk", "wv"], "out": ["wo"], "fc1": ["w1"]}
        return {"qkv": ["wq", "wk", "wv"], "out": ["wo"], "fc1": ["wg", "wu"]}

    def param_count(self) -> int:
        n = sum(_numel(s) for _, s in self.global_weight_names())
        n += self.n_layers * sum(_numel(s) for _, s in self.block_weight_names())
        return n


def _numel(shape):
    n = 1
    for s in shape:
        n *= s
    return n


# Size ladder mirroring the paper's OPT-125M..30B / LLaMA-7B..30B ladders at
# CPU-trainable scale. All dims divisible by 128.
MODELS = {
    "opt-s1": ModelConfig("opt-s1", "opt", d_model=128, n_heads=4, n_layers=2, d_ff=512),
    "opt-s2": ModelConfig("opt-s2", "opt", d_model=256, n_heads=8, n_layers=3, d_ff=1024),
    "opt-s3": ModelConfig("opt-s3", "opt", d_model=384, n_heads=12, n_layers=4, d_ff=1536),
    "ll-s1": ModelConfig("ll-s1", "ll", d_model=128, n_heads=4, n_layers=2, d_ff=384),
    "ll-s2": ModelConfig("ll-s2", "ll", d_model=256, n_heads=8, n_layers=3, d_ff=768),
}

# Weight-quantization group sizes baked per calib/fakequant artifact.
# 0 means per-output-channel (one group spanning the whole input dim).
GROUPS = (0, 64, 128)
