"""AOT driver: lower every entry point to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True; the
rust side unwraps with ``Literal::to_tuple``.

Usage:  cd python && python -m compile.aot --out ../artifacts [--models a,b]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import affine, model, quantize
from .configs import MODELS, GROUPS
from .flat import Layout


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_dict(name, s):
    return {
        "name": name,
        "dtype": str(s.dtype),
        "shape": list(s.shape),
    }


def lower_entry(fn, specs, names, out_dir, entry):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert "custom-call" not in text, f"{entry}: HLO contains custom-calls"
    path = os.path.join(out_dir, f"{entry}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *specs)
    flat_outs, _ = jax.tree_util.tree_flatten(outs)
    meta = {
        # path relative to the artifacts root (manifest lives there)
        "file": f"{os.path.basename(out_dir)}/{entry}.hlo.txt",
        "inputs": [spec_dict(n, s) for n, s in zip(names, specs)],
        "outputs": [spec_dict(f"out{i}", s) for i, s in enumerate(flat_outs)],
    }
    print(f"  {entry:>16}: {len(text)/1e3:8.1f} KB  {time.time()-t0:5.1f}s")
    return meta


def build_model(cfg, out_root):
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    B, S, d = cfg.batch, cfg.seq, cfg.d_model
    Bt = cfg.train_batch

    gl, bl, tl = model.theta_layouts(cfg)
    entries = {}

    # --- embedding / head / blocks -------------------------------------
    entries["embed"] = lower_entry(
        lambda tokens, g: model.embed(cfg, gl, tokens, g),
        [i32(B, S), f32(gl.size)], ["tokens", "globals"], out_dir, "embed")

    entries["head_nll"] = lower_entry(
        lambda h, t, m, g: model.head_nll(cfg, gl, h, t, m, g),
        [f32(B, S, d), i32(B, S), f32(B, S), f32(gl.size)],
        ["hidden", "targets", "mask", "globals"], out_dir, "head_nll")

    block_fp, block_a4, block_cap = model.make_block_entries(cfg, bl)
    entries["block_fp"] = lower_entry(
        block_fp, [f32(B, S, d), f32(bl.size)], ["x", "wb"], out_dir, "block_fp")
    entries["block_a4"] = lower_entry(
        block_a4, [f32(B, S, d), f32(bl.size), f32(1)],
        ["x", "wb", "qmax_a"], out_dir, "block_a4")
    entries["block_capture"] = lower_entry(
        block_cap, [f32(B, S, d), f32(bl.size)], ["x", "wb"],
        out_dir, "block_capture")

    # --- calibration steps ----------------------------------------------
    phi_meta = {}
    for group in GROUPS:
        step, playout = affine.make_calib_step(cfg, "w", group, bl)
        key = f"w_g{group}"
        phi_meta[key] = {"size": playout.size, "entries": playout.to_manifest()}
        entries[f"calib_{key}"] = lower_entry(
            step,
            [f32(B, S, d), f32(B, S, d), f32(bl.size),
             f32(playout.size), f32(playout.size), f32(1)],
            ["xq", "yfp", "wb", "phi", "mphi", "qmax_w"],
            out_dir, f"calib_{key}")

    step, playout = affine.make_calib_step(cfg, "a4", 0, bl)
    phi_meta["a4"] = {"size": playout.size, "entries": playout.to_manifest()}
    entries["calib_a4"] = lower_entry(
        step,
        [f32(B, S, d), f32(B, S, d), f32(bl.size),
         f32(playout.size), f32(playout.size), f32(1), f32(1)],
        ["xq", "yfp", "wb", "phi", "mphi", "qmax_w", "qmax_a"],
        out_dir, "calib_a4")

    # --- FlexRound baseline (Table 7): per-element division rounding -----
    fstep, fapply, fplayout = affine.make_flex_step(cfg, 0, bl)
    phi_meta["flex_g0"] = {"size": fplayout.size, "entries": fplayout.to_manifest()}
    entries["calib_flex_g0"] = lower_entry(
        fstep,
        [f32(B, S, d), f32(B, S, d), f32(bl.size), f32(fplayout.size), f32(1)],
        ["xq", "yfp", "wb", "phi", "qmax_w"], out_dir, "calib_flex_g0")
    entries["flex_apply_g0"] = lower_entry(
        fapply, [f32(bl.size), f32(fplayout.size), f32(1)],
        ["wb", "phi", "qmax_w"], out_dir, "flex_apply_g0")

    # --- weight fake-quant through the pallas kernel --------------------
    lwc_meta = {}
    for group in GROUPS:
        wfq, lwc_layout = model.make_wfq(cfg, bl, group)
        lwc_meta[f"g{group}"] = {
            "size": lwc_layout.size, "entries": lwc_layout.to_manifest()}
        entries[f"wfq_g{group}"] = lower_entry(
            wfq, [f32(bl.size), f32(lwc_layout.size), f32(1)],
            ["wb", "lwc", "qmax_w"], out_dir, f"wfq_g{group}")

    # --- training --------------------------------------------------------
    train_step, _ = model.make_train_step(cfg)
    entries["train_step"] = lower_entry(
        train_step, [i32(Bt, S), i32(Bt, S), f32(tl.size)],
        ["tokens", "targets", "theta"], out_dir, "train_step")

    return {
        "config": {
            "name": cfg.name, "family": cfg.family, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff, "vocab": cfg.vocab, "seq": cfg.seq,
            "batch": cfg.batch, "train_batch": cfg.train_batch,
            "head_dim": cfg.head_dim, "params": cfg.param_count(),
        },
        "globals_layout": gl.to_manifest(),
        "globals_size": gl.size,
        "block_layout": bl.to_manifest(),
        "block_size": bl.size,
        "theta_size": tl.size,
        "phi_layouts": phi_meta,
        "lwc_layouts": lwc_meta,
        "entries": entries,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    args = ap.parse_args()

    manifest = {"version": 1, "models": {}}
    t0 = time.time()
    for name in args.models.split(","):
        cfg = MODELS[name]
        print(f"[{name}] d={cfg.d_model} h={cfg.n_heads} L={cfg.n_layers} "
              f"ff={cfg.d_ff} params={cfg.param_count()/1e6:.2f}M")
        manifest["models"][name] = build_model(cfg, args.out)

    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest -> {path}  (total {time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
