"""Differentiable (STE) fake-quantization used inside calibration graphs.

Matches the paper's pseudo-quantization (Eq. 1):
    Q(x) = s * (clamp(round(x/s) + zp, 0, 2^n - 1) - zp)
with per-group asymmetric weight quantization + OmniQuant-style learnable
weight clipping (LWC), and per-token dynamic asymmetric activation
quantization. ``qmax = 2^n - 1`` is a runtime input so one artifact serves
all bit-widths.

The eval/serving path uses the pallas kernels in ``kernels/``; this module is
the autodiff-friendly twin, and ``kernels/ref.py`` ties them together in
tests.
"""

import jax
import jax.numpy as jnp
from jax import lax

EPS = 1e-8


def ste_round(x):
    """round() with a straight-through gradient."""
    return x + lax.stop_gradient(jnp.round(x) - x)


def group_minmax(w, group):
    """Per-group min/max over the input dim of w: (in, out).

    group == 0 means per-output-channel (one group = whole input dim).
    Returns (wmin, wmax) with shape (n_groups, 1, out) and the grouped view
    (n_groups, g, out).
    """
    din, dout = w.shape
    g = din if group == 0 else group
    wg = w.reshape(din // g, g, dout)
    return wg, jnp.min(wg, axis=1, keepdims=True), jnp.max(wg, axis=1, keepdims=True)


def fake_quant_weight(w, gamma, beta, qmax, group):
    """LWC fake quantization of a weight matrix.

    w: (in, out); gamma/beta: (n_groups, out) learnable clipping logits;
    qmax: scalar (2^bits - 1). Gradients flow to w via STE and to gamma/beta
    through the scale/zero-point computation.
    """
    din, dout = w.shape
    wg, wmin, wmax = group_minmax(w, group)
    cmax = jax.nn.sigmoid(gamma)[:, None, :] * wmax
    cmin = jax.nn.sigmoid(beta)[:, None, :] * wmin
    scale = jnp.maximum((cmax - cmin) / qmax, EPS)
    zp = ste_round(-cmin / scale)
    q = jnp.clip(ste_round(wg / scale) + zp, 0.0, qmax)
    wdq = (q - zp) * scale
    return wdq.reshape(din, dout)


def fake_quant_act(x, qmax):
    """Per-token dynamic asymmetric activation fake quantization.

    x: (..., features); one scale/zp per leading position ("token").
    """
    xmin = jnp.min(x, axis=-1, keepdims=True)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    # include zero so the quantizer can represent exact zeros (padding etc.)
    xmin = jnp.minimum(xmin, 0.0)
    xmax = jnp.maximum(xmax, 0.0)
    scale = jnp.maximum((xmax - xmin) / qmax, EPS)
    zp = ste_round(-xmin / scale)
    q = jnp.clip(ste_round(x / scale) + zp, 0.0, qmax)
    return (q - zp) * scale


def weight_quant_error(w, qmax, group):
    """Per-matrix quantization error of plain RTN (identity LWC) fake quant.

    Returns ``(mse, max_abs)`` of ``fake_quant(w) - w`` — the calibration
    artifact the serving engine bakes per layer at pack time
    (``LayerCalib.weight_mse`` / ``weight_max_abs`` in
    ``rust/src/engine/packed.rs``), computed here on the AOT side so a
    transform's effect on quant error can be inspected *before* packing.
    Identity clipping (gamma/beta -> +inf ≈ sigmoid 1) matches the packed
    path, which is plain per-group RTN on the merged weights.
    """
    din, dout = w.shape
    wg, wmin, wmax = group_minmax(w, group)
    scale = jnp.maximum((wmax - wmin) / qmax, EPS)
    zp = jnp.round(-wmin / scale)
    q = jnp.clip(jnp.round(wg / scale) + zp, 0.0, qmax)
    err = ((q - zp) * scale).reshape(din, dout) - w
    return jnp.mean(err * err), jnp.max(jnp.abs(err))


def lwc_shapes(cfg, group):
    """(name, shape) for the LWC gamma/beta of each quantized weight."""
    shapes = []
    wshapes = dict(cfg.block_weight_names())
    for wname in cfg.quantized_weight_names():
        din, dout = wshapes[wname]
        g = din if group == 0 else group
        shapes.append((f"lwc_g_{wname}", (din // g, dout)))
        shapes.append((f"lwc_b_{wname}", (din // g, dout)))
    return shapes
