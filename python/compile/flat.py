"""Flat-vector parameter packing.

Every AOT entry point takes parameters as a single f32 vector: PJRT call
overhead is per-buffer, and a flat layout gives the rust side a trivial
Adam/optimizer implementation and a trivial checkpoint format. The layout
(name, shape, offset) is recorded in the manifest so rust can view/patch
individual tensors in place.
"""

import numpy as np
import jax.numpy as jnp


def numel(shape):
    n = 1
    for s in shape:
        n *= s
    return n


class Layout:
    """Ordered mapping name -> (shape, offset) over one flat f32 vector."""

    def __init__(self, named_shapes):
        self.entries = []  # (name, shape, offset)
        off = 0
        for name, shape in named_shapes:
            self.entries.append((name, tuple(shape), off))
            off += numel(shape)
        self.size = off
        self.index = {name: (shape, off) for name, shape, off in self.entries}

    def slice(self, theta, name):
        shape, off = self.index[name]
        return theta[off:off + numel(shape)].reshape(shape)

    def unflatten(self, theta):
        return {name: self.slice(theta, name) for name, _, _ in self.entries}

    def flatten(self, d):
        parts = [jnp.ravel(d[name]) for name, _, _ in self.entries]
        return jnp.concatenate(parts)

    def flatten_np(self, d):
        parts = [np.ravel(np.asarray(d[name], dtype=np.float32))
                 for name, _, _ in self.entries]
        return np.concatenate(parts)

    def to_manifest(self):
        return [{"name": n, "shape": list(s), "offset": o}
                for n, s, o in self.entries]
