"""Pure-HLO differentiable matrix inverse for SDD matrices.

``jnp.linalg.inv`` lowers to LAPACK custom-calls on CPU, which the rust PJRT
loader (xla_extension 0.5.1) cannot execute. AffineQuant's Gradual Mask keeps
the affine matrix strictly diagonally dominant (Levy-Desplanques), so
Gauss-Jordan elimination *without pivoting* is numerically stable here and
lowers to a plain `while` HLO loop.

The backward pass uses the analytic identity d(A^{-1}) = -A^{-1} dA A^{-1}
via jax.custom_vjp, so reverse-mode never differentiates through the loop.
"""

import jax
import jax.numpy as jnp
from jax import lax


def _gj_inverse(a):
    """Gauss-Jordan inverse, no pivoting. a: (n, n)."""
    n = a.shape[-1]
    aug = jnp.concatenate([a, jnp.eye(n, dtype=a.dtype)], axis=-1)

    def body(i, aug):
        pivot = aug[i, :] / aug[i, i]
        aug = aug - jnp.outer(aug[:, i], pivot)
        aug = aug.at[i, :].set(pivot)
        return aug

    aug = lax.fori_loop(0, n, body, aug)
    return aug[:, n:]


@jax.custom_vjp
def inv_sdd(a):
    """Inverse of a strictly diagonally dominant matrix. Differentiable."""
    return _gj_inverse(a)


def _inv_fwd(a):
    b = _gj_inverse(a)
    return b, b


def _inv_bwd(b, g):
    return (-(b.T @ g @ b.T),)


inv_sdd.defvjp(_inv_fwd, _inv_bwd)


def inv_sdd_blocks(a):
    """Inverse of a stack of SDD blocks. a: (h, n, n) -> (h, n, n).

    Used for the per-head block-diagonal affine matrix at the out_proj site.
    vmap composes with the custom_vjp batching rule.
    """
    return jax.vmap(inv_sdd)(a)
