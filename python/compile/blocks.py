"""Transformer block forwards for both model families.

``block_fwd`` is the FP/eval path (optionally with per-token activation
fake-quant at the four linear inputs — the w4a4 serving graph, using the
pallas ``act_quant`` kernel). ``block_capture`` additionally returns the four
linear inputs for host-side statistics (GPTQ Hessians, AWQ/SmoothQuant
scales, shift init).
"""

import jax
import jax.numpy as jnp

from . import quantize
from .kernels import act_quant

LN_EPS = 1e-5


def layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + LN_EPS) * g + b


def rms_norm(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + LN_EPS) * g


def rope(q, k):
    """Rotary embeddings over (B, h, S, hd)."""
    B, h, S, hd = q.shape
    half = hd // 2
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    freq = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]                      # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        return jnp.concatenate(
            [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)

    return rot(q), rot(k)


def attention(cfg, q, k, v):
    """Causal multi-head attention; attention internals stay FP (DESIGN §4).

    Returns the per-head context concatenated back to (B, S, d) — the input
    of out_proj, i.e. the paper's per-head affine site.
    """
    B, S, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qh = q.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    kh = k.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    vh = v.reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    if cfg.family == "ll":
        qh, kh = rope(qh, kh)
    scores = qh @ kh.transpose(0, 1, 3, 2) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((S, S), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = probs @ vh                                  # (B, h, S, hd)
    return ctx.transpose(0, 2, 1, 3).reshape(B, S, d)


def _aq(x, act_qmax, act_quant_fn):
    if act_qmax is None:
        return x
    return act_quant_fn(x, act_qmax)


def block_fwd(cfg, w, x, act_qmax=None, act_quant_fn=None, capture=False):
    """One pre-LN transformer block.

    w: dict of block weights (see configs.block_weight_names).
    act_qmax: None for FP; an array for w?a4 per-token activation quant at
    the four linear inputs (qkv / out_proj / fc1 / fc2).
    act_quant_fn: which fake-quant implementation to use (pallas kernel on
    the serving path, STE jnp twin inside calibration graphs).
    """
    if act_quant_fn is None:
        act_quant_fn = act_quant
    caps = {}
    if cfg.family == "opt":
        xn = layer_norm(x, w["ln1_g"], w["ln1_b"])
        caps["x_qkv"] = xn
        xq = _aq(xn, act_qmax, act_quant_fn)
        q = xq @ w["wq"] + w["bq"]
        k = xq @ w["wk"] + w["bk"]
        v = xq @ w["wv"] + w["bv"]
        ctx = attention(cfg, q, k, v)
        caps["x_ctx"] = ctx
        ctxq = _aq(ctx, act_qmax, act_quant_fn)
        x = x + ctxq @ w["wo"] + w["bo"]
        xn = layer_norm(x, w["ln2_g"], w["ln2_b"])
        caps["x_fc1"] = xn
        xq = _aq(xn, act_qmax, act_quant_fn)
        hmid = jax.nn.gelu(xq @ w["w1"] + w["b1"])
        caps["x_fc2"] = hmid
        hq = _aq(hmid, act_qmax, act_quant_fn)
        y = x + hq @ w["w2"] + w["b2"]
    else:
        xn = rms_norm(x, w["rms1_g"])
        caps["x_qkv"] = xn
        xq = _aq(xn, act_qmax, act_quant_fn)
        q = xq @ w["wq"]
        k = xq @ w["wk"]
        v = xq @ w["wv"]
        ctx = attention(cfg, q, k, v)
        caps["x_ctx"] = ctx
        ctxq = _aq(ctx, act_qmax, act_quant_fn)
        x = x + ctxq @ w["wo"]
        xn = rms_norm(x, w["rms2_g"])
        caps["x_fc1"] = xn
        xq = _aq(xn, act_qmax, act_quant_fn)
        hmid = jax.nn.silu(xq @ w["wg"]) * (xq @ w["wu"])
        caps["x_fc2"] = hmid
        hq = _aq(hmid, act_qmax, act_quant_fn)
        y = x + hq @ w["wd"]
    if capture:
        return y, caps
    return y


def block_capture(cfg, w, x):
    """FP forward returning (y, x_qkv, x_ctx, x_fc1, x_fc2)."""
    y, caps = block_fwd(cfg, w, x, capture=True)
    return y, caps["x_qkv"], caps["x_ctx"], caps["x_fc1"], caps["x_fc2"]
