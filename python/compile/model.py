"""Full-model graphs: embedding, LM head NLL, training step, weight fake-quant.

Parameter layouts (flat f32 vectors; see flat.py):
  * ``theta``   — globals then blocks, contiguous: [globals, b0, b1, ...]
  * ``globals`` — tok_emb (+pos_emb), final norm; the LM head ties tok_emb
  * ``wb``      — one block's weights
"""

import jax
import jax.numpy as jnp

from .blocks import block_fwd, block_capture, layer_norm, rms_norm
from .flat import Layout
from .kernels import group_fq
from . import quantize


def theta_layouts(cfg):
    """(globals_layout, block_layout, theta_layout)."""
    gl = Layout(cfg.global_weight_names())
    bl = Layout(cfg.block_weight_names())
    named = list(cfg.global_weight_names())
    for i in range(cfg.n_layers):
        named.extend((f"b{i}.{n}", s) for n, s in cfg.block_weight_names())
    return gl, bl, Layout(named)


def embed(cfg, gl, tokens, gtheta):
    """tokens (B, S) i32 -> hidden (B, S, d)."""
    p = gl.unflatten(gtheta)
    h = p["tok_emb"][tokens]
    if cfg.family == "opt":
        h = h + p["pos_emb"][None, :, :]
    return h


def head_nll(cfg, gl, hidden, targets, mask, gtheta):
    """Per-sequence masked NLL (natural log), shape (B,).

    PPL = exp(sum(nll) / sum(mask)) computed host-side; zero-shot scoring
    masks only the continuation tokens.
    """
    p = gl.unflatten(gtheta)
    if cfg.family == "opt":
        hf = layer_norm(hidden, p["lnf_g"], p["lnf_b"])
    else:
        hf = rms_norm(hidden, p["rmsf_g"])
    logits = hf @ p["tok_emb"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask, axis=-1)


def make_train_step(cfg):
    """fn(tokens (Bt,S) i32, targets (Bt,S) i32, theta) -> (loss(1,), grad)."""
    gl, bl, tl = theta_layouts(cfg)

    def loss_fn(theta, tokens, targets):
        g = theta[:gl.size]
        h = embed(cfg, gl, tokens, g)
        off = gl.size
        for _ in range(cfg.n_layers):
            wb = bl.unflatten(theta[off:off + bl.size])
            h = block_fwd(cfg, wb, h)
            off += bl.size
        nll = head_nll(cfg, gl, h, targets, jnp.ones_like(targets, jnp.float32), g)
        return jnp.sum(nll) / (tokens.shape[0] * tokens.shape[1])

    def step(tokens, targets, theta):
        loss, grad = jax.value_and_grad(loss_fn)(theta, tokens, targets)
        return loss.reshape(1), grad

    return step, (gl, bl, tl)


def make_block_entries(cfg, bl):
    """block_fp / block_a4 / block_capture over a flat block vector."""

    def block_fp(x, wb):
        return block_fwd(cfg, bl.unflatten(wb), x)

    def block_a4(x, wb, qmax_a):
        # serving path: pallas act_quant kernel at the four linear inputs
        return block_fwd(cfg, bl.unflatten(wb), x, act_qmax=qmax_a)

    def block_cap(x, wb):
        return block_capture(cfg, bl.unflatten(wb), x)

    return block_fp, block_a4, block_cap


def make_wfq(cfg, bl, group):
    """Fake-quantize the weight matrices inside a flat block vector through
    the pallas group_fq kernel (norm/bias entries pass through)."""
    lwc_layout = Layout(quantize.lwc_shapes(cfg, group))
    qnames = set(cfg.quantized_weight_names())

    def wfq(wb, lwc, qmax_w):
        w = bl.unflatten(wb)
        lw = lwc_layout.unflatten(lwc)
        out = {}
        for name, _, _ in bl.entries:
            if name in qnames:
                out[name] = group_fq(
                    w[name], lw[f"lwc_g_{name}"], lw[f"lwc_b_{name}"],
                    qmax_w, group)
            else:
                out[name] = w[name]
        return bl.flatten(out)

    return wfq, lwc_layout
