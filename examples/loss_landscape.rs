//! Loss-landscape exhibits — regenerates the paper's Figure 3 (last-block
//! MSE loss curves, AffineQuant vs OmniQuant) and Figures 5/6 (last-block
//! loss vs model PPL scatter + Pearson correlation).
//!
//!     cargo run --release --example loss_landscape -- \
//!         [--model opt-s1] [--configs w2a16,w3a16g128] [--skip-scatter]

use anyhow::Result;

use affinequant::cli::{parse_config, Cli};
use affinequant::coordinator::{calibrate, CalibOptions};
use affinequant::data::CorpusKind;
use affinequant::eval::{self, pearson};
use affinequant::harness::{Ctx, EVAL_BATCHES};
use affinequant::report::{log_line, save_series};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["loss".to_string()], args].concat())?;
    let model = cli.str_or("model", "opt-s1");
    let configs: Vec<String> =
        cli.str_or("configs", "w2a16,w3a16g128").split(',').map(str::to_string).collect();
    let mut ctx = Ctx::load()?;
    let (rt, fp) = ctx.model(&model)?;

    // ---- Figure 3: last-block loss curves ------------------------------
    for config in &configs {
        let (spec, act_bits) = parse_config(config)?;
        for (method, opts) in [
            ("affinequant", CalibOptions::affinequant(spec, act_bits)),
            ("omniquant", CalibOptions::omniquant(spec, act_bits)),
        ] {
            let (_, rep) = calibrate(&rt, &fp, &opts, false)?;
            let curve = &rep.blocks.last().unwrap().loss_curve;
            let rows: Vec<(f64, f64)> =
                curve.iter().enumerate().map(|(e, &l)| ((e + 1) as f64, l)).collect();
            save_series(&format!("fig3_loss_{model}_{config}_{method}"), "epoch,loss", &rows)?;
            println!(
                "fig3 {model} {config} {method}: first {:.3e} last {:.3e}",
                curve.first().unwrap(),
                curve.last().unwrap()
            );
        }
    }

    // ---- Figures 5/6: loss ↔ PPL scatter + Pearson r --------------------
    if !cli.flag("skip-scatter") {
        let alphas = [1.0f32, 0.3, 0.1, 0.03, 0.01, 1e-3];
        let mut pts_w: Vec<(f64, f64)> = Vec::new();
        let mut pts_c: Vec<(f64, f64)> = Vec::new();
        for &alpha in &alphas {
            let mut opts = CalibOptions::affinequant(affinequant::quant::QuantSpec::new(4, 0), 4);
            opts.alpha = alpha;
            let (qps, rep) = calibrate(&rt, &fp, &opts, false)?;
            if rep.any_diverged() {
                println!("alpha {alpha}: diverged, skipped");
                continue;
            }
            let loss = rep.last_block_loss();
            let qmax = eval::act_qmax(4);
            let pw = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, EVAL_BATCHES, qmax)?;
            let pc = eval::perplexity(&rt, &qps, CorpusKind::C4s, EVAL_BATCHES, qmax)?;
            println!("alpha {alpha:.0e}: loss {loss:.3e} ppl(wt2s) {pw:.3} ppl(c4s) {pc:.3}");
            pts_w.push((loss, pw));
            pts_c.push((loss, pc));
        }
        save_series(&format!("fig5_scatter_{model}_wt2s"), "last_block_loss,ppl", &pts_w)?;
        save_series(&format!("fig6_scatter_{model}_c4s"), "last_block_loss,ppl", &pts_c)?;
        let rw = pearson(
            &pts_w.iter().map(|p| p.0).collect::<Vec<_>>(),
            &pts_w.iter().map(|p| p.1).collect::<Vec<_>>(),
        );
        let rc = pearson(
            &pts_c.iter().map(|p| p.0).collect::<Vec<_>>(),
            &pts_c.iter().map(|p| p.1).collect::<Vec<_>>(),
        );
        println!("Pearson r: wt2s {rw:.3}  c4s {rc:.3}  (paper: ≈0.95)");
        log_line(&format!("fig56 {model}: pearson wt2s={rw:.3} c4s={rc:.3}"))?;
    }
    Ok(())
}
