//! Weight-activation (w4a4) evaluation — regenerates the paper's Table 3
//! (PPL across method set {SmoothQuant, OmniQuant, AffineQuant} vs FP16)
//! and Table 2 (six-task zero-shot accuracy).
//!
//!     cargo run --release --example w4a4_eval -- \
//!         [--models opt-s1,opt-s2,ll-s1] [--skip-zeroshot]

use anyhow::Result;

use affinequant::cli::Cli;
use affinequant::harness::{w4a4_ppl_table, zeroshot_table, Ctx};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["w4a4".to_string()], args].concat())?;
    let models: Vec<String> = cli
        .str_or("models", "opt-s1,opt-s2,ll-s1")
        .split(',')
        .map(str::to_string)
        .collect();
    let methods: Vec<String> =
        ["fp16", "smoothquant", "omniquant", "affinequant"].map(String::from).to_vec();

    let mut ctx = Ctx::load()?;
    let t3 = w4a4_ppl_table(&mut ctx, &models, &methods, "table3_w4a4")?;
    t3.print();

    if !cli.flag("skip-zeroshot") {
        let zs_methods: Vec<String> =
            ["fp16", "omniquant", "affinequant"].map(String::from).to_vec();
        let t2 = zeroshot_table(&mut ctx, &models, &zs_methods, "w4a4", "table2_zeroshot")?;
        t2.print();
    }
    Ok(())
}
