//! Ablation studies — regenerates the paper's Table 4 (numerical
//! precision), Table 5 (stability factor alpha), and Table 6 (gradual mask
//! contribution).
//!
//!     cargo run --release --example ablations -- \
//!         [--what alpha,gradual,precision] [--model opt-s1] [--config w2a16g128]

use anyhow::Result;

use affinequant::benchx::Table;
use affinequant::cli::{parse_config, Cli};
use affinequant::coordinator::{calibrate, CalibOptions};
use affinequant::data::CorpusKind;
use affinequant::eval;
use affinequant::harness::{alpha_sweep, gradual_ablation, Ctx, EVAL_BATCHES};
use affinequant::linalg;
use affinequant::model::merge::MergePrecision;
use affinequant::report::save_table;
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;
use affinequant::util::Timer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["ablations".to_string()], args].concat())?;
    let what = cli.str_or("what", "alpha,gradual,precision");
    let model = cli.str_or("model", "opt-s1");
    let mut ctx = Ctx::load()?;

    if what.contains("alpha") {
        // Table 5: full sweep 1e0 .. 1e-8
        let alphas: Vec<f32> = (0..=8).map(|k| 10f32.powi(-k)).collect();
        alpha_sweep(&mut ctx, &model, &cli.str_or("config", "w2a16g128"), &alphas, "table5_alpha")?
            .print();
    }
    if what.contains("gradual") {
        // Table 6
        gradual_ablation(&mut ctx, &model, &cli.str_or("config", "w3a16"), "table6_gradual")?
            .print();
    }
    if what.contains("precision") {
        precision_table(&mut ctx, &model)?.print();
    }
    if what.contains("projection") {
        projection_table(&mut ctx, &model)?.print();
    }
    Ok(())
}

/// Extension ablation (DESIGN.md §10 / paper "future work"): can an
/// explicit SDD re-projection after every epoch rescue stability factors
/// that are otherwise too aggressive (the NaN rows of Table 5)?
fn projection_table(ctx: &mut Ctx, model: &str) -> Result<Table> {
    let (spec, act_bits) = parse_config("w2a16")?;
    let (rt, fp) = ctx.model(model)?;
    let mut t = Table::new(
        "SDD projection extension (alpha stress)",
        &["alpha", "project_sdd", "diverged", "ppl_wt2s", "last_block_loss"],
    );
    for alpha in [1.0f32, 0.5] {
        for project in [false, true] {
            let mut opts = CalibOptions::affinequant(spec, act_bits);
            opts.alpha = alpha;
            opts.project_sdd = project;
            let (qps, rep) = calibrate(&rt, &fp, &opts, false)?;
            let ppl = if rep.any_diverged() {
                "NaN".to_string()
            } else {
                format!("{:.3}", eval::perplexity(&rt, &qps, CorpusKind::Wt2s, EVAL_BATCHES, None)?)
            };
            t.row(vec![
                format!("{alpha}"),
                format!("{project}"),
                format!("{}", rep.any_diverged()),
                ppl,
                format!("{:.3e}", rep.last_block_loss()),
            ]);
            t.print_last();
        }
    }
    save_table(&t, "ext_projection")?;
    Ok(t)
}

/// Table 4: merge error (the paper's 1000-run random-matrix protocol at our
/// dimensions), plus PPL / runtime under the three precision schemes.
fn precision_table(ctx: &mut Ctx, model: &str) -> Result<Table> {
    let mut t = Table::new(
        "Precision schemes (Table 4)",
        &["scheme", "merge_error", "ppl_wt2s", "runtime_s", "transform_bytes"],
    );
    let (spec, act_bits) = parse_config("w2a16")?;
    let (rt, fp) = ctx.model(model)?;
    let d = rt.cfg.d_model;

    for (scheme, prec) in [
        ("double", MergePrecision::F64),
        ("float", MergePrecision::F32),
        ("float-double", MergePrecision::F32InvF64),
    ] {
        // merge error: ‖XW − (XA⁻¹)(AW)‖² mean over random SDD A (paper §4.3)
        let runs = 100;
        let mut err_sum = 0.0f64;
        let mut rng = Pcg32::seeded(42);
        for _ in 0..runs {
            let mut a = Tensor::randn(&[d, d], 1.0 / d as f32, &mut rng);
            for i in 0..d {
                let off: f32 =
                    (0..d).filter(|&j| j != i).map(|j| a.data[i * d + j].abs()).sum();
                a.data[i * d + i] = 1.2 * (off + 0.05);
            }
            let x = Tensor::randn(&[64, d], 1.0, &mut rng);
            let w = Tensor::randn(&[d, d], 0.05, &mut rng);
            let ainv = affinequant::model::merge::inverse_prec(&a, prec);
            let aw = affinequant::model::merge::mm_prec(&a, &w, prec);
            let y0 = x.matmul(&w);
            let y1 = x.matmul(&ainv).matmul(&aw);
            err_sum += y0.mse(&y1);
        }
        let merge_err = err_sum / runs as f64;

        // PPL + runtime of a full calibration under this scheme
        let mut opts = CalibOptions::affinequant(spec, act_bits);
        opts.prec = prec;
        let timer = Timer::start();
        let (qps, _) = calibrate(&rt, &fp, &opts, false)?;
        let secs = timer.secs();
        let ppl = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, EVAL_BATCHES, None)?;
        // transform working-set bytes per block: 2·d² + h·hd² matrices
        let elems = 2 * d * d + rt.cfg.n_heads * rt.cfg.head_dim * rt.cfg.head_dim;
        let bytes = match prec {
            MergePrecision::F32 => elems * 4,
            MergePrecision::F64 => elems * 8,
            MergePrecision::F32InvF64 => elems * 4 + d * d * 8,
        };
        t.row(vec![
            scheme.to_string(),
            format!("{merge_err:.3e}"),
            format!("{ppl:.3}"),
            format!("{secs:.1}"),
            format!("{bytes}"),
        ]);
        t.print_last();
    }
    // sanity: the f64 inverse is orders tighter on the residual metric
    let mut rng = Pcg32::seeded(7);
    let mut a = Tensor::randn(&[d, d], 1.0 / d as f32, &mut rng);
    for i in 0..d {
        let off: f32 = (0..d).filter(|&j| j != i).map(|j| a.data[i * d + j].abs()).sum();
        a.data[i * d + i] = 1.2 * (off + 0.05);
    }
    let a64: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
    let r32 = linalg::inverse_residual(
        &a64,
        &affinequant::model::merge::inverse_prec(&a, MergePrecision::F32)
            .data
            .iter()
            .map(|&v| v as f64)
            .collect::<Vec<_>>(),
        d,
    );
    println!("f32 inverse residual at d={d}: {r32:.3e}");
    save_table(&t, "table4_precision")?;
    Ok(t)
}
