//! PPL vs weighted-memory Pareto frontier — regenerates the paper's
//! Figure 4: for each model size and quantization config, the deployed
//! memory (packed codes + scales/zps + kept affine matrices) against PPL,
//! for AffineQuant vs OmniQuant (the paper's comparison pair).
//!
//!     cargo run --release --example pareto_frontier -- \
//!         [--models opt-s1,opt-s2] [--configs w2a16g64,w3a16,w4a16]

use anyhow::Result;

use affinequant::benchx::Table;
use affinequant::cli::{parse_config, Cli};
use affinequant::data::CorpusKind;
use affinequant::eval::{self, weighted_memory_bytes};
use affinequant::harness::{method_ppl, Ctx};
use affinequant::report::save_table;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["pareto".to_string()], args].concat())?;
    let models: Vec<String> =
        cli.str_or("models", "opt-s1,opt-s2").split(',').map(str::to_string).collect();
    let configs: Vec<String> =
        cli.str_or("configs", "w2a16g64,w3a16,w4a16").split(',').map(str::to_string).collect();

    let mut ctx = Ctx::load()?;
    let mut t = Table::new(
        "PPL vs weighted memory (Fig. 4)",
        &["model", "config", "method", "memory_bytes", "ppl_wt2s", "ppl_c4s"],
    );
    for model in &models {
        // FP16 anchor point
        let (rt, fp) = ctx.model(model)?;
        let fp_mem = affinequant::quant::fp16_bytes(fp.theta.len());
        let ppl_w = eval::perplexity(&rt, &fp, CorpusKind::Wt2s, affinequant::harness::EVAL_BATCHES, None)?;
        let ppl_c = eval::perplexity(&rt, &fp, CorpusKind::C4s, affinequant::harness::EVAL_BATCHES, None)?;
        t.row(vec![
            model.clone(),
            "fp16".into(),
            "fp16".into(),
            format!("{fp_mem}"),
            format!("{ppl_w:.3}"),
            format!("{ppl_c:.3}"),
        ]);
        t.print_last();
        for config in &configs {
            let (spec, act_bits) = parse_config(config)?;
            for method in ["omniquant", "affinequant"] {
                let ppl = method_ppl(&mut ctx, model, method, spec, act_bits)?;
                // AffineQuant keeps the full A⁻¹ per site in weight-only
                // deployment; OmniQuant's diagonal folds away entirely.
                let kept = method == "affinequant";
                let (_, fp2) = ctx.model(model)?;
                let mem = weighted_memory_bytes(&fp2, spec, kept);
                t.row(vec![
                    model.clone(),
                    config.clone(),
                    method.into(),
                    format!("{mem}"),
                    format!("{:.3}", ppl["wt2s"]),
                    format!("{:.3}", ppl["c4s"]),
                ]);
                t.print_last();
            }
        }
    }
    t.print();
    save_table(&t, "fig4_pareto")?;
    Ok(())
}
