//! Full weight-only sweep — regenerates the paper's Tables 1/8/9 (OPT on
//! WikiText2/PTB/C4) and Tables 10/11 (LLaMA) in one pass: each quantized
//! model is evaluated on all three corpora.
//!
//!     cargo run --release --example weight_only_sweep -- \
//!         [--models opt-s1,opt-s2,opt-s3] \
//!         [--configs w2a16g64,w3a16,w3a16g128,w4a16,w4a16g128] \
//!         [--methods rtn,gptq,awq,omniquant,affinequant]

use anyhow::Result;

use affinequant::cli::Cli;
use affinequant::harness::{weight_only_tables, Ctx};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["sweep".to_string()], args].concat())?;
    let models: Vec<String> = cli
        .str_or("models", "opt-s1,opt-s2,ll-s1")
        .split(',')
        .map(str::to_string)
        .collect();
    let configs: Vec<String> = cli
        .str_or("configs", "w2a16g64,w2a16g128,w3a16,w3a16g128,w4a16,w4a16g128")
        .split(',')
        .map(str::to_string)
        .collect();
    let methods: Vec<String> = cli
        .str_or("methods", "rtn,gptq,awq,omniquant,affinequant")
        .split(',')
        .map(str::to_string)
        .collect();

    let mut ctx = Ctx::load()?;
    let t = weight_only_tables(&mut ctx, &models, &configs, &methods, "weight_only_sweep")?;
    t.print();
    Ok(())
}
