//! End-to-end driver (DESIGN.md §6): trains (or loads) the `opt-s1`
//! checkpoint through the AOT `train_step` artifact, runs AffineQuant
//! calibration at w4a16 and w4a4, and evaluates perplexity on all three
//! corpora plus the six zero-shot tasks against FP16 and RTN.
//!
//!     cargo run --release --example quickstart [-- --model opt-s1]

use anyhow::Result;

use affinequant::benchx::Table;
use affinequant::cli::{parse_config, Cli};
use affinequant::data::CorpusKind;
use affinequant::eval::{self, act_qmax, zeroshot};
use affinequant::harness::Ctx;
use affinequant::report::save_table;
use affinequant::util::Timer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&[vec!["quickstart".to_string()], args].concat())?;
    let model = cli.str_or("model", "opt-s1");
    let mut ctx = Ctx::load()?;
    let t = Timer::start();

    println!("== quickstart: {model} ==");
    let (rt, fp) = ctx.model(&model)?;
    println!(
        "model {} ({} params, {} blocks), artifacts loaded",
        rt.cfg.name,
        affinequant::util::human_count(rt.cfg.params as f64),
        rt.cfg.n_layers
    );

    let mut ppl_t = Table::new(
        &format!("quickstart PPL — {model}"),
        &["method", "config", "wt2s", "ptbs", "c4s"],
    );
    let mut zs_rows: Vec<(String, Vec<(String, f64)>)> = Vec::new();

    for (method, config) in [
        ("fp16", "-"),
        ("rtn", "w4a16"),
        ("affinequant", "w4a16"),
        ("rtn", "w4a4"),
        ("affinequant", "w4a4"),
    ] {
        let (qps, qmax) = if method == "fp16" {
            (fp.clone(), None)
        } else {
            let (spec, act_bits) = parse_config(config)?;
            let q = affinequant::baselines::quantize_with(
                &rt,
                &fp,
                method,
                spec,
                act_bits,
                affinequant::harness::default_alpha(&model, spec),
            )?;
            (q, act_qmax(act_bits))
        };
        let mut row = vec![method.to_string(), config.to_string()];
        for kind in CorpusKind::all() {
            row.push(format!(
                "{:.3}",
                eval::perplexity(&rt, &qps, kind, affinequant::harness::EVAL_BATCHES, qmax)?
            ));
        }
        ppl_t.row(row);
        ppl_t.print_last();
        zs_rows.push((
            format!("{method} {config}"),
            zeroshot::suite(&rt, &qps, affinequant::harness::ZEROSHOT_N, qmax)?,
        ));
    }
    ppl_t.print();
    save_table(&ppl_t, "quickstart_ppl")?;

    let mut header = vec!["method".to_string()];
    header.extend(zeroshot::TASKS.iter().map(|s| s.to_string()));
    header.push("avg".into());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut zs_t = Table::new(&format!("quickstart zero-shot — {model}"), &hrefs);
    for (label, suite) in zs_rows {
        let mut row = vec![label];
        row.extend(suite.iter().map(|(_, a)| format!("{a:.2}")));
        zs_t.row(row);
    }
    zs_t.print();
    save_table(&zs_t, "quickstart_zeroshot")?;

    // Deployment path: pack the trained weights to w4g128 and decode a few
    // continuations through the host engine (KV cache + continuous
    // batching; `affinequant generate` is the CLI twin of this snippet).
    let (spec, _) = parse_config("w4a16g128")?;
    let mut engine = affinequant::engine::Engine::from_store(&fp, spec, 4);
    println!("\n== packed engine — {}", engine.memory_report());
    let prompts = ["the bani ", "a fel of the ", "the masi sotos "];
    let gen_t = Timer::start();
    let (texts, stats) =
        engine.generate_text(&prompts, 32, affinequant::engine::Sampler::Greedy, 0)?;
    for (p, o) in prompts.iter().zip(&texts) {
        println!("  {p}⟨{o}⟩");
    }
    println!(
        "  {} generated (+{} prefill) at {:.0} tok/s throughput (peak batch {})",
        stats.tokens_generated,
        stats.tokens_processed - stats.tokens_generated,
        stats.tokens_processed as f64 / gen_t.secs().max(1e-9),
        stats.peak_batch
    );

    println!("quickstart done in {}", affinequant::util::human_secs(t.secs()));
    Ok(())
}
