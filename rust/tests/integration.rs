//! Integration tests over the real AOT artifacts + PJRT runtime.
//! Require `make artifacts` (skip with a clear message otherwise). They use
//! `opt-s1`/`ll-s1` with tiny calibration settings so the whole file runs
//! in a couple of minutes on one core.

use affinequant::coordinator::{calibrate, CalibOptions};
use affinequant::data::CorpusKind;
use affinequant::eval;
use affinequant::model::ParamStore;
use affinequant::quant::QuantSpec;
use affinequant::runtime::{Arg, Runtime};
use affinequant::tensor::Tensor;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping integration tests: run `make artifacts` first");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime"))
}

fn small_opts(spec: QuantSpec, act_bits: u32) -> CalibOptions {
    let mut o = CalibOptions::affinequant(spec, act_bits);
    o.n_calib = 16;
    o.epochs = 3;
    o
}

fn init_model(rt: &affinequant::runtime::ModelRuntime) -> ParamStore {
    let mut ps =
        ParamStore::new(rt.cfg.clone(), rt.globals_layout.clone(), rt.block_layout.clone());
    ps.init(42);
    ps
}

#[test]
fn manifest_models_all_load_and_execute_blocks() {
    let Some(root) = runtime() else { return };
    for name in root.model_names() {
        let rt = root.model(&name).unwrap();
        let ps = init_model(&rt);
        let cfg = &rt.cfg;
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % 200) as i32).collect();
        let h = rt.embed(&tokens, ps.globals()).unwrap();
        assert_eq!(h.shape, vec![cfg.batch, cfg.seq, cfg.d_model], "{name}");
        let y = rt.block_fp(&h, ps.block(0)).unwrap();
        assert_eq!(y.shape, h.shape, "{name}");
        assert!(y.data.iter().all(|v| v.is_finite()), "{name}: non-finite block output");
    }
}

#[test]
fn block_a4_quantizes_activations() {
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let ps = init_model(&rt);
    let tokens: Vec<i32> = (0..rt.cfg.batch * rt.cfg.seq).map(|i| (i * 7 % 256) as i32).collect();
    let h = rt.embed(&tokens, ps.globals()).unwrap();
    let y_fp = rt.block_fp(&h, ps.block(0)).unwrap();
    let y_a4 = rt.block_a4(&h, ps.block(0), 15.0).unwrap();
    let y_a8 = rt.block_a4(&h, ps.block(0), 255.0).unwrap();
    // quantization must change the output, and 8-bit must be closer than 4-bit
    let e4 = y_fp.mse(&y_a4);
    let e8 = y_fp.mse(&y_a8);
    assert!(e4 > 0.0 && e8 > 0.0);
    assert!(e8 < e4, "a8 {e8} should beat a4 {e4}");
}

#[test]
fn capture_outputs_match_block_fp() {
    let Some(root) = runtime() else { return };
    let rt = root.model("ll-s1").unwrap();
    let ps = init_model(&rt);
    let tokens: Vec<i32> = (0..rt.cfg.batch * rt.cfg.seq).map(|i| (i % 250) as i32).collect();
    let h = rt.embed(&tokens, ps.globals()).unwrap();
    let y = rt.block_fp(&h, ps.block(0)).unwrap();
    let caps = rt.block_capture(&h, ps.block(0)).unwrap();
    assert_eq!(caps.len(), 5);
    assert!(y.sub(&caps[0]).max_abs() < 1e-5, "capture y != block_fp y");
    // fc2 capture has ff width
    assert_eq!(*caps[4].shape.last().unwrap(), rt.cfg.d_ff);
}

#[test]
fn wfq_matches_host_quantizer() {
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let ps = init_model(&rt);
    let spec = QuantSpec::new(4, 0);
    let lwc_layout = &rt.lwc_layouts["g0"];
    let lwc = vec![20.0f32; lwc_layout.size]; // sigmoid≈1 ⇒ no clipping
    let got = rt.wfq(0, ps.block(0), &lwc, spec.qmax()).unwrap();
    // compare one weight against the host quantizer
    let bl = &rt.block_layout;
    let w = bl.tensor(ps.block(0), "wq");
    let want = affinequant::quant::quant_dequant(&w, spec, None);
    let got_wq = bl.tensor(&got.data, "wq");
    assert!(
        got_wq.sub(&want).max_abs() < 1e-4,
        "pallas group_fq vs host quantizer: {}",
        got_wq.sub(&want).max_abs()
    );
    // norm entries pass through untouched
    let g0 = bl.tensor(ps.block(0), "ln1_g");
    let g1 = bl.tensor(&got.data, "ln1_g");
    assert_eq!(g0, g1);
}

#[test]
fn calib_step_loss_decreases_and_masked_grads_zero() {
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let ps = init_model(&rt);
    let playout = rt.phi_layouts["w_g0"].clone();
    let cfg = &rt.cfg;
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i * 13 % 256) as i32).collect();
    let x = rt.embed(&tokens, ps.globals()).unwrap();
    let y = rt.block_fp(&x, ps.block(0)).unwrap();

    // diagonal-identity init, full-open mask with alpha damping
    let mut phi = vec![0.0f32; playout.size];
    for name in ["A_qkv", "A_fc1"] {
        let r = playout.range(name);
        let n = playout.shape(name)[0];
        for i in 0..n {
            phi[r.start + i * n + i] = 1.0;
        }
    }
    let r = playout.range("A_out");
    let (h, hd) = (cfg.n_heads, cfg.head_dim);
    for hi in 0..h {
        for k in 0..hd {
            phi[r.start + hi * hd * hd + k * hd + k] = 1.0;
        }
    }
    for (name, _, _) in playout.entries.clone() {
        if name.starts_with("lwc_") {
            phi[playout.range(&name)].fill(4.0);
        }
    }
    // mask: diagonal-only (band 0) — off-diagonal grads must come back 0
    let sched = affinequant::coordinator::mask::MaskSchedule {
        alpha: 0.1,
        epochs: 10,
        full_affine: true,
        gradual: true,
    };
    let mphi = sched.mphi(&playout, 1); // epoch 1 of 10 on d=128 ⇒ band 12.8
    let qmax = [7.0f32];
    let call = |phi: &[f32]| {
        rt.call(
            "calib_w_g0",
            &[
                Arg::F32(&x.data),
                Arg::F32(&y.data),
                Arg::F32(ps.block(0)),
                Arg::F32(phi),
                Arg::F32(&mphi),
                Arg::F32(&qmax),
            ],
        )
        .unwrap()
    };
    let outs = call(&phi);
    let loss0 = outs[0].data[0];
    let grad = &outs[1];
    assert!(loss0.is_finite() && loss0 > 0.0);
    // gradient of masked-out entries is exactly zero (Eq. 9: GM ∘ dL/dA*)
    let rq = playout.range("A_qkv");
    let n = playout.shape("A_qkv")[0];
    for i in 0..n {
        for j in 0..n {
            if (i as f32 - j as f32).abs() > sched.band(1, n) {
                assert_eq!(
                    grad.data[rq.start + i * n + j], 0.0,
                    "grad outside band nonzero at ({i},{j})"
                );
            }
        }
    }
    // a few SGD steps must reduce the loss
    let mut phi2 = phi.clone();
    let mut last = loss0;
    for _ in 0..5 {
        let outs = call(&phi2);
        last = outs[0].data[0];
        for (p, g) in phi2.iter_mut().zip(&outs[1].data) {
            *p -= 0.05 * g;
        }
    }
    assert!(last <= loss0, "loss did not decrease: {loss0} -> {last}");
}

#[test]
fn full_calibration_improves_over_rtn_and_keeps_finite() {
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    // use the trained checkpoint when available (realistic distributions)
    let mut ps = init_model(&rt);
    let ck = "checkpoints/opt-s1.aqck";
    if std::path::Path::new(ck).exists() {
        ps.load_into(ck).unwrap();
    }
    let spec = QuantSpec::new(2, 64);
    let opts = small_opts(spec, 16);
    let (qps, rep) = calibrate(&rt, &ps, &opts, true).unwrap();
    assert!(!rep.any_diverged());
    assert_eq!(rep.blocks.len(), rt.cfg.n_layers);
    // SDD margins recorded and positive (Levy-Desplanques held)
    for b in &rep.blocks {
        assert!(!b.sdd_margins.is_empty());
        assert!(b.sdd_margins.iter().all(|&m| m > 0.0), "SDD violated: {:?}", b.sdd_margins);
    }
    let ppl_fp = eval::perplexity(&rt, &ps, CorpusKind::Wt2s, 2, None).unwrap();
    let ppl_q = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, 2, None).unwrap();
    assert!(ppl_q.is_finite() && ppl_q > 1.0);
    assert!(ppl_q > ppl_fp * 0.95, "quantized ppl implausibly below fp");
    let rtn = affinequant::baselines::rtn::quantize(&rt, &ps, spec).unwrap();
    let ppl_rtn = eval::perplexity(&rt, &rtn, CorpusKind::Wt2s, 2, None).unwrap();
    assert!(
        ppl_q <= ppl_rtn * 1.05,
        "affinequant ({ppl_q:.3}) should not lose clearly to RTN ({ppl_rtn:.3})"
    );
}

#[test]
fn a4_merge_serves_equivalently_at_high_bits() {
    // At w8a8 the merged a4 model must sit very close to FP: the fold into
    // LN/bias is exact, only mild quantization noise remains.
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let mut ps = init_model(&rt);
    let ck = "checkpoints/opt-s1.aqck";
    if std::path::Path::new(ck).exists() {
        ps.load_into(ck).unwrap();
    }
    let mut opts = small_opts(QuantSpec::new(8, 0), 8);
    opts.epochs = 1;
    let (qps, _) = calibrate(&rt, &ps, &opts, false).unwrap();
    let ppl_fp = eval::perplexity(&rt, &ps, CorpusKind::Wt2s, 2, None).unwrap();
    let ppl_q = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, 2, eval::act_qmax(8)).unwrap();
    assert!(
        (ppl_q / ppl_fp - 1.0).abs() < 0.05,
        "w8a8 merged model drifted: fp {ppl_fp:.3} vs q {ppl_q:.3}"
    );
}

#[test]
fn train_step_reduces_loss_from_scratch() {
    let Some(root) = runtime() else { return };
    let rt = root.model("ll-s1").unwrap();
    let mut ps = init_model(&rt);
    let tc = affinequant::train::TrainConfig {
        steps: 30,
        corpus_bytes: 200_000,
        log_every: 10,
        ..Default::default()
    };
    let curve = affinequant::train::train_lm(&rt, &mut ps, &tc).unwrap();
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(last < first, "training did not reduce loss: {first} -> {last}");
}

#[test]
fn head_nll_is_a_proper_nll() {
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let ps = init_model(&rt);
    let cfg = &rt.cfg;
    let tokens: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % 256) as i32).collect();
    let h = rt.embed(&tokens, ps.globals()).unwrap();
    let ones = vec![1.0f32; cfg.batch * cfg.seq];
    let nll = rt.head_nll(&h, &tokens, &ones, ps.globals()).unwrap();
    assert_eq!(nll.shape, vec![cfg.batch]);
    // random init ⇒ per-token NLL near ln(vocab) = ln 256 ≈ 5.55; the tied
    // embedding head makes self-prediction cheaper, so allow a wide band
    let per_tok = nll.data.iter().sum::<f32>() / (cfg.batch * cfg.seq) as f32;
    assert!(per_tok > 2.0 && per_tok < 8.0, "per-token NLL {per_tok}");
    // half mask ⇒ half the NLL mass
    let mut half = ones.clone();
    for v in half.iter_mut().skip(cfg.seq / 2).step_by(1).take(cfg.seq / 2) {
        *v = 0.0;
    }
    let nll_half = rt.head_nll(&h, &tokens, &half, ps.globals()).unwrap();
    assert!(nll_half.data[0] < nll.data[0]);
}

#[test]
fn gradual_mask_off_is_riskier_than_on() {
    // Structural check of the Table-6 mechanism: without gradual release
    // the epoch-1 mask already contains every off-diagonal at alpha.
    let Some(root) = runtime() else { return };
    let rt = root.model("opt-s1").unwrap();
    let playout = rt.phi_layouts["w_g0"].clone();
    let mk = |gradual| affinequant::coordinator::mask::MaskSchedule {
        alpha: 0.5,
        epochs: 10,
        full_affine: true,
        gradual,
    };
    let m_on = mk(true).mphi(&playout, 1);
    let m_off = mk(false).mphi(&playout, 1);
    let r = playout.range("A_qkv");
    let live = |m: &Vec<f32>| m[r.clone()].iter().filter(|&&v| v != 0.0).count();
    assert!(live(&m_off) > live(&m_on) * 4, "{} vs {}", live(&m_off), live(&m_on));
}

#[test]
fn tensor_literal_roundtrip_through_identity_entry() {
    // embed with an identity-ish check: tokens map to rows of tok_emb
    let Some(root) = runtime() else { return };
    let rt = root.model("ll-s1").unwrap();
    let ps = init_model(&rt);
    let cfg = &rt.cfg;
    let tok0 = 17i32;
    let tokens: Vec<i32> = vec![tok0; cfg.batch * cfg.seq];
    let h = rt.embed(&tokens, ps.globals()).unwrap();
    let gl = &rt.globals_layout;
    let emb = gl.tensor(ps.globals(), "tok_emb");
    let row: Vec<f32> = emb.data[tok0 as usize * cfg.d_model..(tok0 as usize + 1) * cfg.d_model].to_vec();
    let got = &h.data[..cfg.d_model];
    let diff = row
        .iter()
        .zip(got)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff < 1e-6, "ll embed must be a pure row lookup (no pos emb): {diff}");
    let _ = Tensor::zeros(&[1]);
}

#[test]
fn engine_hidden_matches_pjrt_block_chain() {
    // The packed engine's host forward vs the PJRT "merged serving" path:
    // fake-quant the weights host-side (RTN == plain quant_dequant), run
    // embed + block_fp through XLA, and compare against the engine's
    // hidden states over the same tokens. The only divergences are f16
    // narrowing of the packed scales and XLA-vs-host float ordering.
    let Some(root) = runtime() else { return };
    for name in ["opt-s1", "ll-s1"] {
        let rt = root.model(name).unwrap();
        let ps = init_model(&rt);
        let spec = QuantSpec::new(4, 128);
        let qps = affinequant::baselines::rtn::quantize(&rt, &ps, spec).unwrap();
        let cfg = rt.cfg.clone();
        let tokens: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| ((i * 31 + 5) % 256) as i32).collect();
        let mut h = rt.embed(&tokens, qps.globals()).unwrap();
        for b in 0..cfg.n_layers {
            h = rt.block_fp(&h, qps.block(b)).unwrap();
        }
        let pm = affinequant::engine::PackedModel::from_store(&ps, spec);
        let d = cfg.d_model;
        let mut max_diff = 0.0f32;
        let mut max_mag = 0.0f32;
        for s in 0..cfg.batch {
            let seq_toks = &tokens[s * cfg.seq..(s + 1) * cfg.seq];
            let hh = affinequant::engine::hidden_full(&pm, seq_toks);
            for t in 0..cfg.seq {
                for j in 0..d {
                    let a = hh.at2(t, j);
                    let b = h.data[(s * cfg.seq + t) * d + j];
                    max_diff = max_diff.max((a - b).abs());
                    max_mag = max_mag.max(b.abs());
                }
            }
        }
        assert!(
            max_diff < 0.05 * (1.0 + max_mag),
            "{name}: engine vs PJRT hidden diverged: {max_diff} (mag {max_mag})"
        );
        println!("{name}: engine-vs-pjrt max|diff| = {max_diff:.2e} (mag {max_mag:.2e})");
    }
}
