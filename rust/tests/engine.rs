//! Engine-level tests: fused packed GEMM vs the fake-quant reference,
//! KV-cache equivalence (incremental decode == full-context forward,
//! bit-identical), continuous-batching invariance, and the greedy-decode
//! acceptance check against a reference host forward on a seeded
//! checkpoint. Pure host — runs with `--no-default-features`.

use affinequant::engine::decode::{self, argmax, Sampler, StepInput};
use affinequant::engine::kv::KvCache;
use affinequant::engine::packed::{PackedLinear, PackedModel};
use affinequant::engine::{Engine, Request};
use affinequant::model::zoo;
use affinequant::prop_assert;
use affinequant::proptestx::{Runner, Shrink};
use affinequant::quant::{quant_dequant, QuantSpec};
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

// ------------------------------------------------------- GEMM properties

#[derive(Clone, Debug)]
struct GemmCase {
    din: usize,
    dout: usize,
    bits: u32,
    group: usize,
    m: usize,
    seed: u64,
}

impl Shrink for GemmCase {}

fn gen_case(rng: &mut Pcg32) -> GemmCase {
    let din = 64 * (1 + rng.below(4)); // 64..256, divisible by all groups
    let dout = 16 + rng.below(100);
    let bits = [2u32, 3, 4, 8][rng.below(4)];
    let group = [0usize, 16, 32, 64][rng.below(4)];
    let m = 1 + rng.below(17);
    GemmCase { din, dout, bits, group, m, seed: rng.next_u64() }
}

/// Fused packed GEMM == dense GEMM over the dequantized weights (same
/// deployment params, so only summation order differs).
#[test]
fn prop_packed_matmul_matches_dequant_gemm() {
    Runner { cases: 48, ..Default::default() }.run(
        "packed matmul == x @ dequant(W)",
        gen_case,
        |c| {
            let mut rng = Pcg32::seeded(c.seed);
            let w = Tensor::randn(&[c.din, c.dout], 1.0, &mut rng);
            let spec = QuantSpec::new(c.bits, c.group);
            let pl = PackedLinear::pack("w", &w, spec);
            let x = Tensor::randn(&[c.m, c.din], 1.0, &mut rng);
            let got = pl.matmul(&x.data, c.m);
            let want = x.matmul(&pl.dequantize());
            let scale = 1.0 + want.max_abs();
            for (i, (&g, &wv)) in got.iter().zip(&want.data).enumerate() {
                prop_assert!(
                    (g - wv).abs() <= 1e-3 * scale,
                    "{c:?} elem {i}: {g} vs {wv} (scale {scale})"
                );
            }
            Ok(())
        },
    );
}

/// Packed GEMM tracks the f32 fake-quant reference GEMM to ≤1e-3 relative —
/// the only divergence is f16 narrowing of the per-group scale (zero-points
/// are integers ≤ qmax, exact in f16).
#[test]
fn prop_packed_matmul_matches_fake_quant_reference() {
    Runner { cases: 48, ..Default::default() }.run(
        "packed matmul == x @ fake_quant(W) to 1e-3",
        gen_case,
        |c| {
            let mut rng = Pcg32::seeded(c.seed ^ 0xabcd);
            let w = Tensor::randn(&[c.din, c.dout], 1.0, &mut rng);
            let spec = QuantSpec::new(c.bits, c.group);
            let pl = PackedLinear::pack("w", &w, spec);
            let x = Tensor::randn(&[c.m, c.din], 1.0, &mut rng);
            let got = pl.matmul(&x.data, c.m);
            let want = x.matmul(&quant_dequant(&w, spec, None));
            let scale = 1.0 + want.max_abs();
            for (i, (&g, &wv)) in got.iter().zip(&want.data).enumerate() {
                prop_assert!(
                    (g - wv).abs() <= 1e-3 * scale,
                    "{c:?} elem {i}: {g} vs {wv} (scale {scale})"
                );
            }
            Ok(())
        },
    );
}

// --------------------------------------------- KV-cache equivalence

fn test_tokens(n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 41 + 13) % 256) as i32).collect()
}

/// Incremental decode through the ring KV cache produces *bit-identical*
/// logits to the whole-context reference forward, for both families.
#[test]
fn kv_incremental_equals_full_forward() {
    for (name, spec) in [
        ("opt-s1", QuantSpec::new(4, 128)),
        ("ll-s1", QuantSpec::new(3, 64)),
    ] {
        let ps = zoo::seeded_store(name, 42).unwrap();
        let pm = PackedModel::from_store(&ps, spec);
        let tokens = test_tokens(24);
        let full = decode::forward_full(&pm, &tokens);
        let cfg = &pm.cfg;
        let mut cache = KvCache::new(1, cfg.n_layers, tokens.len(), cfg.d_model);
        for (i, &tok) in tokens.iter().enumerate() {
            let logits = decode::step(&pm, &[StepInput { slot: 0, token: tok, pos: i }], &mut cache);
            assert_eq!(
                logits.row(0),
                full.row(i),
                "{name}: step {i} logits differ from full forward"
            );
        }
    }
}

/// Acceptance: engine greedy decode (with continuous batching around it) is
/// bit-identical argmax to re-running a reference host forward after every
/// token, on a seeded checkpoint.
#[test]
fn greedy_decode_matches_reference_forward() {
    let name = "opt-s1";
    let spec = QuantSpec::new(4, 128);
    let ps = zoo::seeded_store(name, 42).unwrap();
    let pm = PackedModel::from_store(&ps, spec);

    let prompt = test_tokens(8);
    let max_new = 12;

    // reference: full forward after every token, take argmax of last row
    let mut seq = prompt.clone();
    let mut reference = Vec::new();
    for _ in 0..max_new {
        let logits = decode::forward_full(&pm, &seq);
        let tok = argmax(logits.row(seq.len() - 1));
        reference.push(tok);
        seq.push(tok);
    }

    // engine: same request, decoded alongside two other live sequences
    let mut engine = Engine::new(pm, 3);
    let reqs = vec![
        Request { id: 0, prompt: prompt.clone(), max_new, eos: None },
        Request { id: 1, prompt: test_tokens(5), max_new: 20, eos: None },
        Request { id: 2, prompt: test_tokens(17), max_new: 3, eos: None },
    ];
    let (completions, stats) = engine.generate(reqs, Sampler::Greedy, 0);
    assert_eq!(completions.len(), 3);
    assert_eq!(
        completions[0].tokens, reference,
        "engine decode diverged from the reference host forward"
    );
    assert!(stats.peak_batch == 3, "requests must actually share steps");
}

/// A sequence's greedy output is independent of the batch it shares steps
/// with — the continuous-batching correctness property.
#[test]
fn completions_invariant_to_max_batch() {
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i as u64,
            prompt: test_tokens(3 + 5 * i),
            max_new: 4 + 3 * i,
            eos: None,
        })
        .collect();
    let run = |max_batch: usize| {
        let mut e = Engine::new(pm.clone(), max_batch);
        e.generate(reqs.clone(), Sampler::Greedy, 0).0
    };
    let serial = run(1);
    let batched = run(4);
    assert_eq!(serial.len(), 5);
    for (a, b) in serial.iter().zip(&batched) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} depends on batch composition", a.id);
    }
}

/// RoPE models keep decoding past the cache capacity via the sliding ring.
#[test]
fn ring_slides_past_capacity_for_rope_models() {
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let mut engine = Engine::from_store(&ps, QuantSpec::new(4, 128), 1);
    let cap = engine.model.cfg.seq;
    let max_new = cap + 12; // forces eviction of the oldest entries
    let (c, _) = engine.generate(
        vec![Request { id: 0, prompt: test_tokens(4), max_new, eos: None }],
        Sampler::Greedy,
        0,
    );
    assert_eq!(c[0].tokens.len(), max_new);
    assert!(c[0].tokens.iter().all(|&t| (0..256).contains(&t)));
}

/// Save → load → serve roundtrip: identical completions.
#[test]
fn packed_model_roundtrip_preserves_decode() {
    let ps = zoo::seeded_store("opt-s1", 7).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(2, 64));
    let path = "/tmp/aq_engine_roundtrip.bin";
    pm.save(path).unwrap();
    let mut e1 = Engine::new(pm, 2);
    let mut e2 = Engine::load(path, 2).unwrap();
    std::fs::remove_file(path).ok();
    let reqs = vec![Request { id: 0, prompt: test_tokens(6), max_new: 10, eos: None }];
    let (c1, _) = e1.generate(reqs.clone(), Sampler::Greedy, 0);
    let (c2, _) = e2.generate(reqs, Sampler::Greedy, 0);
    assert_eq!(c1[0].tokens, c2[0].tokens);
}
