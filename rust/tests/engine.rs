//! Engine-level tests: fused packed GEMM vs the fake-quant reference,
//! KV-cache equivalence (incremental decode == full-context forward,
//! bit-identical), continuous-batching invariance, and the greedy-decode
//! acceptance check against a reference host forward on a seeded
//! checkpoint. Pure host — runs with `--no-default-features`.

use affinequant::engine::decode::{self, argmax, Sampler, StepInput};
use affinequant::engine::gemm::{packed_gemm_with, PackedWeight};
use affinequant::engine::kernels;
use affinequant::engine::kv::KvCache;
use affinequant::engine::packed::{PackedLinear, PackedModel};
use affinequant::engine::{Engine, FinishReason, Request, SchedConfig, Scheduler, SubmitError};
use affinequant::model::zoo;
use affinequant::prop_assert;
use affinequant::proptestx::{Runner, Shrink};
use affinequant::quant::{quant_dequant, QuantSpec};
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

// ------------------------------------------------------- GEMM properties

#[derive(Clone, Debug)]
struct GemmCase {
    din: usize,
    dout: usize,
    bits: u32,
    group: usize,
    m: usize,
    seed: u64,
}

impl Shrink for GemmCase {}

fn gen_case(rng: &mut Pcg32) -> GemmCase {
    let din = 64 * (1 + rng.below(4)); // 64..256, divisible by all groups
    let dout = 16 + rng.below(100);
    let bits = [2u32, 3, 4, 8][rng.below(4)];
    let group = [0usize, 16, 32, 64][rng.below(4)];
    let m = 1 + rng.below(17);
    GemmCase { din, dout, bits, group, m, seed: rng.next_u64() }
}

/// Fused packed GEMM == dense GEMM over the dequantized weights (same
/// deployment params, so only summation order differs).
#[test]
fn prop_packed_matmul_matches_dequant_gemm() {
    Runner { cases: 48, ..Default::default() }.run(
        "packed matmul == x @ dequant(W)",
        gen_case,
        |c| {
            let mut rng = Pcg32::seeded(c.seed);
            let w = Tensor::randn(&[c.din, c.dout], 1.0, &mut rng);
            let spec = QuantSpec::new(c.bits, c.group);
            let pl = PackedLinear::pack("w", &w, spec);
            let x = Tensor::randn(&[c.m, c.din], 1.0, &mut rng);
            let got = pl.matmul(&x.data, c.m);
            let want = x.matmul(&pl.dequantize());
            let scale = 1.0 + want.max_abs();
            for (i, (&g, &wv)) in got.iter().zip(&want.data).enumerate() {
                prop_assert!(
                    (g - wv).abs() <= 1e-3 * scale,
                    "{c:?} elem {i}: {g} vs {wv} (scale {scale})"
                );
            }
            Ok(())
        },
    );
}

/// Packed GEMM tracks the f32 fake-quant reference GEMM to ≤1e-3 relative —
/// the only divergence is f16 narrowing of the per-group scale (zero-points
/// are integers ≤ qmax, exact in f16).
#[test]
fn prop_packed_matmul_matches_fake_quant_reference() {
    Runner { cases: 48, ..Default::default() }.run(
        "packed matmul == x @ fake_quant(W) to 1e-3",
        gen_case,
        |c| {
            let mut rng = Pcg32::seeded(c.seed ^ 0xabcd);
            let w = Tensor::randn(&[c.din, c.dout], 1.0, &mut rng);
            let spec = QuantSpec::new(c.bits, c.group);
            let pl = PackedLinear::pack("w", &w, spec);
            let x = Tensor::randn(&[c.m, c.din], 1.0, &mut rng);
            let got = pl.matmul(&x.data, c.m);
            let want = x.matmul(&quant_dequant(&w, spec, None));
            let scale = 1.0 + want.max_abs();
            for (i, (&g, &wv)) in got.iter().zip(&want.data).enumerate() {
                prop_assert!(
                    (g - wv).abs() <= 1e-3 * scale,
                    "{c:?} elem {i}: {g} vs {wv} (scale {scale})"
                );
            }
            Ok(())
        },
    );
}

// --------------------------------------------- KV-cache equivalence

fn test_tokens(n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 41 + 13) % 256) as i32).collect()
}

/// Incremental decode through the ring KV cache produces *bit-identical*
/// logits to the whole-context reference forward, for both families.
#[test]
fn kv_incremental_equals_full_forward() {
    for (name, spec) in [
        ("opt-s1", QuantSpec::new(4, 128)),
        ("ll-s1", QuantSpec::new(3, 64)),
    ] {
        let ps = zoo::seeded_store(name, 42).unwrap();
        let pm = PackedModel::from_store(&ps, spec);
        let tokens = test_tokens(24);
        let full = decode::forward_full(&pm, &tokens);
        let cfg = &pm.cfg;
        let mut cache = KvCache::new(1, cfg.n_layers, tokens.len(), cfg.d_model);
        for (i, &tok) in tokens.iter().enumerate() {
            let logits = decode::step(&pm, &[StepInput { slot: 0, token: tok, pos: i }], &mut cache);
            assert_eq!(
                logits.row(0),
                full.row(i),
                "{name}: step {i} logits differ from full forward"
            );
        }
    }
}

/// Acceptance: engine greedy decode (with continuous batching around it) is
/// bit-identical argmax to re-running a reference host forward after every
/// token, on a seeded checkpoint.
#[test]
fn greedy_decode_matches_reference_forward() {
    let name = "opt-s1";
    let spec = QuantSpec::new(4, 128);
    let ps = zoo::seeded_store(name, 42).unwrap();
    let pm = PackedModel::from_store(&ps, spec);

    let prompt = test_tokens(8);
    let max_new = 12;

    // reference: full forward after every token, take argmax of last row
    let mut seq = prompt.clone();
    let mut reference = Vec::new();
    for _ in 0..max_new {
        let logits = decode::forward_full(&pm, &seq);
        let tok = argmax(logits.row(seq.len() - 1));
        reference.push(tok);
        seq.push(tok);
    }

    // engine: same request, decoded alongside two other live sequences
    let mut engine = Engine::new(pm, 3);
    let reqs = vec![
        Request { id: 0, prompt: prompt.clone(), max_new, eos: None },
        Request { id: 1, prompt: test_tokens(5), max_new: 20, eos: None },
        Request { id: 2, prompt: test_tokens(17), max_new: 3, eos: None },
    ];
    let (completions, stats) = engine.generate(reqs, Sampler::Greedy, 0).unwrap();
    assert_eq!(completions.len(), 3);
    assert_eq!(
        completions[0].tokens, reference,
        "engine decode diverged from the reference host forward"
    );
    assert!(stats.peak_batch == 3, "requests must actually share steps");
}

/// A sequence's greedy output is independent of the batch it shares steps
/// with — the continuous-batching correctness property.
#[test]
fn completions_invariant_to_max_batch() {
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            id: i as u64,
            prompt: test_tokens(3 + 5 * i),
            max_new: 4 + 3 * i,
            eos: None,
        })
        .collect();
    let run = |max_batch: usize| {
        let mut e = Engine::new(pm.clone(), max_batch);
        e.generate(reqs.clone(), Sampler::Greedy, 0).unwrap().0
    };
    let serial = run(1);
    let batched = run(4);
    assert_eq!(serial.len(), 5);
    for (a, b) in serial.iter().zip(&batched) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} depends on batch composition", a.id);
    }
}

/// Chunked prefill — any chunk size, with or without a per-tick token
/// budget — produces bit-identical greedy completions to token-at-a-time
/// prefill, for both families, including prompts longer than the KV ring
/// (chunks that wrap the ring mid-prefill).
#[test]
fn chunked_prefill_bit_identical_for_any_chunk_and_budget() {
    for (name, spec, prompt_len) in [
        ("opt-s1", QuantSpec::new(4, 128), 24usize),
        ("ll-s1", QuantSpec::new(3, 64), 24),
        // prompt longer than the KV ring capacity (128): prefill slides it
        ("ll-s1", QuantSpec::new(4, 128), 200),
    ] {
        let ps = zoo::seeded_store(name, 42).unwrap();
        let pm = PackedModel::from_store(&ps, spec);
        let reqs: Vec<Request> = (0..3u64)
            .map(|i| Request {
                id: i,
                prompt: test_tokens(prompt_len + 3 * i as usize),
                max_new: 6,
                eos: None,
            })
            .collect();
        let run = |sched: SchedConfig| {
            let mut e = Engine::with_config(pm.clone(), 2, sched);
            e.generate(reqs.clone(), Sampler::Greedy, 0).unwrap().0
        };
        let base = run(SchedConfig { prefill_chunk: 1, ..SchedConfig::default() });
        assert_eq!(base.len(), 3);
        for sched in [
            SchedConfig { prefill_chunk: 4, ..SchedConfig::default() },
            SchedConfig { prefill_chunk: 16, ..SchedConfig::default() },
            // 0 = the whole remaining prompt in one chunk
            SchedConfig { prefill_chunk: 0, ..SchedConfig::default() },
            // tight budget: chunks are clipped but outputs must not change
            SchedConfig { prefill_chunk: 16, token_budget: 8, ..SchedConfig::default() },
        ] {
            let got = run(sched);
            assert_eq!(base.len(), got.len());
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens, b.tokens,
                    "{name} prompt_len={prompt_len} {sched:?}: chunking changed the output"
                );
                assert_eq!(a.finish, b.finish);
            }
        }
    }
}

/// A slot freed by the positional-table eviction sweep must be refilled by
/// a queued request in the *same* tick (regression: admission used to run
/// only before the sweep, idling freed capacity for a full step).
#[test]
fn evicted_slot_is_refilled_the_same_tick() {
    let ps = zoo::seeded_store("opt-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let seq = pm.cfg.seq;
    let sched = SchedConfig { prefill_chunk: 16, ..SchedConfig::default() };
    let mut e = Engine::with_config(pm, 2, sched);
    let reqs = vec![
        // overruns the positional table -> evicted mid-prefill by the sweep
        Request { id: 0, prompt: test_tokens(seq + 12), max_new: 4, eos: None },
        // keeps the other slot busy while the eviction happens
        Request { id: 1, prompt: test_tokens(4), max_new: 60, eos: None },
        // queued behind both; must enter the freed slot the tick it frees
        Request { id: 2, prompt: test_tokens(5), max_new: 4, eos: None },
    ];
    let (c, stats) = e.generate(reqs, Sampler::Greedy, 0).unwrap();
    assert_eq!(
        stats.starved_ticks, 0,
        "a slot idled for a tick while requests were queued"
    );
    assert_eq!(c.len(), 3);
    // the truncated sequence is flagged, not passed off as a completion
    assert_eq!(c[0].finish, FinishReason::PosCapacity);
    assert!(c[0].tokens.is_empty(), "mid-prefill eviction generates nothing");
    assert_eq!(c[0].prompt_len, seq + 12);
    assert_eq!(c[1].tokens.len(), 60);
    assert_eq!(c[1].finish, FinishReason::MaxNew);
    assert_eq!(c[2].tokens.len(), 4);
    assert_eq!(c[2].finish, FinishReason::MaxNew);
}

/// Decoding through a small KV ring far past its capacity is bit-identical
/// to an independent sliding-window reference forward (flat arena, window
/// masks) — the ring's eviction path checked from outside `kv.rs`.
#[test]
fn ring_eviction_matches_sliding_window_reference() {
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 64));
    let cfg = pm.cfg.clone();
    let window = 16usize;
    let prompt = test_tokens(8);
    let steps = 40usize; // decode to 3x the ring capacity

    let mut cache = KvCache::new(1, cfg.n_layers, window, cfg.d_model);
    let mut last = decode::step(
        &pm,
        &[StepInput { slot: 0, token: prompt[0], pos: 0 }],
        &mut cache,
    );
    for (i, &tok) in prompt.iter().enumerate().skip(1) {
        last = decode::step(&pm, &[StepInput { slot: 0, token: tok, pos: i }], &mut cache);
    }
    let mut seq = prompt.clone();
    for step_i in 0..steps {
        let reference = decode::forward_window(&pm, &seq, window);
        assert_eq!(
            last.row(0),
            reference.row(seq.len() - 1),
            "step {step_i}: ring logits diverge from the sliding-window reference"
        );
        let tok = argmax(last.row(0));
        assert_eq!(tok, argmax(reference.row(seq.len() - 1)));
        let pos = seq.len();
        seq.push(tok);
        last = decode::step(&pm, &[StepInput { slot: 0, token: tok, pos }], &mut cache);
    }
    assert!(seq.len() > window + prompt.len(), "test must actually wrap the ring");
}

/// The tentpole acceptance check for paged KV: greedy completions are
/// byte-identical to the pre-refactor reference trace (the ring was proven
/// bit-identical to a sliding-window forward, so that forward *is* the
/// reference) for every page size in {1, 4, 16, 64}, with and without
/// prefix sharing, across both reclamation orders, and with the pool both
/// unbounded and tightly bounded. Prompts share nested prefixes so the
/// sharing + copy-on-write path actually fires, and the longest prompt
/// overruns the attention window so trimming fires too.
#[test]
fn paged_kv_bit_stable_across_page_size_sharing_and_reclaim() {
    use affinequant::engine::{worst_case_pages_for, KvConfig, Reclaim};

    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let window = pm.cfg.seq;

    let shapes: [(usize, usize); 3] = [(24, 6), (26, 5), (140, 4)];
    let reqs: Vec<Request> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(plen, max_new))| Request {
            id: i as u64,
            prompt: test_tokens(plen),
            max_new,
            eos: None,
        })
        .collect();

    // reference trace: re-run the sliding-window forward after every token
    let reference: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let mut seq = r.prompt.clone();
            let mut out = Vec::new();
            for _ in 0..r.max_new {
                let logits = decode::forward_window(&pm, &seq, window);
                let tok = argmax(logits.row(seq.len() - 1));
                out.push(tok);
                seq.push(tok);
            }
            out
        })
        .collect();

    let sched = SchedConfig { prefill_chunk: 4, ..SchedConfig::default() };
    for page_tokens in [1usize, 4, 16, 64] {
        for share in [true, false] {
            for reclaim in [Reclaim::Lru, Reclaim::Mru] {
                // tight enough that parked prefix pages must be reclaimed,
                // roomy enough that every request is admissible
                let worst = worst_case_pages_for(window, page_tokens, 140, 6, 4);
                for max_pages in [0, 2 * worst + 2] {
                    let kv = KvConfig { page_tokens, max_pages, share, reclaim };
                    let mut e = Engine::with_kv_config(pm.clone(), 2, sched, kv);
                    let (got, _) = e.generate(reqs.clone(), Sampler::Greedy, 0).unwrap();
                    assert_eq!(got.len(), reqs.len());
                    for (c, want) in got.iter().zip(&reference) {
                        assert_eq!(
                            &c.tokens, want,
                            "{kv:?}: paged engine diverged from the pre-refactor reference"
                        );
                        assert_eq!(c.finish, FinishReason::MaxNew, "{kv:?}");
                    }
                }
            }
        }
    }
}

/// RoPE models keep decoding past the cache capacity via the sliding ring.
#[test]
fn ring_slides_past_capacity_for_rope_models() {
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let mut engine = Engine::from_store(&ps, QuantSpec::new(4, 128), 1);
    let cap = engine.model.cfg.seq;
    let max_new = cap + 12; // forces eviction of the oldest entries
    let (c, _) = engine
        .generate(
            vec![Request { id: 0, prompt: test_tokens(4), max_new, eos: None }],
            Sampler::Greedy,
            0,
        )
        .unwrap();
    assert_eq!(c[0].tokens.len(), max_new);
    assert!(c[0].tokens.iter().all(|&t| (0..256).contains(&t)));
}

/// Save → load → serve roundtrip: identical completions.
#[test]
fn packed_model_roundtrip_preserves_decode() {
    let ps = zoo::seeded_store("opt-s1", 7).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(2, 64));
    let path = "/tmp/aq_engine_roundtrip.bin";
    pm.save(path).unwrap();
    let mut e1 = Engine::new(pm, 2);
    let mut e2 = Engine::load(path, 2).unwrap();
    std::fs::remove_file(path).ok();
    let reqs = vec![Request { id: 0, prompt: test_tokens(6), max_new: 10, eos: None }];
    let (c1, _) = e1.generate(reqs.clone(), Sampler::Greedy, 0).unwrap();
    let (c2, _) = e2.generate(reqs, Sampler::Greedy, 0).unwrap();
    assert_eq!(c1[0].tokens, c2[0].tokens);
}

// ------------------------------------------- serving-robustness scheduler

/// A small packed model + matching cache for direct `Scheduler` tests.
fn sched_fixture(max_batch: usize) -> (PackedModel, KvCache) {
    let ps = zoo::seeded_store("opt-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let cache = KvCache::new(max_batch, pm.cfg.n_layers, pm.cfg.seq, pm.cfg.d_model);
    (pm, cache)
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request { id, prompt, max_new, eos: None }
}

/// Malformed requests are refused as values, never panics — and through
/// `Engine::generate` they surface as errors naming the request.
#[test]
fn submit_refuses_malformed_requests() {
    let mut sched = Scheduler::new(1);
    assert_eq!(sched.submit(req(0, vec![], 4)), Err(SubmitError::EmptyPrompt));
    assert_eq!(sched.submit(req(1, vec![5], 0)), Err(SubmitError::ZeroMaxNew));
    assert!(sched.submit(req(2, vec![5], 1)).is_ok());

    let ps = zoo::seeded_store("opt-s1", 42).unwrap();
    let mut engine = Engine::from_store(&ps, QuantSpec::new(4, 128), 1);
    let err = engine.generate(vec![req(9, vec![], 4)], Sampler::Greedy, 0).unwrap_err();
    assert!(err.to_string().contains("request 9"), "{err}");
}

/// Past `queue_cap` the pending deque sheds instead of growing; the shed
/// count lands in `RunStats` and capacity freed by a drain re-admits.
#[test]
fn queue_cap_bounds_the_pending_deque() {
    let cfg = SchedConfig { queue_cap: 2, ..SchedConfig::default() };
    let mut sched = Scheduler::with_config(1, cfg);
    assert!(sched.submit(req(0, vec![1], 1)).is_ok());
    assert!(sched.submit(req(1, vec![1], 1)).is_ok());
    assert_eq!(sched.submit(req(2, vec![1], 1)), Err(SubmitError::QueueFull { cap: 2 }));
    assert_eq!(sched.pending_len(), 2, "the refused request must not queue");
    assert_eq!(sched.stats.shed_requests, 1);

    let (pm, mut cache) = sched_fixture(1);
    let mut rng = Pcg32::seeded(0);
    let done = sched.run(&pm, &mut cache, Sampler::Greedy, &mut rng);
    assert_eq!(done.len(), 2);
    assert!(sched.submit(req(2, vec![1], 1)).is_ok(), "drained queue admits again");
}

/// `evict_expired` with an explicit clock: deterministic deadline eviction
/// for both queued and live sequences, partial output preserved.
#[test]
fn deadline_eviction_is_deterministic() {
    let (pm, mut cache) = sched_fixture(1);
    let mut rng = Pcg32::seeded(0);
    let mut sched = Scheduler::new(1);
    let soon = std::time::Instant::now() + std::time::Duration::from_secs(3600);
    sched.submit_at(req(0, vec![3, 4, 5], 100), Some(soon)).unwrap();
    sched.submit_at(req(1, vec![6, 7], 100), Some(soon)).unwrap();

    // a few ticks: request 0 decodes in the only slot, request 1 queues
    for _ in 0..6 {
        sched.tick(&pm, &mut cache, Sampler::Greedy, &mut rng);
    }
    assert_eq!(sched.active_len(), 1);
    assert_eq!(sched.pending_len(), 1);
    assert!(sched.take_finished().is_empty());

    // jump the clock past both deadlines — no sleeping, no wall time
    sched.evict_expired(soon, &mut cache);
    let mut done = sched.take_finished();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].finish, FinishReason::Deadline);
    assert!(!done[0].tokens.is_empty(), "mid-decode eviction keeps partial output");
    assert_eq!(done[1].finish, FinishReason::Deadline);
    assert!(done[1].tokens.is_empty(), "queued eviction never decoded");
    assert_eq!(sched.stats.deadline_evictions, 2);
    assert_eq!(sched.active_len(), 0, "the slot must be reclaimed");
    assert!(!sched.has_work());
}

/// `cancel` (the disconnect path) frees the slot without a completion and
/// the freed capacity is immediately reusable.
#[test]
fn cancel_frees_slot_without_completion() {
    let (pm, mut cache) = sched_fixture(1);
    let mut rng = Pcg32::seeded(0);
    let mut sched = Scheduler::new(1);
    sched.submit(req(0, vec![3, 4, 5], 100)).unwrap();
    sched.submit(req(1, vec![6, 7], 100)).unwrap();
    for _ in 0..4 {
        sched.tick(&pm, &mut cache, Sampler::Greedy, &mut rng);
    }
    assert!(sched.cancel(0, &mut cache), "live sequence");
    assert!(sched.cancel(1, &mut cache), "queued sequence");
    assert!(!sched.cancel(7, &mut cache), "unknown id");
    assert_eq!(sched.stats.cancelled, 2);
    assert!(!sched.has_work());
    assert!(sched.take_finished().is_empty(), "cancel delivers nothing");

    sched.submit(req(2, vec![9, 9], 3)).unwrap();
    let done = sched.run(&pm, &mut cache, Sampler::Greedy, &mut rng);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].tokens.len(), 3, "reclaimed slot decodes normally");
}

/// Telemetry is observation only: enabling the recorder (histograms,
/// spans, journal) must leave greedy completions bit-identical and the
/// scheduler counters unchanged — while actually populating the registry.
#[test]
fn telemetry_on_is_bit_identical_to_telemetry_off() {
    use affinequant::telemetry::Recorder;

    let ps = zoo::seeded_store("opt-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i as u64,
            prompt: test_tokens(3 + 7 * i),
            max_new: 5 + 2 * i,
            eos: None,
        })
        .collect();
    let sched = SchedConfig { prefill_chunk: 4, ..SchedConfig::default() };

    let mut plain = Engine::with_config(pm.clone(), 2, sched);
    let (base, base_stats) = plain.generate(reqs.clone(), Sampler::Greedy, 0).unwrap();

    let mut instrumented = Engine::with_config(pm, 2, sched);
    instrumented.recorder = Recorder::new_enabled();
    let (got, got_stats) = instrumented.generate(reqs, Sampler::Greedy, 0).unwrap();

    assert_eq!(base.len(), got.len());
    for (a, b) in base.iter().zip(&got) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {}: telemetry changed the output", a.id);
        assert_eq!(a.finish, b.finish);
    }
    assert_eq!(base_stats.tokens_generated, got_stats.tokens_generated);
    assert_eq!(base_stats.scheduler_steps, got_stats.scheduler_steps);

    // and the run actually left a trail behind
    let t = instrumented.recorder.telemetry().unwrap();
    assert_eq!(t.ttft.count(), 4, "one TTFT per request");
    assert!(t.inter_token.count() > 0);
    assert_eq!(t.request.count(), 4);
    assert_eq!(t.queue_wait.count(), 4);
    assert!(t.tick.count() as usize == got_stats.scheduler_steps);
    let span = t.traces.get(3).expect("span for request 3");
    assert_eq!(span.tokens, 11);
    assert_eq!(span.outcome, "max_new");
    assert!(span.ttft_ms >= 0.0 && span.total_ms >= span.ttft_ms);
}

/// Numeric-health sampling and the cross-bit-width divergence draft are
/// observation only: with the recorder live and a w2 draft enabled, greedy
/// completions stay bit-identical to the uninstrumented engine — while the
/// per-layer sampler and the probe accumulator actually populate.
#[test]
fn numeric_sampling_and_draft_keep_greedy_bit_identical() {
    use affinequant::telemetry::Recorder;

    let ps = zoo::seeded_store("opt-s1", 42).unwrap();
    let pm = PackedModel::from_store(&ps, QuantSpec::new(4, 128));
    // from_store bakes the calibration probe into every layer
    assert_eq!(pm.calib.len(), pm.cfg.n_layers, "one calibration record per layer");
    for c in &pm.calib {
        assert!(c.act_count > 0, "calibration probe must feed every layer");
        assert!(c.weight_mse > 0.0, "quantization error is never exactly zero");
    }

    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i as u64,
            prompt: test_tokens(4 + 6 * i),
            max_new: 24,
            eos: None,
        })
        .collect();
    let sched = SchedConfig { prefill_chunk: 4, ..SchedConfig::default() };

    let mut plain = Engine::with_config(pm.clone(), 2, sched);
    let (base, _) = plain.generate(reqs.clone(), Sampler::Greedy, 0).unwrap();

    let mut observed = Engine::with_config(pm, 2, sched);
    observed.recorder = Recorder::new_enabled();
    observed.enable_draft(QuantSpec::new(2, 128));
    let (got, _) = observed.generate(reqs, Sampler::Greedy, 0).unwrap();

    assert_eq!(base.len(), got.len());
    for (a, b) in base.iter().zip(&got) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {}: numeric sampling changed the output", a.id);
        assert_eq!(a.finish, b.finish);
    }

    let t = observed.recorder.telemetry().unwrap();
    let snap = t.numeric.snapshot();
    assert_eq!(snap.layers.len(), observed.model.cfg.n_layers);
    let rows: u64 = snap.layers.iter().map(|l| l.rows).sum();
    assert!(rows > 0, "1-in-16 sampling must hit at least one row");
    assert!(snap.div.probes > 0, "divergence probe must fire after the warm-up ticks");
    assert_eq!(snap.div.serve_bits, 4);
    assert_eq!(snap.div.draft_bits, 2);
    let pct = snap.div.agree_pct();
    assert!((0.0..=100.0).contains(&pct), "agree_pct out of range: {pct}");
}

/// The per-tick `emitted()` stream — what the HTTP server forwards —
/// reassembles into exactly the completions' token lists.
#[test]
fn emitted_stream_reassembles_completions() {
    let (pm, mut cache) = sched_fixture(2);
    let mut rng = Pcg32::seeded(0);
    let mut sched = Scheduler::new(2);
    sched.submit(req(0, vec![3, 4, 5], 7)).unwrap();
    sched.submit(req(1, vec![6, 7], 5)).unwrap();
    sched.submit(req(2, vec![8], 4)).unwrap();

    let mut streamed: std::collections::HashMap<u64, Vec<i32>> = Default::default();
    let mut done = Vec::new();
    loop {
        let more = sched.tick(&pm, &mut cache, Sampler::Greedy, &mut rng);
        for &(id, tok) in sched.emitted() {
            streamed.entry(id).or_default().push(tok);
        }
        done.extend(sched.take_finished());
        if !more {
            break;
        }
    }
    assert_eq!(done.len(), 3);
    for c in &done {
        assert_eq!(streamed[&c.id], c.tokens, "request {}: stream != completion", c.id);
    }
}

// ------------------------------------------------ kernel dispatch parity

#[derive(Clone, Debug)]
struct KernelCase {
    din: usize,
    dout: usize,
    bits: u32,
    group: usize,
    m: usize,
    seed: u64,
}

impl Shrink for KernelCase {}

fn gen_kernel_case(rng: &mut Pcg32) -> KernelCase {
    // din divisible by every group in the set; dout deliberately unaligned
    // so plan_stripes produces merged ragged tails.
    let din = 128 * (1 + rng.below(2));
    let dout = 16 + rng.below(150);
    let bits = [2u32, 3, 4, 8][rng.below(4)];
    let group = [0usize, 32, 64, 128][rng.below(4)];
    let m = 1 + rng.below(9);
    KernelCase { din, dout, bits, group, m, seed: rng.next_u64() }
}

/// The dispatch acceptance invariant: every compiled-and-runnable kernel
/// variant produces *bit-identical* GEMM output to the runtime-generic
/// scalar baseline, across all bit-widths × group sizes × ragged `dout`
/// tails × batch sizes (the full threaded path, not just one stripe).
#[test]
fn prop_kernel_variants_bit_identical() {
    Runner { cases: 48, ..Default::default() }.run(
        "packed GEMM bit-identical across kernel variants",
        gen_kernel_case,
        |c| {
            let mut rng = Pcg32::seeded(c.seed ^ 0x5eed);
            let w = Tensor::randn(&[c.din, c.dout], 1.0, &mut rng);
            let spec = QuantSpec::new(c.bits, c.group);
            let pl = PackedLinear::pack("w", &w, spec);
            let (scales, zps) = pl.params();
            let pw = PackedWeight {
                packed: &pl.packed,
                bits: c.bits,
                din: c.din,
                dout: c.dout,
                group_len: spec.group_len(c.din),
                scales,
                zps,
            };
            let x: Vec<f32> = (0..c.m * c.din).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0.0f32; c.m * c.dout];
            packed_gemm_with(kernels::reference_kernel(), &pw, &x, &mut want, c.m);
            for v in kernels::available() {
                let k = kernels::select_for(v, c.bits, pw.group_len);
                let mut got = vec![0.0f32; c.m * c.dout];
                packed_gemm_with(k, &pw, &x, &mut got, c.m);
                prop_assert!(
                    got == want,
                    "{c:?}: kernel {} diverges from the generic baseline",
                    k.name
                );
            }
            Ok(())
        },
    );
}

/// Greedy engine output does not depend on the dispatch variant: a model
/// forced onto the scalar baseline kernels generates bit-identical tokens
/// to the auto-dispatched model (whatever this host selected).
#[test]
fn forced_scalar_kernel_keeps_greedy_bit_identical() {
    let spec = QuantSpec::new(4, 128);
    let ps = zoo::seeded_store("ll-s1", 42).unwrap();
    let pm_auto = PackedModel::from_store(&ps, spec);
    let mut pm_scalar = PackedModel::from_store(&ps, spec);
    pm_scalar.force_kernel(kernels::Variant::Scalar);
    assert!(
        pm_scalar.kernel_name().starts_with("scalar/"),
        "force_kernel must pin every linear to the scalar baseline (got {})",
        pm_scalar.kernel_name()
    );

    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i as u64,
            prompt: test_tokens(4 + 5 * i),
            max_new: 16,
            eos: None,
        })
        .collect();
    let sched = SchedConfig { prefill_chunk: 4, ..SchedConfig::default() };

    let mut e_auto = Engine::with_config(pm_auto, 2, sched);
    let (base, _) = e_auto.generate(reqs.clone(), Sampler::Greedy, 0).unwrap();
    let mut e_scalar = Engine::with_config(pm_scalar, 2, sched);
    let (got, _) = e_scalar.generate(reqs, Sampler::Greedy, 0).unwrap();

    assert_eq!(base.len(), got.len());
    for (a, b) in base.iter().zip(&got) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "request {}: kernel variant changed greedy output (auto {} vs scalar)",
            a.id,
            e_auto.model.kernel_name()
        );
        assert_eq!(a.finish, b.finish);
    }
}
