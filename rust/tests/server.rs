//! End-to-end serving tests over a real socket: spawn the HTTP front-end
//! in-process on an ephemeral port and drive it with raw `TcpStream`
//! clients. Covers the whole degradation ladder — 400s for malformed
//! payloads, 429 + `Retry-After` past the admission ceiling and per-client
//! cap, deadline expiry (504 / `"deadline"`), mid-stream disconnects
//! freeing their slot, graceful drain — plus the bit-stability contract:
//! greedy tokens streamed over the socket are identical to offline
//! [`Engine::generate`] output. Pure host — runs with
//! `--no-default-features`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use affinequant::engine::{Engine, Sampler, SchedConfig};
use affinequant::jsonx::{self, Value};
use affinequant::model::zoo;
use affinequant::quant::QuantSpec;
use affinequant::server::fault::FaultConfig;
use affinequant::server::{Server, ServerConfig, ServerHandle};

// --------------------------------------------------------------- fixtures

fn test_engine(max_batch: usize) -> Engine {
    let ps = zoo::seeded_store("opt-s1", 42).expect("zoo model");
    let mut engine = Engine::from_store(&ps, QuantSpec::new(4, 128), max_batch);
    engine.sched = SchedConfig { prefill_chunk: 16, ..SchedConfig::default() };
    engine
}

fn spawn(max_batch: usize, cfg: ServerConfig) -> ServerHandle {
    Server::spawn(test_engine(max_batch), cfg).expect("spawn server")
}

fn quiet_cfg() -> ServerConfig {
    ServerConfig { addr: "127.0.0.1:0".into(), workers: 4, ..ServerConfig::default() }
}

/// Like [`test_engine`] but with the w2 divergence draft enabled, so the
/// numeric-health surface has cross-bit-width probes to report.
fn numeric_engine(max_batch: usize) -> Engine {
    let mut engine = test_engine(max_batch);
    engine.enable_draft(QuantSpec::new(2, 128));
    engine
}

// ------------------------------------------------------------ raw client

struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) {
    send_request_with(stream, method, path, body, &[]);
}

fn send_request_with(
    stream: &mut TcpStream,
    method: &str,
    path: &str,
    body: &str,
    headers: &[(&str, &str)],
) {
    let extra: String = headers.iter().map(|(k, v)| format!("{k}: {v}\r\n")).collect();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n{extra}\r\n{body}",
        body.len()
    )
    .expect("write request");
}

/// Parse a full `Connection: close` response (de-chunking if needed).
fn parse_response(raw: &[u8]) -> Response {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("no header/body separator");
    let head = String::from_utf8_lossy(&raw[..split]);
    let mut lines = head.split("\r\n");
    let status_line = lines.next().expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"));
    let headers: Vec<(String, String)> = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect();
    let mut resp = Response { status, headers, body: raw[split + 4..].to_vec() };
    if resp.header("transfer-encoding") == Some("chunked") {
        resp.body = dechunk(&resp.body);
    }
    resp
}

/// Reassemble a chunked body; tolerates a truncated tail (cut streams).
fn dechunk(mut raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let Some(eol) = raw.windows(2).position(|w| w == b"\r\n") else { break };
        let size = usize::from_str_radix(String::from_utf8_lossy(&raw[..eol]).trim(), 16)
            .unwrap_or(0);
        if size == 0 || raw.len() < eol + 2 + size {
            break;
        }
        out.extend_from_slice(&raw[eol + 2..eol + 2 + size]);
        raw = &raw[(eol + 2 + size + 2).min(raw.len())..];
    }
    out
}

fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> Response {
    request_with(addr, method, path, body, &[])
}

fn request_with(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    headers: &[(&str, &str)],
) -> Response {
    let mut stream = TcpStream::connect(addr).expect("connect");
    send_request_with(&mut stream, method, path, body, headers);
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    parse_response(&raw)
}

/// `data: ...` payloads from a de-chunked SSE body.
fn sse_events(body: &str) -> Vec<String> {
    body.split("\n\n")
        .filter_map(|e| e.trim().strip_prefix("data: ").map(str::to_string))
        .collect()
}

/// Read from an open stream until `needle` shows up in the bytes so far
/// (e.g. the first SSE `data:` frame proves the request is decoding).
fn read_streamed_until(s: &mut TcpStream, needle: &str) -> Vec<u8> {
    let mut acc = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        let n = s.read(&mut buf).expect("stream read");
        assert!(n > 0, "stream closed before {needle:?} arrived");
        acc.extend_from_slice(&buf[..n]);
        if String::from_utf8_lossy(&acc).contains(needle) {
            return acc;
        }
    }
}

/// Drain an SSE stream to completion and return its token events.
fn stream_tokens(mut s: TcpStream, mut raw: Vec<u8>) -> Vec<i32> {
    s.read_to_end(&mut raw).expect("drain stream");
    let resp = parse_response(&raw);
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body_str());
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    events[..events.len() - 2]
        .iter()
        .map(|e| jsonx::parse(e).expect("token event json").req("token").as_f64() as i32)
        .collect()
}

fn wait_until(what: &str, mut ok: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !ok() {
        assert!(t0.elapsed() < Duration::from_secs(20), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ----------------------------------------------------------------- tests

#[test]
fn malformed_requests_get_400_not_a_crash() {
    let handle = spawn(2, quiet_cfg());
    let addr = handle.addr;
    for body in [
        "this is not json",
        "{\"max_tokens\": 4}",                      // missing prompt
        "{\"prompt\": 7}",                         // wrong type
        "{\"prompt\": \"\", \"max_tokens\": 4}",   // scheduler: EmptyPrompt
        "{\"prompt\": \"hi\", \"max_tokens\": 0}", // scheduler: ZeroMaxNew
    ] {
        let resp = request(addr, "POST", "/v1/completions", body);
        assert_eq!(resp.status, 400, "{body:?} -> {}", resp.body_str());
    }
    assert_eq!(request(addr, "GET", "/nope", "").status, 404);
    assert_eq!(request(addr, "DELETE", "/v1/completions", "").status, 405);
    // the server survived all of it
    let health = request(addr, "GET", "/healthz", "");
    assert_eq!(health.status, 200);
    assert!(health.body_str().contains("\"ok\""));
    handle.shutdown();
    handle.join();
}

#[test]
fn streamed_and_buffered_match_offline_generate() {
    let prompt = "the bani ";
    let max_new = 12;
    let offline = {
        let mut engine = test_engine(2);
        let reqs = Engine::byte_requests(&[prompt], max_new);
        let (c, _) = engine.generate(reqs, Sampler::Greedy, 0).expect("offline generate");
        c.into_iter().next().expect("one completion")
    };

    let handle = spawn(2, quiet_cfg());
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_tokens\": {max_new}, \"stream\": true}}");
    let resp = request(handle.addr, "POST", "/v1/completions", &body);
    assert_eq!(resp.status, 200);
    let events = sse_events(&resp.body_str());
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    // per-tick token events, then the completion object, then [DONE]
    let token_events = &events[..events.len() - 2];
    let streamed: Vec<i32> = token_events
        .iter()
        .map(|e| jsonx::parse(e).expect("token event json").req("token").as_f64() as i32)
        .collect();
    assert_eq!(streamed, offline.tokens, "streamed tokens must be bit-identical to offline");
    let fin = jsonx::parse(&events[events.len() - 2]).expect("final event json");
    assert_eq!(fin.req("finish_reason"), &Value::Str("max_new".into()));
    let fin_tokens: Vec<i32> = match fin.req("tokens") {
        Value::Arr(a) => a.iter().map(|v| v.as_f64() as i32).collect(),
        other => panic!("tokens not an array: {other:?}"),
    };
    assert_eq!(fin_tokens, offline.tokens);

    // buffered mode: same result, single JSON body
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_tokens\": {max_new}}}");
    let resp = request(handle.addr, "POST", "/v1/completions", &body);
    assert_eq!(resp.status, 200);
    let v = jsonx::parse(&resp.body_str()).expect("completion json");
    let buf_tokens: Vec<i32> = match v.req("tokens") {
        Value::Arr(a) => a.iter().map(|t| t.as_f64() as i32).collect(),
        other => panic!("tokens not an array: {other:?}"),
    };
    assert_eq!(buf_tokens, offline.tokens);
    handle.shutdown();
    handle.join();
}

#[test]
fn overload_sheds_429_with_retry_after() {
    // 1 batch slot + 1 queue slot = in-flight ceiling 2; a slow engine
    // (fault tick delay) keeps both held while the third request arrives
    let cfg = ServerConfig {
        queue_cap: 1,
        client_cap: 0,
        retry_after_s: 7,
        fault: FaultConfig { tick_delay_ms: 30, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(1, cfg);
    let addr = handle.addr;
    let slow = "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"stream\": true}";
    let mut s1 = TcpStream::connect(addr).expect("connect");
    send_request(&mut s1, "POST", "/v1/completions", slow);
    let mut s2 = TcpStream::connect(addr).expect("connect");
    send_request(&mut s2, "POST", "/v1/completions", slow);
    wait_until("both requests admitted", || {
        handle.gauges.active.load(Ordering::Relaxed)
            + handle.gauges.pending.load(Ordering::Relaxed)
            >= 2
    });

    let resp = request(addr, "POST", "/v1/completions", slow);
    assert_eq!(resp.status, 429, "{}", resp.body_str());
    assert_eq!(resp.header("retry-after"), Some("7"), "429 must carry Retry-After");

    let stats = jsonx::parse(&request(addr, "GET", "/v1/stats", "").body_str()).expect("stats");
    assert!(stats.req("http").req("shed_429").as_f64() >= 1.0);
    // the pending deque never grew past its cap while overloaded
    assert!(stats.req("peak_pending").as_f64() <= 1.0);

    drop(s1); // disconnects cancel the in-flight work so drain is quick
    drop(s2);
    handle.shutdown();
    handle.join();
}

#[test]
fn per_client_cap_isolates_greedy_clients() {
    let cfg = ServerConfig {
        queue_cap: 8,
        client_cap: 1,
        fault: FaultConfig { tick_delay_ms: 20, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(4, cfg);
    let addr = handle.addr;
    let alice = "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"stream\": true, \
                 \"client_id\": \"alice\"}";
    let mut s1 = TcpStream::connect(addr).expect("connect");
    send_request(&mut s1, "POST", "/v1/completions", alice);
    wait_until("alice admitted", || handle.gauges.active.load(Ordering::Relaxed) >= 1);

    // alice is at her cap; bob is unaffected by her backlog
    let resp = request(addr, "POST", "/v1/completions", alice);
    assert_eq!(resp.status, 429, "{}", resp.body_str());
    let bob = "{\"prompt\": \"abcdef\", \"max_tokens\": 2, \"client_id\": \"bob\"}";
    let resp = request(addr, "POST", "/v1/completions", bob);
    assert_eq!(resp.status, 200, "{}", resp.body_str());

    drop(s1);
    handle.shutdown();
    handle.join();
}

#[test]
fn expired_deadline_evicts_and_reports_504() {
    // deadline_ms 1 with a 25ms/tick engine: the sweep on the second tick
    // is always past the deadline, long before max_tokens could finish
    let cfg = ServerConfig {
        fault: FaultConfig { tick_delay_ms: 25, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(2, cfg);
    let body = "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"deadline_ms\": 1}";
    let resp = request(handle.addr, "POST", "/v1/completions", body);
    assert_eq!(resp.status, 504, "{}", resp.body_str());
    let v = jsonx::parse(&resp.body_str()).expect("completion json");
    assert_eq!(v.req("finish_reason"), &Value::Str("deadline".into()));

    // streamed flavour: the terminator event carries the deadline marker
    let body = "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"deadline_ms\": 1, \
                \"stream\": true}";
    let resp = request(handle.addr, "POST", "/v1/completions", body);
    assert_eq!(resp.status, 200, "streams commit their status before the outcome");
    let events = sse_events(&resp.body_str());
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    let fin = jsonx::parse(&events[events.len() - 2]).expect("final event json");
    assert_eq!(fin.req("finish_reason"), &Value::Str("deadline".into()));
    assert!(handle.gauges.deadline_evictions.load(Ordering::Relaxed) >= 2);
    handle.shutdown();
    handle.join();
}

#[test]
fn mid_stream_disconnect_frees_the_slot() {
    // one batch slot: if the dropped stream's slot were not reclaimed, the
    // follow-up request could never decode
    let cfg = ServerConfig {
        queue_cap: 4,
        fault: FaultConfig { tick_delay_ms: 10, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(1, cfg);
    let addr = handle.addr;
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        send_request(
            &mut s,
            "POST",
            "/v1/completions",
            "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"stream\": true}",
        );
        // read a few streamed bytes to prove it was decoding, then vanish
        let mut buf = [0u8; 512];
        let n = s.read(&mut buf).expect("first streamed bytes");
        assert!(n > 0);
    } // socket dropped mid-stream
    wait_until("disconnect cancels the sequence", || {
        handle.gauges.cancelled.load(Ordering::Relaxed) >= 1
    });

    let resp = request(addr, "POST", "/v1/completions", "{\"prompt\": \"hi\", \"max_tokens\": 2}");
    assert_eq!(resp.status, 200, "slot was not reclaimed: {}", resp.body_str());
    handle.shutdown();
    handle.join();
}

/// Minimal Prometheus 0.0.4 sanity check: every sample line is
/// `name[{labels}] value` with a parseable float, every line belongs to a
/// family that declared a `# TYPE`, and cumulative buckets never decrease.
fn assert_prometheus_text(text: &str) {
    let mut typed: Vec<String> = Vec::new();
    let mut last_bucket: Option<(String, u64)> = None;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            typed.push(rest.split_whitespace().next().expect("family name").to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        assert!(
            value.parse::<f64>().is_ok() || value == "+Inf",
            "unparseable value in {line:?}"
        );
        let name = series.split('{').next().unwrap();
        let family = name
            .trim_end_matches("_bucket")
            .trim_end_matches("_sum")
            .trim_end_matches("_count");
        assert!(
            typed.iter().any(|t| t == family || t == name),
            "sample {name} has no # TYPE header"
        );
        // cumulative bucket monotonicity within one labelled series
        if name.ends_with("_bucket") {
            let key = series.split("le=").next().unwrap().to_string();
            let v: u64 = value.parse().expect("bucket counts are integers");
            if let Some((prev_key, prev_v)) = &last_bucket {
                if *prev_key == key {
                    assert!(v >= *prev_v, "bucket counts must be cumulative: {line:?}");
                }
            }
            last_bucket = Some((key, v));
        } else {
            last_bucket = None;
        }
    }
    assert!(!typed.is_empty(), "no metric families rendered");
}

/// One Prometheus sample value by exact series name (no labels).
fn prom_value(text: &str, series: &str) -> f64 {
    text.lines()
        .find(|l| l.split(' ').next() == Some(series))
        .unwrap_or_else(|| panic!("series {series} not found"))
        .rsplit(' ')
        .next()
        .unwrap()
        .parse()
        .expect("sample value")
}

#[test]
fn metrics_endpoint_serves_prometheus_after_completion() {
    let handle = spawn(2, quiet_cfg());
    let addr = handle.addr;

    // before any completion the endpoint already serves valid text
    let before = request(addr, "GET", "/metrics", "");
    assert_eq!(before.status, 200);
    assert!(
        before.header("content-type").unwrap_or("").starts_with("text/plain"),
        "prometheus scrapes expect text/plain"
    );
    assert_prometheus_text(&before.body_str());
    let requests_before = prom_value(&before.body_str(), "aq_http_requests_total");

    // a streamed completion populates TTFT and inter-token histograms
    let body = "{\"prompt\": \"the bani \", \"max_tokens\": 12, \"stream\": true}";
    let resp = request(addr, "POST", "/v1/completions", body);
    assert_eq!(resp.status, 200);

    let after = request(addr, "GET", "/metrics", "");
    let text = after.body_str();
    assert_prometheus_text(&text);
    assert!(
        prom_value(&text, "aq_http_requests_total") > requests_before,
        "counters must move"
    );
    assert!(prom_value(&text, "aq_ttft_seconds_count") >= 1.0, "TTFT observed:\n{text}");
    assert!(
        prom_value(&text, "aq_inter_token_seconds_count") >= 1.0,
        "inter-token gaps observed:\n{text}"
    );
    assert!(prom_value(&text, "aq_completed_total") >= 1.0);
    assert!(text.contains("aq_tick_seconds_bucket{phase=\"all\","), "phase series:\n{text}");

    // the journal endpoint is also live on a telemetry-on server
    assert_eq!(request(addr, "GET", "/v1/journal", "").status, 200);
    handle.shutdown();
    handle.join();
}

#[test]
fn trace_endpoint_and_request_id_echo() {
    let handle = spawn(2, quiet_cfg());
    let addr = handle.addr;

    // inbound X-Request-Id is honoured and echoed on the response
    let body = "{\"prompt\": \"the bani \", \"max_tokens\": 6}";
    let resp = request_with(addr, "POST", "/v1/completions", body, &[("X-Request-Id", "trace-me")]);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-request-id"), Some("trace-me"));
    let v = jsonx::parse(&resp.body_str()).expect("completion json");
    assert_eq!(v.req("request_id"), &Value::Str("trace-me".into()));

    // the span is addressable by that id and carries the request's life
    let trace = request(addr, "GET", "/v1/trace/trace-me", "");
    assert_eq!(trace.status, 200, "{}", trace.body_str());
    let t = jsonx::parse(&trace.body_str()).expect("trace json");
    assert_eq!(t.req("request_id"), &Value::Str("trace-me".into()));
    assert_eq!(t.req("outcome"), &Value::Str("max_new".into()));
    assert_eq!(t.req("tokens").as_f64(), 6.0);
    assert!(t.req("ttft_ms").as_f64() > 0.0);
    assert!(t.req("total_ms").as_f64() >= t.req("ttft_ms").as_f64());

    // without an inbound id the server mints one (req-<hex>)
    let resp = request(addr, "POST", "/v1/completions", body);
    let minted = resp.header("x-request-id").expect("generated id").to_string();
    assert!(minted.starts_with("req-"), "{minted}");
    assert_eq!(request(addr, "GET", &format!("/v1/trace/{minted}"), "").status, 200);

    assert_eq!(request(addr, "GET", "/v1/trace/no-such-trace", "").status, 404);
    handle.shutdown();
    handle.join();
}

#[test]
fn request_id_propagates_to_error_responses() {
    // 400: malformed payload still carries the inbound id, header and body
    let cfg = ServerConfig {
        queue_cap: 1,
        client_cap: 1,
        // slow ticks keep alice's stream alive while the shed happens
        fault: FaultConfig { tick_delay_ms: 20, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(1, cfg);
    let addr = handle.addr;
    let resp =
        request_with(addr, "POST", "/v1/completions", "not json", &[("X-Request-Id", "bad-1")]);
    assert_eq!(resp.status, 400);
    assert_eq!(resp.header("x-request-id"), Some("bad-1"));
    let v = jsonx::parse(&resp.body_str()).expect("error json");
    assert_eq!(v.req("request_id"), &Value::Str("bad-1".into()));

    // 429: hold the single per-client slot open, then get shed with the id
    let slow = "{\"prompt\": \"abcdef\", \"max_tokens\": 400, \"stream\": true, \
                \"client_id\": \"alice\"}";
    let mut s1 = TcpStream::connect(addr).expect("connect");
    send_request(&mut s1, "POST", "/v1/completions", slow);
    wait_until("alice admitted", || handle.gauges.active.load(Ordering::Relaxed) >= 1);
    let resp = request_with(addr, "POST", "/v1/completions", slow, &[("X-Request-Id", "shed-1")]);
    assert_eq!(resp.status, 429, "{}", resp.body_str());
    assert_eq!(resp.header("x-request-id"), Some("shed-1"));
    assert!(resp.header("retry-after").is_some(), "429 keeps Retry-After");
    let v = jsonx::parse(&resp.body_str()).expect("error json");
    assert_eq!(v.req("request_id"), &Value::Str("shed-1".into()));

    drop(s1);
    handle.shutdown();
    handle.join();
}

#[test]
fn telemetry_off_is_bit_identical_and_still_counts() {
    let offline = {
        let mut engine = test_engine(2);
        let reqs = Engine::byte_requests(&["the bani "], 8);
        let (c, _) = engine.generate(reqs, Sampler::Greedy, 0).expect("offline generate");
        c.into_iter().next().expect("one completion").tokens
    };

    let handle = spawn(2, ServerConfig { telemetry: false, ..quiet_cfg() });
    let addr = handle.addr;
    assert!(handle.telemetry.is_none());

    let body = "{\"prompt\": \"the bani \", \"max_tokens\": 8}";
    let resp = request(addr, "POST", "/v1/completions", body);
    assert_eq!(resp.status, 200);
    let v = jsonx::parse(&resp.body_str()).expect("completion json");
    let tokens: Vec<i32> = match v.req("tokens") {
        Value::Arr(a) => a.iter().map(|t| t.as_f64() as i32).collect(),
        other => panic!("tokens not an array: {other:?}"),
    };
    assert_eq!(tokens, offline, "telemetry off must not change sampled tokens");

    // counters still serve; histogram families are simply absent
    let m = request(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200);
    assert_prometheus_text(&m.body_str());
    assert!(prom_value(&m.body_str(), "aq_http_requests_total") >= 1.0);
    assert!(!m.body_str().contains("aq_ttft_seconds"), "no request histograms when off");
    // span/journal surfaces 404 rather than serving empty lies
    assert_eq!(request(addr, "GET", "/v1/trace/1", "").status, 404);
    assert_eq!(request(addr, "GET", "/v1/journal", "").status, 404);
    // stats JSON has no latency block
    let stats = jsonx::parse(&request(addr, "GET", "/v1/stats", "").body_str()).expect("stats");
    assert!(stats.get("latency").is_none());
    handle.shutdown();
    handle.join();
}

#[test]
fn numeric_health_endpoint_reports_layers_and_divergence() {
    let handle = Server::spawn(numeric_engine(2), quiet_cfg()).expect("spawn server");
    let addr = handle.addr;

    // long enough to cross the probe warm-up (first probe at decode tick 4)
    let body = "{\"prompt\": \"the bani \", \"max_tokens\": 24}";
    assert_eq!(request(addr, "POST", "/v1/completions", body).status, 200);

    let resp = request(addr, "GET", "/v1/health/numeric", "");
    assert_eq!(resp.status, 200, "{}", resp.body_str());
    let v = jsonx::parse(&resp.body_str()).expect("health json");
    let status = v.req("status").as_str();
    assert!(
        status == "ok" || status == "drifting",
        "calibrated engine must not report {status:?}"
    );
    let layers = match v.req("layers") {
        Value::Arr(a) => a,
        other => panic!("layers not an array: {other:?}"),
    };
    assert!(!layers.is_empty(), "baked envelopes must surface per-layer reports");
    for l in layers {
        let verdict = l.req("verdict").as_str();
        assert!(
            ["ok", "no_data", "drifting"].contains(&verdict),
            "unknown verdict {verdict:?}"
        );
        let baked = l.req("baked");
        assert!(baked.req("count").as_f64() > 0.0, "calibration envelope must be baked");
        assert!(baked.req("weight_mse").as_f64() > 0.0, "weight quant error is never zero");
        let live = l.req("live");
        let frac = live.req("outlier_frac").as_f64();
        assert!((0.0..=1.0).contains(&frac), "outlier_frac out of range: {frac}");
    }

    let div = v.req("divergence");
    assert_eq!(div.req("serve_bits").as_f64(), 4.0);
    assert_eq!(div.req("draft_bits").as_f64(), 2.0);
    assert!(div.req("probes").as_f64() >= 1.0, "probe must fire after warm-up");
    let pct = div.req("agree_pct").as_f64();
    assert!((0.0..=100.0).contains(&pct), "agree_pct out of range: {pct}");
    assert!(div.req("max_logit_delta").as_f64() >= 0.0);

    handle.shutdown();
    handle.join();
}

#[test]
fn numeric_metrics_families_serve_valid_prometheus() {
    let handle = Server::spawn(numeric_engine(2), quiet_cfg()).expect("spawn server");
    let addr = handle.addr;
    let body = "{\"prompt\": \"the bani \", \"max_tokens\": 24}";
    assert_eq!(request(addr, "POST", "/v1/completions", body).status, 200);

    let m = request(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200);
    let text = m.body_str();
    assert_prometheus_text(&text);
    assert!(prom_value(&text, "aq_numeric_sampled_rows_total") >= 1.0, "{text}");
    assert!(prom_value(&text, "aq_numeric_probes_total") >= 1.0, "{text}");
    assert!(prom_value(&text, "aq_numeric_drift_layers") >= 0.0);
    assert!(
        text.contains("aq_numeric_layer_drift{layer=\"0\"}"),
        "per-layer drift series missing:\n{text}"
    );
    assert!(text.contains("aq_numeric_layer_outlier_frac{layer=\"0\"}"));
    let agree = prom_value(&text, "aq_numeric_top1_agree_pct");
    assert!((0.0..=100.0).contains(&agree), "{agree}");
    handle.shutdown();
    handle.join();
}

#[test]
fn numeric_health_404_when_telemetry_off() {
    let cfg = ServerConfig { telemetry: false, ..quiet_cfg() };
    let handle = Server::spawn(numeric_engine(2), cfg).expect("spawn server");
    let addr = handle.addr;
    assert_eq!(request(addr, "GET", "/v1/health/numeric", "").status, 404);
    let m = request(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200);
    assert!(
        !m.body_str().contains("aq_numeric_"),
        "numeric families only exist with telemetry on"
    );
    handle.shutdown();
    handle.join();
}

#[test]
fn kv_page_pool_exhaustion_sheds_429_with_retry_after_and_recovers() {
    // opt-s1 window 128, 16-token pages, prefill chunk 16: a 100-token
    // prompt with max_tokens 150 prices at min(250, 127 + 16) = 143 peak
    // tokens -> ceil(143/16) + 1 = 10 pages. An 11-page budget leaves one
    // free, so any follow-up (2 pages minimum) must shed — 429 with
    // Retry-After, no panic, no queue growth.
    let cfg = ServerConfig {
        kv_pages: 11,
        kv_page_tokens: 16,
        queue_cap: 4,
        retry_after_s: 3,
        fault: FaultConfig { tick_delay_ms: 20, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(2, cfg);
    let addr = handle.addr;
    let long_prompt = "x".repeat(100);
    let slow = format!("{{\"prompt\": \"{long_prompt}\", \"max_tokens\": 150, \"stream\": true}}");
    let mut s1 = TcpStream::connect(addr).expect("connect");
    send_request(&mut s1, "POST", "/v1/completions", &slow);
    let _ = read_streamed_until(&mut s1, "data: ");

    let small = "{\"prompt\": \"abcdef\", \"max_tokens\": 8}";
    let resp = request(addr, "POST", "/v1/completions", small);
    assert_eq!(resp.status, 429, "{}", resp.body_str());
    assert!(resp.header("retry-after").is_some(), "page shed must carry Retry-After");
    assert!(resp.body_str().contains("page"), "error names the page pool: {}", resp.body_str());

    let stats = jsonx::parse(&request(addr, "GET", "/v1/stats", "").body_str()).expect("stats");
    assert!(stats.req("admission").req("shed_pages").as_f64() >= 1.0);
    assert_eq!(stats.req("kv").req("kv_page_budget").as_f64(), 11.0);

    // dropping the hog releases its reservation and the pool recovers
    drop(s1);
    wait_until("page reservation released", || {
        let body = request(addr, "GET", "/v1/stats", "").body_str();
        jsonx::parse(&body).expect("stats").req("kv").req("kv_pages_reserved").as_f64() == 0.0
    });
    let resp = request(addr, "POST", "/v1/completions", small);
    assert_eq!(resp.status, 200, "pool must recover after release: {}", resp.body_str());
    handle.shutdown();
    handle.join();
}

#[test]
fn shared_prompt_two_clients_share_pages_and_match_greedy() {
    let prompt = "system: you are a terse assistant. user: say hi. ";
    let offline = {
        let mut engine = test_engine(2);
        let reqs = Engine::byte_requests(&[prompt], 12);
        let (c, _) = engine.generate(reqs, Sampler::Greedy, 0).expect("offline generate");
        c.into_iter().next().expect("one completion").tokens
    };

    let cfg = ServerConfig {
        kv_page_tokens: 4,
        fault: FaultConfig { tick_delay_ms: 10, ..FaultConfig::default() },
        ..quiet_cfg()
    };
    let handle = spawn(2, cfg);
    let addr = handle.addr;

    // first client streams long enough to stay live throughout
    let a_body = format!("{{\"prompt\": \"{prompt}\", \"max_tokens\": 40, \"stream\": true}}");
    let mut a = TcpStream::connect(addr).expect("connect");
    send_request(&mut a, "POST", "/v1/completions", &a_body);
    let a_head = read_streamed_until(&mut a, "data: ");

    // second client, same prompt: admission attaches the prefix pages the
    // first client's prefill registered instead of recomputing them
    let b_body = format!("{{\"prompt\": \"{prompt}\", \"max_tokens\": 12, \"stream\": true}}");
    let mut b = TcpStream::connect(addr).expect("connect");
    send_request(&mut b, "POST", "/v1/completions", &b_body);
    let b_head = read_streamed_until(&mut b, "data: ");

    // while both sequences are live they reference the same prompt pages
    wait_until("shared kv pages visible in /v1/stats", || {
        let body = request(addr, "GET", "/v1/stats", "").body_str();
        let stats = jsonx::parse(&body).expect("stats json");
        stats.req("kv").req("kv_pages_shared").as_f64() > 0.0
    });

    let a_tokens = stream_tokens(a, a_head);
    let b_tokens = stream_tokens(b, b_head);
    assert_eq!(b_tokens, offline, "shared-prefix client must stay bit-identical to offline");
    assert_eq!(&a_tokens[..12], &offline[..], "donor's greedy prefix must match offline");

    let stats = jsonx::parse(&request(addr, "GET", "/v1/stats", "").body_str()).expect("stats");
    assert!(stats.req("kv").req("kv_prefix_hits").as_f64() >= 1.0, "attach must be counted");
    handle.shutdown();
    handle.join();
}

#[test]
fn admin_shutdown_drains_gracefully() {
    let handle = spawn(2, quiet_cfg());
    let addr = handle.addr;
    let ok = request(addr, "POST", "/v1/completions", "{\"prompt\": \"hi\", \"max_tokens\": 2}");
    assert_eq!(ok.status, 200);
    let resp = request(addr, "POST", "/admin/shutdown", "");
    assert_eq!(resp.status, 202);
    // every thread (accept, workers, engine) must exit on its own
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        handle.join();
        let _ = tx.send(());
    });
    rx.recv_timeout(Duration::from_secs(20)).expect("drain must complete");
    // fresh connections are refused once the listener is gone
    wait_until("listener closed", || TcpStream::connect(addr).is_err());
}

/// Smoke check for the GEMM dispatch observability surfaces: `/v1/stats`
/// carries the `kernel` block (name + a known variant + the available
/// list) and `/metrics` exports the `aq_kernel_info` gauge. Named with the
/// `kernel_` prefix so `scripts/ci.sh` can run it as a targeted smoke.
#[test]
fn kernel_stats_and_metric_report_dispatch() {
    let handle = spawn(2, quiet_cfg());
    let addr = handle.addr;

    let stats = jsonx::parse(&request(addr, "GET", "/v1/stats", "").body_str()).expect("stats");
    let k = stats.req("kernel");
    let name = k.req("name").as_str();
    let variant = k.req("variant").as_str();
    assert!(!name.is_empty(), "kernel.name must be populated");
    assert!(
        name.starts_with(&format!("{variant}/")),
        "kernel name {name:?} must be namespaced under the variant {variant:?}"
    );
    assert!(
        ["scalar", "avx2", "avx512", "neon"].contains(&variant),
        "unknown kernel variant {variant:?}"
    );
    let available = match k.req("available") {
        Value::Arr(a) => a.iter().map(|v| v.as_str().to_string()).collect::<Vec<_>>(),
        other => panic!("kernel.available not an array: {other:?}"),
    };
    assert!(
        available.iter().any(|v| v == "scalar"),
        "scalar must always be available (got {available:?})"
    );

    let m = request(addr, "GET", "/metrics", "");
    assert_eq!(m.status, 200);
    assert_prometheus_text(&m.body_str());
    let needle = format!("aq_kernel_info{{variant=\"{variant}\"");
    assert!(
        m.body_str().contains(&needle),
        "metrics must export aq_kernel_info for {variant:?}"
    );

    handle.shutdown();
    handle.join();
}
