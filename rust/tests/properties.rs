//! Property tests over the coordinator invariants (DESIGN.md §7) using the
//! in-repo mini property harness (proptest is not vendored offline).

use affinequant::coordinator::mask::MaskSchedule;
use affinequant::coordinator::stability;
use affinequant::linalg::{gj_inverse_nopivot, inverse, inverse_residual, sdd_margin};
use affinequant::prop_assert;
use affinequant::proptestx::Runner;
use affinequant::quant::{pack_bits, quant_dequant, quantize_codes, unpack_bits, QuantSpec};
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

fn random_sdd(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut a: Vec<f32> = (0..n * n).map(|_| (rng.normal() as f32) / n as f32).collect();
    for i in 0..n {
        let off: f32 = (0..n).filter(|&j| j != i).map(|j| a[i * n + j].abs()).sum();
        a[i * n + i] = (1.0 + rng.uniform() as f32) * (off + 0.05);
    }
    a
}

/// SDD matrices are invertible — both LU and the in-graph Gauss-Jordan.
#[test]
fn prop_sdd_matrices_invert() {
    Runner { cases: 40, ..Default::default() }.run(
        "A @ inv(A) ≈ I for SDD",
        |rng| {
            let n = 2 + rng.below(24);
            random_sdd(rng, n)
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() {
                return Ok(()); // shrunk to non-square, skip
            }
            let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
            let lu = inverse(&a64, n).ok_or("LU failed on SDD")?;
            prop_assert!(inverse_residual(&a64, &lu, n) < 1e-8, "LU residual too big");
            let gj = gj_inverse_nopivot(&a64, n).ok_or("GJ failed on SDD")?;
            prop_assert!(inverse_residual(&a64, &gj, n) < 1e-8, "GJ residual too big");
            Ok(())
        },
    );
}

/// Gradual-mask damping never breaks strict diagonal dominance of a
/// diagonally-initialized matrix when off-diagonals are small (Theorem 1
/// regime) — and the mask never enables entries outside the band.
#[test]
fn prop_masked_matrix_stays_sdd() {
    Runner { cases: 40, ..Default::default() }.run(
        "masked A stays SDD for small alpha",
        |rng| {
            let n = 4 + rng.below(16);
            // raw A: big diagonal + arbitrary off-diagonal noise
            let mut a: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32 * 0.5).collect();
            for i in 0..n {
                a[i * n + i] = 1.0 + rng.uniform() as f32;
            }
            a
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() || n < 2 {
                return Ok(());
            }
            // alpha below 1/(n·max_off/min_diag) guarantees SDD of A∘GM
            let max_off = a
                .iter()
                .enumerate()
                .filter(|(i, _)| i / n != i % n)
                .map(|(_, v)| v.abs())
                .fold(0.0f32, f32::max);
            let min_diag =
                (0..n).map(|i| a[i * n + i].abs()).fold(f32::INFINITY, f32::min);
            let alpha = 0.9 * min_diag / ((n as f32) * max_off.max(1e-6));
            let sched = MaskSchedule { alpha, epochs: 10, full_affine: true, gradual: true };
            for e in 1..=10 {
                let mut m = vec![0.0f32; n * n];
                sched.fill_square(e, n, &mut m);
                let masked: Vec<f32> = a.iter().zip(&m).map(|(x, y)| x * y).collect();
                prop_assert!(
                    sdd_margin(&masked, n) > 0.0,
                    "masked matrix lost SDD at epoch {e} (alpha {alpha})"
                );
            }
            Ok(())
        },
    );
}

/// SDD projection restores a positive margin without touching the diagonal.
#[test]
fn prop_projection_restores_sdd() {
    Runner { cases: 40, ..Default::default() }.run(
        "project_sdd restores margin",
        |rng| {
            let n = 3 + rng.below(12);
            (0..n * n).map(|_| rng.normal() as f32).collect::<Vec<f32>>()
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() || n < 2 {
                return Ok(());
            }
            let mut b = a.clone();
            // force nonzero diagonal so a positive margin is achievable
            for i in 0..n {
                if b[i * n + i].abs() < 0.1 {
                    b[i * n + i] = 0.5;
                }
            }
            let before_diag: Vec<f32> = (0..n).map(|i| b[i * n + i]).collect();
            stability::project_sdd(&mut b, n, 0.01);
            prop_assert!(sdd_margin(&b, n) >= 0.009, "margin {}", sdd_margin(&b, n));
            for i in 0..n {
                prop_assert!(b[i * n + i] == before_diag[i], "diagonal changed");
            }
            Ok(())
        },
    );
}

/// Quantize-dequantize error is bounded by scale/2 and idempotent;
/// bit-packing round-trips for every bit width.
#[test]
fn prop_quant_roundtrips() {
    Runner { cases: 30, ..Default::default() }.run(
        "quant-dequant invariants",
        |rng| {
            let din = [32usize, 64, 128][rng.below(3)];
            let dout = 8 + rng.below(24);
            let mut v = rng.normal_vec(din * dout, 1.0);
            v.push(din as f32); // smuggle dims through the Vec<f32> case
            v.push(dout as f32);
            v
        },
        |v| {
            if v.len() < 3 {
                return Ok(());
            }
            let dout = v[v.len() - 1] as usize;
            let din = v[v.len() - 2] as usize;
            if din * dout + 2 != v.len() || din % 32 != 0 {
                return Ok(());
            }
            let w = Tensor::new(vec![din, dout], v[..din * dout].to_vec());
            for (bits, group) in [(2u32, 0usize), (3, 32), (4, 0), (8, 32)] {
                let spec = QuantSpec::new(bits, group);
                let (codes, params, shape) = quantize_codes(&w, spec, None);
                prop_assert!(
                    codes.iter().all(|&c| (c as u64) < (1 << bits)),
                    "code out of range at {bits} bits"
                );
                let dq = affinequant::quant::dequantize_codes(&codes, &params, &shape, spec);
                let g = spec.group_len(din);
                for i in 0..din {
                    for j in 0..dout {
                        let p = params[(i / g) * dout + j];
                        let err = (dq.at2(i, j) - w.at2(i, j)).abs();
                        prop_assert!(
                            err <= p.scale / 2.0 + 1e-5,
                            "error {err} > scale/2 {}",
                            p.scale / 2.0
                        );
                    }
                }
                // idempotence
                let dq2 = quant_dequant(&dq, spec, None);
                prop_assert!(dq.mse(&dq2) < 1e-10, "not idempotent");
                // packing round-trip
                let packed = pack_bits(&codes, bits);
                prop_assert!(
                    unpack_bits(&packed, bits, codes.len()) == codes,
                    "pack/unpack mismatch at {bits} bits"
                );
            }
            Ok(())
        },
    );
}

/// Merge equivalence: with near-infinite bits (8-bit is enough at these
/// magnitudes), W_eval = A⁻¹·QDQ(A·W) returns to W; the out-site per-head
/// fold composes back to the identity through (wv·A⁻¹)·(A·wo).
#[test]
fn prop_merge_identity_high_bits() {
    use affinequant::model::merge::{inverse_prec, mm_prec, MergePrecision};
    Runner { cases: 20, ..Default::default() }.run(
        "A⁻¹ QDQ(A W) ≈ W at high bits",
        |rng| {
            let n = 8 + 4 * rng.below(8);
            let mut v = random_sdd(rng, n);
            v.extend(rng.normal_vec(n * n, 0.05));
            v
        },
        |v| {
            let n = ((v.len() / 2) as f64).sqrt() as usize;
            if 2 * n * n != v.len() || n < 2 {
                return Ok(());
            }
            let a = Tensor::new(vec![n, n], v[..n * n].to_vec());
            let w = Tensor::new(vec![n, n], v[n * n..].to_vec());
            let prec = MergePrecision::F32InvF64;
            let aw = mm_prec(&a, &w, prec);
            let q = quant_dequant(&aw, QuantSpec::new(8, 0), None);
            let back = mm_prec(&inverse_prec(&a, prec), &q, prec);
            let err = back.sub(&w).max_abs();
            prop_assert!(err < 0.05, "round-trip error {err}");
            Ok(())
        },
    );
}
