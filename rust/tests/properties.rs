//! Property tests over the coordinator invariants (DESIGN.md §7) using the
//! in-repo mini property harness (proptest is not vendored offline).

use affinequant::coordinator::mask::MaskSchedule;
use affinequant::coordinator::stability;
use affinequant::engine::kv::{KvCache, KvConfig};
use affinequant::linalg::{gj_inverse_nopivot, inverse, inverse_residual, sdd_margin};
use affinequant::prop_assert;
use affinequant::proptestx::Runner;
use affinequant::quant::{pack_bits, quant_dequant, quantize_codes, unpack_bits, QuantSpec};
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

fn random_sdd(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let mut a: Vec<f32> = (0..n * n).map(|_| (rng.normal() as f32) / n as f32).collect();
    for i in 0..n {
        let off: f32 = (0..n).filter(|&j| j != i).map(|j| a[i * n + j].abs()).sum();
        a[i * n + i] = (1.0 + rng.uniform() as f32) * (off + 0.05);
    }
    a
}

/// SDD matrices are invertible — both LU and the in-graph Gauss-Jordan.
#[test]
fn prop_sdd_matrices_invert() {
    Runner { cases: 40, ..Default::default() }.run(
        "A @ inv(A) ≈ I for SDD",
        |rng| {
            let n = 2 + rng.below(24);
            random_sdd(rng, n)
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() {
                return Ok(()); // shrunk to non-square, skip
            }
            let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
            let lu = inverse(&a64, n).ok_or("LU failed on SDD")?;
            prop_assert!(inverse_residual(&a64, &lu, n) < 1e-8, "LU residual too big");
            let gj = gj_inverse_nopivot(&a64, n).ok_or("GJ failed on SDD")?;
            prop_assert!(inverse_residual(&a64, &gj, n) < 1e-8, "GJ residual too big");
            Ok(())
        },
    );
}

/// Gradual-mask damping never breaks strict diagonal dominance of a
/// diagonally-initialized matrix when off-diagonals are small (Theorem 1
/// regime) — and the mask never enables entries outside the band.
#[test]
fn prop_masked_matrix_stays_sdd() {
    Runner { cases: 40, ..Default::default() }.run(
        "masked A stays SDD for small alpha",
        |rng| {
            let n = 4 + rng.below(16);
            // raw A: big diagonal + arbitrary off-diagonal noise
            let mut a: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32 * 0.5).collect();
            for i in 0..n {
                a[i * n + i] = 1.0 + rng.uniform() as f32;
            }
            a
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() || n < 2 {
                return Ok(());
            }
            // alpha below 1/(n·max_off/min_diag) guarantees SDD of A∘GM
            let max_off = a
                .iter()
                .enumerate()
                .filter(|(i, _)| i / n != i % n)
                .map(|(_, v)| v.abs())
                .fold(0.0f32, f32::max);
            let min_diag =
                (0..n).map(|i| a[i * n + i].abs()).fold(f32::INFINITY, f32::min);
            let alpha = 0.9 * min_diag / ((n as f32) * max_off.max(1e-6));
            let sched = MaskSchedule { alpha, epochs: 10, full_affine: true, gradual: true };
            for e in 1..=10 {
                let mut m = vec![0.0f32; n * n];
                sched.fill_square(e, n, &mut m);
                let masked: Vec<f32> = a.iter().zip(&m).map(|(x, y)| x * y).collect();
                prop_assert!(
                    sdd_margin(&masked, n) > 0.0,
                    "masked matrix lost SDD at epoch {e} (alpha {alpha})"
                );
            }
            Ok(())
        },
    );
}

/// SDD projection restores a positive margin without touching the diagonal.
#[test]
fn prop_projection_restores_sdd() {
    Runner { cases: 40, ..Default::default() }.run(
        "project_sdd restores margin",
        |rng| {
            let n = 3 + rng.below(12);
            (0..n * n).map(|_| rng.normal() as f32).collect::<Vec<f32>>()
        },
        |a| {
            let n = (a.len() as f64).sqrt() as usize;
            if n * n != a.len() || n < 2 {
                return Ok(());
            }
            let mut b = a.clone();
            // force nonzero diagonal so a positive margin is achievable
            for i in 0..n {
                if b[i * n + i].abs() < 0.1 {
                    b[i * n + i] = 0.5;
                }
            }
            let before_diag: Vec<f32> = (0..n).map(|i| b[i * n + i]).collect();
            stability::project_sdd(&mut b, n, 0.01);
            prop_assert!(sdd_margin(&b, n) >= 0.009, "margin {}", sdd_margin(&b, n));
            for i in 0..n {
                prop_assert!(b[i * n + i] == before_diag[i], "diagonal changed");
            }
            Ok(())
        },
    );
}

/// Quantize-dequantize error is bounded by scale/2 and idempotent;
/// bit-packing round-trips for every bit width.
#[test]
fn prop_quant_roundtrips() {
    Runner { cases: 30, ..Default::default() }.run(
        "quant-dequant invariants",
        |rng| {
            let din = [32usize, 64, 128][rng.below(3)];
            let dout = 8 + rng.below(24);
            let mut v = rng.normal_vec(din * dout, 1.0);
            v.push(din as f32); // smuggle dims through the Vec<f32> case
            v.push(dout as f32);
            v
        },
        |v| {
            if v.len() < 3 {
                return Ok(());
            }
            let dout = v[v.len() - 1] as usize;
            let din = v[v.len() - 2] as usize;
            if din * dout + 2 != v.len() || din % 32 != 0 {
                return Ok(());
            }
            let w = Tensor::new(vec![din, dout], v[..din * dout].to_vec());
            for (bits, group) in [(2u32, 0usize), (3, 32), (4, 0), (8, 32)] {
                let spec = QuantSpec::new(bits, group);
                let (codes, params, shape) = quantize_codes(&w, spec, None);
                prop_assert!(
                    codes.iter().all(|&c| (c as u64) < (1 << bits)),
                    "code out of range at {bits} bits"
                );
                let dq = affinequant::quant::dequantize_codes(&codes, &params, &shape, spec);
                let g = spec.group_len(din);
                for i in 0..din {
                    for j in 0..dout {
                        let p = params[(i / g) * dout + j];
                        let err = (dq.at2(i, j) - w.at2(i, j)).abs();
                        prop_assert!(
                            err <= p.scale / 2.0 + 1e-5,
                            "error {err} > scale/2 {}",
                            p.scale / 2.0
                        );
                    }
                }
                // idempotence
                let dq2 = quant_dequant(&dq, spec, None);
                prop_assert!(dq.mse(&dq2) < 1e-10, "not idempotent");
                // packing round-trip
                let packed = pack_bits(&codes, bits);
                prop_assert!(
                    unpack_bits(&packed, bits, codes.len()) == codes,
                    "pack/unpack mismatch at {bits} bits"
                );
            }
            Ok(())
        },
    );
}

/// A sequence's token at `pos`: its prompt, then a slot-salted tail
/// standing in for sampled tokens (never registered for sharing).
fn token_at(prompt: &[i32], salt: i32, pos: usize) -> i32 {
    if pos < prompt.len() {
        prompt[pos]
    } else {
        1000 + salt + pos as i32
    }
}

/// Paged-KV bookkeeping survives random admit / chunked-advance / cancel
/// interleavings over a family of prefix-sharing prompts: no double free
/// (every page's refcount matches its table references, validated after
/// every op), shared rows always read back the donor's bytes, and
/// resetting every slot at the end drains all refcounts to zero.
#[test]
fn prop_paged_kv_interleavings_never_corrupt() {
    Runner { cases: 60, ..Default::default() }.run(
        "paged kv random interleavings",
        |rng| rng.below(1 << 30),
        |&seed| {
            let mut rng = Pcg32::seeded(seed as u64 ^ 0x9e37_79b9);
            let page_tokens = 1 + rng.below(4);
            let window = 2 + rng.below(7);
            let n_slots = 3usize;
            let mut c = KvCache::with_options(
                n_slots,
                2,
                window,
                2,
                KvConfig { page_tokens, ..KvConfig::default() },
            );
            // family of prompts sharing a common base prefix
            let base: Vec<i32> = (0..10).map(|_| rng.below(5) as i32 + 1).collect();
            let mut prompts: Vec<Vec<i32>> = Vec::new();
            for _ in 0..n_slots {
                let keep = 2 + rng.below(base.len() - 1);
                let mut p = base[..keep].to_vec();
                for _ in 0..rng.below(4) {
                    p.push(rng.below(5) as i32 + 1);
                }
                prompts.push(p);
            }
            // per-slot live state: (prompt index, tokens appended, tail salt)
            let mut live: Vec<Option<(usize, usize, i32)>> = vec![None; n_slots];
            for _op in 0..48 {
                let slot = rng.below(n_slots);
                let cancel_roll = rng.below(4) == 0;
                match live[slot] {
                    None => {
                        // admit: attach whatever prefix is already shared
                        c.reset(slot);
                        let pi = rng.below(prompts.len());
                        let shared = c.attach_prefix(slot, &prompts[pi]);
                        prop_assert!(
                            shared < prompts[pi].len(),
                            "attach returned {shared} for a {}-token prompt",
                            prompts[pi].len()
                        );
                        let salt = rng.below(100) as i32;
                        live[slot] = Some((pi, shared, salt));
                    }
                    Some(_) if cancel_roll => {
                        // cancel / evict mid-flight
                        c.reset(slot);
                        live[slot] = None;
                    }
                    Some((pi, fed, salt)) => {
                        // one scheduler step: trim once, then a chunk of rows
                        let chunk = 1 + rng.below(3);
                        c.trim(slot);
                        for t in 0..chunk {
                            let pos = c.advance(slot);
                            prop_assert!(
                                pos == fed + t,
                                "advance returned {pos}, expected {}",
                                fed + t
                            );
                            let tok = token_at(&prompts[pi], salt, pos);
                            for layer in 0..c.n_layers {
                                c.write_k(slot, layer, pos, &[tok as f32, pos as f32]);
                                c.write_v(slot, layer, pos, &[pos as f32, tok as f32]);
                            }
                        }
                        let fed = fed + chunk;
                        let reg = fed.min(prompts[pi].len());
                        c.register_prefix(slot, &prompts[pi][..reg]);
                        live[slot] = Some((pi, fed, salt));
                        // the attention window must read back exactly this
                        // sequence's tokens — including rows served from
                        // shared pages
                        let len = c.len(slot);
                        for pos in len - c.attn_len(slot)..len {
                            let want = token_at(&prompts[pi], salt, pos) as f32;
                            let got = c.k_row(slot, 0, pos)[0];
                            prop_assert!(got == want, "slot {slot} pos {pos}: k {got} != {want}");
                        }
                    }
                }
                c.debug_validate()?;
            }
            // drain: resetting every slot returns all refcounts to zero
            for slot in 0..n_slots {
                c.reset(slot);
            }
            c.debug_validate()?;
            let st = c.stats();
            prop_assert!(st.pages_resident == 0, "{} pages resident after drain", st.pages_resident);
            prop_assert!(st.pages_shared == 0 && st.shared_bytes == 0, "sharing after drain");
            Ok(())
        },
    );
}

/// Attaching a prompt that diverges from a registered donor prefix at a
/// fuzzed position costs exactly one copy-on-write when the divergence
/// lands mid-page (zero at a page boundary), never touches the donor's
/// rows, and never fires again for subsequent appends into the owned tail.
#[test]
fn prop_fuzzed_divergence_is_exactly_one_cow() {
    Runner { cases: 80, ..Default::default() }.run(
        "divergence => exactly one CoW",
        |rng| rng.below(1 << 30),
        |&seed| {
            let mut rng = Pcg32::seeded(seed as u64 ^ 0x517c_c1b7);
            let page_tokens = 1 + rng.below(4);
            let mut c = KvCache::with_options(
                2,
                2,
                64,
                2,
                KvConfig { page_tokens, ..KvConfig::default() },
            );
            let len = 2 + rng.below(15);
            let donor: Vec<i32> = (0..len).map(|_| rng.below(6) as i32 + 1).collect();
            for (pos, &tok) in donor.iter().enumerate() {
                c.trim(0);
                let p = c.advance(0);
                prop_assert!(p == pos, "donor advance desync at {pos}");
                for layer in 0..c.n_layers {
                    c.write_k(0, layer, pos, &[tok as f32, pos as f32]);
                    c.write_v(0, layer, pos, &[pos as f32, tok as f32]);
                }
            }
            c.register_prefix(0, &donor);

            // the attacher shares j tokens, then diverges
            let j = 1 + rng.below(len);
            let mut attacher = donor[..j].to_vec();
            let diff = donor.get(j).map_or(7, |&t| t + 1); // != donor[j]
            attacher.push(diff);
            let shared = c.attach_prefix(1, &attacher);
            prop_assert!(shared == j, "shared {shared}, expected {j}");

            let before = c.stats().cow_faults;
            let pos = c.advance(1);
            prop_assert!(pos == j, "attacher position {pos}, expected {j}");
            for layer in 0..c.n_layers {
                c.write_k(1, layer, pos, &[diff as f32, pos as f32]);
                c.write_v(1, layer, pos, &[pos as f32, diff as f32]);
            }
            let expected: u64 = if j % page_tokens == 0 { 0 } else { 1 };
            let delta = c.stats().cow_faults - before;
            prop_assert!(
                delta == expected,
                "divergence at {j} over {page_tokens}-token pages cost {delta} CoW, expected {expected}"
            );
            // donor rows untouched; CoW carried the rows below the
            // divergence point over to the attacher
            for p in 0..j {
                prop_assert!(c.k_row(0, 0, p)[0] == donor[p] as f32, "donor row {p} corrupted");
                prop_assert!(
                    c.k_row(1, 0, p)[0] == donor[p] as f32,
                    "attacher lost shared row {p}"
                );
            }
            if j < donor.len() {
                prop_assert!(
                    c.k_row(0, 0, j)[0] == donor[j] as f32,
                    "donor divergence row corrupted"
                );
            }
            prop_assert!(c.k_row(1, 0, j)[0] == diff as f32, "attacher divergence row missing");
            // appending into the now-owned tail never CoWs again
            for _ in 0..page_tokens {
                let p = c.advance(1);
                for layer in 0..c.n_layers {
                    c.write_k(1, layer, p, &[0.0, 0.0]);
                    c.write_v(1, layer, p, &[0.0, 0.0]);
                }
            }
            prop_assert!(
                c.stats().cow_faults - before == expected,
                "extra CoW on owned-tail appends"
            );
            c.debug_validate()?;
            Ok(())
        },
    );
}

/// Merge equivalence: with near-infinite bits (8-bit is enough at these
/// magnitudes), W_eval = A⁻¹·QDQ(A·W) returns to W; the out-site per-head
/// fold composes back to the identity through (wv·A⁻¹)·(A·wo).
#[test]
fn prop_merge_identity_high_bits() {
    use affinequant::model::merge::{inverse_prec, mm_prec, MergePrecision};
    Runner { cases: 20, ..Default::default() }.run(
        "A⁻¹ QDQ(A W) ≈ W at high bits",
        |rng| {
            let n = 8 + 4 * rng.below(8);
            let mut v = random_sdd(rng, n);
            v.extend(rng.normal_vec(n * n, 0.05));
            v
        },
        |v| {
            let n = ((v.len() / 2) as f64).sqrt() as usize;
            if 2 * n * n != v.len() || n < 2 {
                return Ok(());
            }
            let a = Tensor::new(vec![n, n], v[..n * n].to_vec());
            let w = Tensor::new(vec![n, n], v[n * n..].to_vec());
            let prec = MergePrecision::F32InvF64;
            let aw = mm_prec(&a, &w, prec);
            let q = quant_dequant(&aw, QuantSpec::new(8, 0), None);
            let back = mm_prec(&inverse_prec(&a, prec), &q, prec);
            let err = back.sub(&w).max_abs();
            prop_assert!(err < 0.05, "round-trip error {err}");
            Ok(())
        },
    );
}
