//! Host-side dense f32 tensor substrate.
//!
//! Row-major, owned storage. 2-D matmuls are cache-blocked over `k` and
//! parallelized over row chunks with scoped threads — these carry the
//! host-side hot paths (GPTQ, merging, statistics); the model forward runs
//! inside XLA, not here.

use crate::rngx::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape {shape:?} vs {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn randn(shape: &[usize], scale: f32, rng: &mut Pcg32) -> Self {
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(numel(shape), scale) }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "dims2 on shape {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let (_, c) = self.dims2();
        self.data[i * c + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.dims2();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (_, c) = self.dims2();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(numel(shape), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn transpose2(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) -> &mut Self {
        for v in &mut self.data {
            *v *= s;
        }
        self
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn mse(&self, other: &Tensor) -> f64 {
        self.sub(other).frob_sq() / self.numel() as f64
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Mean of |x| per column of a 2-D tensor.
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let (r, c) = self.dims2();
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for (j, o) in out.iter_mut().enumerate() {
                *o += self.data[i * c + j].abs();
            }
        }
        for o in &mut out {
            *o /= r as f32;
        }
        out
    }

    /// Max of |x| per column of a 2-D tensor.
    pub fn col_abs_max(&self) -> Vec<f32> {
        let (r, c) = self.dims2();
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for (j, o) in out.iter_mut().enumerate() {
                *o = o.max(self.data[i * c + j].abs());
            }
        }
        out
    }

    /// Per-column (min, max) of a 2-D tensor.
    pub fn col_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let (r, c) = self.dims2();
        let mut mn = vec![f32::INFINITY; c];
        let mut mx = vec![f32::NEG_INFINITY; c];
        for i in 0..r {
            for j in 0..c {
                let v = self.data[i * c + j];
                mn[j] = mn[j].min(v);
                mx[j] = mx[j].max(v);
            }
        }
        (mn, mx)
    }

    /// self (m,k) @ other (k,n) -> (m,n), parallel over row chunks.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = other.dims2();
        assert_eq!(k, k2, "matmul {:?} x {:?}", self.shape, other.shape);
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// selfᵀ (k,m)ᵀ @ other (k,n) -> (m,n) without materializing selfᵀ.
    pub fn matmul_at(&self, other: &Tensor) -> Tensor {
        let (k, m) = self.dims2();
        let (k2, n) = other.dims2();
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[m, n]);
        // out[i,j] = sum_t self[t,i] * other[t,j]
        for t in 0..k {
            let a_row = &self.data[t * m..(t + 1) * m];
            let b_row = &other.data[t * n..(t + 1) * n];
            for (i, &a) in a_row.iter().enumerate() {
                if a != 0.0 {
                    let o = &mut out.data[i * n..(i + 1) * n];
                    for (j, &b) in b_row.iter().enumerate() {
                        o[j] += a * b;
                    }
                }
            }
        }
        out
    }
}

/// Blocked, thread-parallel C = A (m,k) @ B (k,n), all row-major slices.
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let threads = num_threads().min(m.max(1));
    if threads <= 1 || m * k * n < 64 * 64 * 64 {
        matmul_rows(a, b, c, k, n, 0);
        return;
    }
    let chunk = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ti, c_chunk) in c.chunks_mut(chunk * n).enumerate() {
            let row0 = ti * chunk;
            let rows = c_chunk.len() / n;
            let a_chunk = &a[row0 * k..row0 * k + rows * k];
            scope.spawn(move || matmul_rows(a_chunk, b, c_chunk, k, n, 0));
        }
    });
}

/// Serial ikj kernel over a row slab (vectorizes along n).
fn matmul_rows(a: &[f32], b: &[f32], c: &mut [f32], k: usize, n: usize, _row0: usize) {
    let m = c.len() / n;
    for i in 0..m {
        let a_row = &a[i * k..(i + 1) * k];
        let c_row = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av != 0.0 {
                let b_row = &b[kk * n..(kk + 1) * n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += av * bv;
                }
            }
        }
    }
}

pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_parallel_matches_serial() {
        let mut rng = Pcg32::seeded(1);
        let a = Tensor::randn(&[200, 96], 1.0, &mut rng);
        let b = Tensor::randn(&[96, 130], 1.0, &mut rng);
        let big = a.matmul(&b);
        // reference: naive triple loop
        let mut want = Tensor::zeros(&[200, 130]);
        for i in 0..200 {
            for j in 0..130 {
                let mut s = 0.0f32;
                for t in 0..96 {
                    s += a.data[i * 96 + t] * b.data[t * 130 + j];
                }
                want.data[i * 130 + j] = s;
            }
        }
        assert!(big.sub(&want).max_abs() < 1e-3);
    }

    #[test]
    fn matmul_at_matches_transpose() {
        let mut rng = Pcg32::seeded(2);
        let a = Tensor::randn(&[64, 32], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 48], 1.0, &mut rng);
        let got = a.matmul_at(&b);
        let want = a.transpose2().matmul(&b);
        assert!(got.sub(&want).max_abs() < 1e-3);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(3);
        let a = Tensor::randn(&[17, 29], 1.0, &mut rng);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut rng = Pcg32::seeded(4);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let c = a.matmul(&Tensor::eye(16));
        assert!(c.sub(&a).max_abs() < 1e-6);
    }

    #[test]
    fn col_stats() {
        let a = Tensor::new(vec![2, 2], vec![1., -4., -3., 2.]);
        assert_eq!(a.col_abs_mean(), vec![2.0, 3.0]);
        assert_eq!(a.col_abs_max(), vec![3.0, 4.0]);
        let (mn, mx) = a.col_min_max();
        assert_eq!(mn, vec![-3.0, -4.0]);
        assert_eq!(mx, vec![1.0, 2.0]);
    }

    #[test]
    fn mse_and_frob() {
        let a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 3.0);
        assert_eq!(a.mse(&b), 4.0);
        assert_eq!(b.frob_sq(), 36.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }
}
