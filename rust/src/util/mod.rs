//! Small shared utilities: wall-clock timing, human formatting, fs helpers.

use std::time::Instant;

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// `1234567` -> `"1.23M"`.
pub fn human_count(n: f64) -> String {
    if n >= 1e9 {
        format!("{:.2}G", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.2}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.2}k", n / 1e3)
    } else {
        format!("{:.0}", n)
    }
}

/// `3723.4` seconds -> `"1.03h"`, `"12.3s"`, ...
pub fn human_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.2}h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1}m", s / 60.0)
    } else if s >= 1.0 {
        format!("{:.1}s", s)
    } else {
        format!("{:.1}ms", s * 1e3)
    }
}

/// Create the parent directory of `path` if needed.
pub fn ensure_parent(path: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    Ok(())
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human() {
        assert_eq!(human_count(1_234_567.0), "1.23M");
        assert_eq!(human_count(999.0), "999");
        assert_eq!(human_secs(3723.4), "1.03h");
        assert_eq!(human_secs(0.5), "500.0ms");
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 1.0, 1.0])).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }
}
