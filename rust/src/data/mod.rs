//! Synthetic corpora + byte-level tokenizer (substitute for WikiText2 / PTB
//! / C4, which are not available offline — see DESIGN.md §2).
//!
//! Three corpus flavors share a syllable-built vocabulary with Zipfian word
//! frequencies and an SVO sentence grammar, but differ in markup, casing,
//! and topic distribution — reproducing the paper's "calibrate on
//! WikiText2, evaluate on WikiText2/PTB/C4" distribution shifts. The
//! grammar embeds learnable regularities (function-word bigrams, bracket
//! pairs, repeated-phrase structure) that the zero-shot tasks probe.

use crate::rngx::Pcg32;

pub const VOCAB_SIZE: usize = 256; // byte-level

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CorpusKind {
    /// WikiText2-like: section headers, mixed punctuation, full vocab.
    Wt2s,
    /// PTB-like: lowercase, digits replaced by `N`, reduced vocab.
    Ptbs,
    /// C4-like: web noise (url-ish tokens), shifted topic distribution.
    C4s,
}

impl CorpusKind {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::Wt2s => "wt2s",
            CorpusKind::Ptbs => "ptbs",
            CorpusKind::C4s => "c4s",
        }
    }

    pub fn all() -> [CorpusKind; 3] {
        [CorpusKind::Wt2s, CorpusKind::Ptbs, CorpusKind::C4s]
    }
}

// ------------------------------------------------------------ vocabulary

const ONSETS: [&str; 12] = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t"];
const NUCLEI: [&str; 6] = ["a", "e", "i", "o", "u", "ai"];
const CODAS: [&str; 8] = ["", "n", "r", "s", "t", "l", "nd", "st"];

/// Deterministic synthetic content vocabulary, grouped by syntactic role.
pub struct Vocab {
    pub nouns: Vec<String>,
    pub verbs: Vec<String>,
    pub adjs: Vec<String>,
}

impl Vocab {
    pub fn build(seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let mut word = |syll: usize, suffix: &str| -> String {
            let mut w = String::new();
            for _ in 0..syll {
                w.push_str(ONSETS[rng.below(ONSETS.len())]);
                w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
                w.push_str(CODAS[rng.below(CODAS.len())]);
            }
            w.push_str(suffix);
            w
        };
        let nouns = (0..60).map(|_| word(2, "")).collect();
        let verbs = (0..40).map(|i| word(1 + (i % 2), "s")).collect();
        let adjs = (0..30).map(|_| word(2, "y")).collect();
        Vocab { nouns, verbs, adjs }
    }
}

/// Zipfian index sampler over [0, n), optionally shifted to model a
/// different "topic" distribution (C4 flavor).
fn zipf(rng: &mut Pcg32, n: usize, shift: usize) -> usize {
    let weights: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
    let mut x = rng.uniform() * weights;
    for i in 1..=n {
        x -= 1.0 / i as f64;
        if x <= 0.0 {
            return (i - 1 + shift) % n;
        }
    }
    n - 1
}

// --------------------------------------------------------------- grammar

struct Style {
    lowercase: bool,
    headers: bool,
    urls: bool,
    digits_as_n: bool,
    topic_shift: usize,
}

impl Style {
    fn of(kind: CorpusKind) -> Style {
        match kind {
            CorpusKind::Wt2s => Style {
                lowercase: false,
                headers: true,
                urls: false,
                digits_as_n: false,
                topic_shift: 0,
            },
            CorpusKind::Ptbs => Style {
                lowercase: true,
                headers: false,
                urls: false,
                digits_as_n: true,
                topic_shift: 7,
            },
            CorpusKind::C4s => Style {
                lowercase: false,
                headers: false,
                urls: true,
                digits_as_n: false,
                topic_shift: 19,
            },
        }
    }
}

/// One sentence from the SVO grammar. Also used by the zero-shot task
/// generators (eval::zeroshot), hence public.
pub fn sentence(vocab: &Vocab, rng: &mut Pcg32, topic_shift: usize) -> String {
    let noun = |rng: &mut Pcg32| vocab.nouns[zipf(rng, vocab.nouns.len(), topic_shift)].clone();
    let verb = |rng: &mut Pcg32| vocab.verbs[zipf(rng, vocab.verbs.len(), topic_shift)].clone();
    let adj = |rng: &mut Pcg32| vocab.adjs[zipf(rng, vocab.adjs.len(), topic_shift)].clone();

    let mut parts: Vec<String> = vec!["the".into()];
    if rng.uniform() < 0.4 {
        parts.push(adj(rng));
    }
    parts.push(noun(rng));
    // optional parenthesized aside — teaches bracket closing
    if rng.uniform() < 0.15 {
        parts.push("(".into());
        parts.push("of".into());
        parts.push("the".into());
        parts.push(noun(rng));
        parts.push(")".into());
    }
    parts.push(verb(rng));
    parts.push(if rng.uniform() < 0.5 { "the".into() } else { "a".into() });
    if rng.uniform() < 0.3 {
        parts.push(adj(rng));
    }
    parts.push(noun(rng));
    if rng.uniform() < 0.25 {
        parts.push(["in", "of", "to", "with"][rng.below(4)].into());
        parts.push("the".into());
        parts.push(noun(rng));
    }
    // occasional repeated-phrase structure — teaches copying
    if rng.uniform() < 0.1 {
        parts.push("and".into());
        let n = parts.len();
        parts.push(parts[n - 2].clone());
        parts.push(parts[n - 1].clone());
    }
    parts.join(" ")
}

/// Generate `n_bytes` of corpus text.
pub fn gen_corpus(kind: CorpusKind, n_bytes: usize, seed: u64) -> Vec<u8> {
    let vocab = Vocab::build(1234); // shared vocabulary across flavors
    let style = Style::of(kind);
    let mut rng = Pcg32::new(seed, kind as u64 + 1);
    let mut out = String::with_capacity(n_bytes + 256);
    let mut section = 1;
    while out.len() < n_bytes {
        if style.headers && rng.uniform() < 0.02 {
            out.push_str(&format!("\n= Section {} =\n", section));
            section += 1;
        }
        if style.urls && rng.uniform() < 0.05 {
            let host = &vocab.nouns[rng.below(vocab.nouns.len())];
            out.push_str(&format!("http://{}.net ", host));
        }
        let mut s = sentence(&vocab, &mut rng, style.topic_shift);
        if rng.uniform() < 0.12 {
            let year = 1900 + rng.below(120);
            s.push_str(&format!(" in {}", year));
        }
        if style.digits_as_n {
            s = s.chars().map(|c| if c.is_ascii_digit() { 'N' } else { c }).collect();
        }
        let mut s = if style.lowercase {
            s.to_lowercase()
        } else {
            // capitalize sentence start
            let mut cs = s.chars();
            match cs.next() {
                Some(f) => f.to_uppercase().collect::<String>() + cs.as_str(),
                None => s,
            }
        };
        s.push_str(if rng.uniform() < 0.9 { ". " } else { "; " });
        out.push_str(&s);
        if rng.uniform() < 0.08 {
            out.push('\n');
        }
    }
    out.truncate(n_bytes);
    out.into_bytes()
}

// ---------------------------------------------------------------- sampling

/// Calibration/eval segment: `seq + 1` bytes so input/target shift by one.
pub fn sample_segments(corpus: &[u8], seq: usize, n: usize, rng: &mut Pcg32) -> Vec<Vec<u8>> {
    assert!(corpus.len() > seq + 1);
    (0..n)
        .map(|_| {
            let off = rng.below(corpus.len() - seq - 1);
            corpus[off..off + seq + 1].to_vec()
        })
        .collect()
}

/// Sequential non-overlapping eval segments (deterministic PPL protocol).
pub fn eval_segments(corpus: &[u8], seq: usize, max_n: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut off = 0;
    while off + seq + 1 <= corpus.len() && out.len() < max_n {
        out.push(corpus[off..off + seq + 1].to_vec());
        off += seq;
    }
    out
}

/// Segments -> (tokens, targets) i32 batch of shape (b, seq) each.
pub fn to_batch(segments: &[Vec<u8>]) -> (Vec<i32>, Vec<i32>) {
    let seq = segments[0].len() - 1;
    let mut toks = Vec::with_capacity(segments.len() * seq);
    let mut tgts = Vec::with_capacity(segments.len() * seq);
    for s in segments {
        assert_eq!(s.len(), seq + 1);
        toks.extend(s[..seq].iter().map(|&b| b as i32));
        tgts.extend(s[1..].iter().map(|&b| b as i32));
    }
    (toks, tgts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = gen_corpus(CorpusKind::Wt2s, 4096, 1);
        let b = gen_corpus(CorpusKind::Wt2s, 4096, 1);
        assert_eq!(a, b);
        let c = gen_corpus(CorpusKind::Wt2s, 4096, 2);
        assert_ne!(a, c);
    }

    #[test]
    fn flavors_differ_but_share_vocab() {
        let w = gen_corpus(CorpusKind::Wt2s, 20_000, 1);
        let p = gen_corpus(CorpusKind::Ptbs, 20_000, 1);
        let c = gen_corpus(CorpusKind::C4s, 20_000, 1);
        assert_ne!(w, p);
        let p_str = String::from_utf8(p).unwrap();
        assert!(p_str.chars().all(|ch| !ch.is_ascii_uppercase() || ch == 'N'),
            "ptbs must be lowercase (except N)");
        assert!(String::from_utf8(c).unwrap().contains("http://"));
        assert!(String::from_utf8(w.clone()).unwrap().contains("= Section"));
    }

    #[test]
    fn corpus_is_ascii_and_exact_len() {
        for kind in CorpusKind::all() {
            let c = gen_corpus(kind, 10_000, 3);
            assert_eq!(c.len(), 10_000);
            assert!(c.iter().all(|&b| b < 128), "{:?}", kind);
        }
    }

    #[test]
    fn corpus_has_structure() {
        // function words must dominate — that's what makes it learnable
        let c = String::from_utf8(gen_corpus(CorpusKind::Wt2s, 50_000, 4)).unwrap();
        let the_count = c.matches(" the ").count();
        assert!(the_count > 200, "{the_count}");
        // bracket balance within tolerance
        let open = c.matches('(').count() as i64;
        let close = c.matches(')').count() as i64;
        assert!((open - close).abs() <= 1, "{open} vs {close}");
    }

    #[test]
    fn segment_sampling() {
        let c = gen_corpus(CorpusKind::Wt2s, 10_000, 5);
        let mut rng = Pcg32::seeded(0);
        let segs = sample_segments(&c, 128, 8, &mut rng);
        assert_eq!(segs.len(), 8);
        assert!(segs.iter().all(|s| s.len() == 129));
        let (toks, tgts) = to_batch(&segs);
        assert_eq!(toks.len(), 8 * 128);
        // target is input shifted by one
        assert_eq!(toks[1], tgts[0]);
    }

    #[test]
    fn eval_segments_are_disjoint_and_ordered() {
        let c = gen_corpus(CorpusKind::Ptbs, 10_000, 6);
        let segs = eval_segments(&c, 128, 1000);
        assert!(segs.len() >= 70);
        assert_eq!(&c[..129], &segs[0][..]);
        assert_eq!(&c[128..257], &segs[1][..]);
    }

    #[test]
    fn sentences_are_parseable() {
        let vocab = Vocab::build(1234);
        let mut rng = Pcg32::seeded(9);
        for _ in 0..50 {
            let s = sentence(&vocab, &mut rng, 0);
            assert!(s.starts_with("the "));
            assert!(s.split(' ').count() >= 4);
        }
    }
}
