//! Bounded ring-buffer event journal for post-mortem dumps.
//!
//! Fixed capacity; when full, the oldest event is dropped. Every event
//! carries a monotonically increasing sequence number, so a dump makes the
//! wraparound visible: if the first retained `seq` is not 0, that many
//! earlier events were discarded.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// One journaled event. `at_ms` is milliseconds since the journal was
/// created (monotonic, not wall clock — the journal carries no epoch).
#[derive(Debug, Clone)]
pub struct Event {
    pub seq: u64,
    pub at_ms: u64,
    pub kind: &'static str,
    pub detail: String,
}

pub struct Journal {
    start: Instant,
    seq: AtomicU64,
    ring: Mutex<VecDeque<Event>>,
    cap: usize,
}

impl Journal {
    pub fn new(cap: usize) -> Journal {
        Journal {
            start: Instant::now(),
            seq: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cap.max(1))),
            cap: cap.max(1),
        }
    }

    pub fn push(&self, kind: &'static str, detail: String) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let at_ms = self.start.elapsed().as_millis() as u64;
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(Event { seq, at_ms, kind, detail });
    }

    /// Oldest-first copy of the retained events.
    pub fn snapshot(&self) -> Vec<Event> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// Total events ever pushed (including ones the ring has dropped).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_order_below_capacity() {
        let j = Journal::new(8);
        for i in 0..5 {
            j.push("t", format!("e{i}"));
        }
        let snap = j.snapshot();
        assert_eq!(snap.len(), 5);
        assert_eq!(j.total(), 5);
        for (i, ev) in snap.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
            assert_eq!(ev.detail, format!("e{i}"));
        }
    }

    #[test]
    fn wraparound_drops_oldest_and_keeps_seq() {
        let j = Journal::new(4);
        for i in 0..10 {
            j.push("t", format!("e{i}"));
        }
        let snap = j.snapshot();
        assert_eq!(snap.len(), 4, "bounded at capacity");
        assert_eq!(j.total(), 10, "total counts dropped events too");
        // retained events are the newest four, in order, seqs intact
        let seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        assert_eq!(snap[0].detail, "e6");
        assert_eq!(snap[3].detail, "e9");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let j = Journal::new(0);
        j.push("a", "1".into());
        j.push("b", "2".into());
        let snap = j.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].kind, "b");
    }
}
