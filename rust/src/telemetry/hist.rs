//! Lock-free fixed-bucket log-scale latency histograms.
//!
//! One histogram is `BUCKETS` power-of-two-spaced duration buckets (first
//! upper bound 1µs, doubling up to ~134s) plus an overflow bucket, each an
//! `AtomicU64` — recording is wait-free (one relaxed `fetch_add` per
//! observation), reading never blocks writers, and two histograms with the
//! same layout merge by adding counts. Percentiles interpolate linearly
//! inside the containing bucket, so p50/p90/p99 are exact to within one
//! bucket's resolution (a factor of 2 — plenty for latency telemetry).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Finite bucket count; bucket `i` has upper bound `1µs << i`, so the last
/// finite bound is `1000 << 27` ns ≈ 134.2 s. Anything slower lands in the
/// overflow (`+Inf`) bucket.
pub const BUCKETS: usize = 28;

/// Upper bound of finite bucket `i` in nanoseconds.
#[inline]
pub fn bucket_bound_ns(i: usize) -> u64 {
    debug_assert!(i < BUCKETS);
    1000u64 << i
}

/// A mergeable log-scale duration histogram. All methods take `&self`;
/// share it behind an `Arc` freely.
pub struct Histogram {
    counts: [AtomicU64; BUCKETS + 1], // last = overflow (+Inf)
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Index of the bucket an observation of `ns` nanoseconds lands in
    /// (`BUCKETS` = the overflow bucket).
    #[inline]
    pub fn bucket_index(ns: u64) -> usize {
        if ns <= 1000 {
            return 0;
        }
        // smallest i with ns <= 1000 << i; ilog2 avoids a 28-step scan
        let i = (ns.ilog2() as usize).saturating_sub(9);
        let i = if i < BUCKETS && ns <= bucket_bound_ns(i) { i } else { i + 1 };
        i.min(BUCKETS)
    }

    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.counts[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time copy of the bucket counts (oldest-write visibility:
    /// relaxed loads, fine for exposition).
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> =
            self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        HistSnapshot { counts, sum_ns: self.sum_ns.load(Ordering::Relaxed) }
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns() as f64 / n as f64 / 1e6
        }
    }

    /// Add `other`'s observations into `self` (same fixed layout by
    /// construction, so merging is bucket-wise addition).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter().zip(&other.counts) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Quantile `p` in `[0, 1]` in nanoseconds, linearly interpolated
    /// within the containing bucket; 0.0 when empty. Observations in the
    /// overflow bucket report the last finite bound (a floor, not a lie:
    /// "at least 134s").
    pub fn percentile_ns(&self, p: f64) -> f64 {
        self.snapshot().percentile_ns(p)
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile_ns(p) / 1e6
    }
}

/// A read-only copy of a histogram's state, for rendering/percentiles.
pub struct HistSnapshot {
    /// `BUCKETS + 1` entries; last is the overflow (+Inf) bucket.
    pub counts: Vec<u64>,
    pub sum_ns: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn percentile_ns(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                if i >= BUCKETS {
                    return bucket_bound_ns(BUCKETS - 1) as f64;
                }
                let lo = if i == 0 { 0.0 } else { bucket_bound_ns(i - 1) as f64 };
                let hi = bucket_bound_ns(i) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return lo + frac * (hi - lo);
            }
            seen += c;
        }
        bucket_bound_ns(BUCKETS - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_inclusive_upper() {
        // bound[i] lands in bucket i, bound[i] + 1 in bucket i + 1
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(1000), 0);
        assert_eq!(Histogram::bucket_index(1001), 1);
        for i in 0..BUCKETS {
            assert_eq!(Histogram::bucket_index(bucket_bound_ns(i)), i, "bound {i}");
            let next = if i + 1 < BUCKETS { i + 1 } else { BUCKETS };
            assert_eq!(
                Histogram::bucket_index(bucket_bound_ns(i) + 1),
                next,
                "bound {i} + 1"
            );
        }
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS);
    }

    #[test]
    fn counts_and_sum_accumulate() {
        let h = Histogram::new();
        h.record_ns(500);
        h.record_ns(1500);
        h.record_ns(1500);
        h.record(Duration::from_micros(100));
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_ns(), 500 + 1500 + 1500 + 100_000);
        let s = h.snapshot();
        assert_eq!(s.counts[0], 1);
        assert_eq!(s.counts[1], 2);
        assert_eq!(s.counts[Histogram::bucket_index(100_000)], 1);
    }

    #[test]
    fn percentiles_interpolate_and_order() {
        let h = Histogram::new();
        // 100 obs in bucket 1 (1µs..2µs], 100 in bucket 11 (~1ms..2ms]
        for _ in 0..100 {
            h.record_ns(1500);
            h.record_ns(1_500_000);
        }
        let p25 = h.percentile_ns(0.25);
        let p50 = h.percentile_ns(0.50);
        let p99 = h.percentile_ns(0.99);
        assert!(p25 > 1000.0 && p25 <= 2000.0, "p25 = {p25}");
        assert!(p50 <= 2000.0, "p50 = {p50} (exactly half the mass is fast)");
        assert!(p99 > 1_000_000.0 && p99 <= 2_097_152.0, "p99 = {p99}");
        assert!(p25 <= p50 && p50 <= p99);
        // empty histogram is all-zero
        assert_eq!(Histogram::new().percentile_ns(0.99), 0.0);
        assert_eq!(Histogram::new().mean_ms(), 0.0);
    }

    #[test]
    fn overflow_reports_last_finite_bound() {
        let h = Histogram::new();
        h.record_ns(u64::MAX);
        assert_eq!(h.percentile_ns(0.5), bucket_bound_ns(BUCKETS - 1) as f64);
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        for _ in 0..10 {
            a.record_ns(1500);
            b.record_ns(1500);
            b.record_ns(3_000_000);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 30);
        assert_eq!(a.snapshot().counts[1], 20);
        assert_eq!(a.sum_ns(), 10 * 1500 + 10 * 1500 + 10 * 3_000_000);
        // merged percentiles see both populations
        assert!(a.percentile_ns(0.99) > 2_000_000.0);
    }
}
