//! Per-request span records, addressable by trace ID.
//!
//! Every accepted request gets a span keyed by the engine-side request id
//! (`u64`) and an externally visible trace-ID string (the inbound
//! `X-Request-Id` when the client sent one, else a generated `req-…`).
//! Spans capture the request's life: admission → enqueue wait →
//! time-to-first-token → inter-token gaps → finish reason. The store is
//! bounded; the oldest span is evicted when full, so `/v1/trace/<id>` is a
//! recent-history lookup, not an archive.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Lifecycle record for one request. Duration fields are `f64`
/// milliseconds and negative means "not reached" (rendered as absent).
#[derive(Debug, Clone)]
pub struct Span {
    pub id: u64,
    pub trace_id: String,
    pub client: String,
    pub prompt_len: usize,
    pub max_new: usize,
    pub queue_wait_ms: f64,
    pub ttft_ms: f64,
    pub gap_count: u64,
    pub gap_sum_ms: f64,
    pub gap_max_ms: f64,
    pub tokens: usize,
    /// Finish-reason label, or a server-side outcome ("shed", "bad_request",
    /// "disconnect", …); empty while in flight.
    pub outcome: String,
    pub total_ms: f64,
}

impl Span {
    fn new(id: u64) -> Span {
        Span {
            id,
            trace_id: String::new(),
            client: String::new(),
            prompt_len: 0,
            max_new: 0,
            queue_wait_ms: -1.0,
            ttft_ms: -1.0,
            gap_count: 0,
            gap_sum_ms: 0.0,
            gap_max_ms: 0.0,
            tokens: 0,
            outcome: String::new(),
            total_ms: -1.0,
        }
    }

    pub fn mean_gap_ms(&self) -> f64 {
        if self.gap_count == 0 {
            0.0
        } else {
            self.gap_sum_ms / self.gap_count as f64
        }
    }
}

struct Inner {
    map: HashMap<u64, Span>,
    order: VecDeque<u64>,
    by_tid: HashMap<String, u64>,
}

/// Bounded id → span store with upsert semantics: the engine and the
/// server both touch spans and either may get there first.
pub struct TraceStore {
    inner: Mutex<Inner>,
    cap: usize,
}

impl TraceStore {
    pub fn new(cap: usize) -> TraceStore {
        TraceStore {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                by_tid: HashMap::new(),
            }),
            cap: cap.max(1),
        }
    }

    /// Mutate (creating if absent) the span for `id`.
    pub fn update(&self, id: u64, f: impl FnOnce(&mut Span)) {
        let mut inner = self.inner.lock().unwrap();
        if !inner.map.contains_key(&id) {
            if inner.order.len() == self.cap {
                if let Some(old) = inner.order.pop_front() {
                    if let Some(s) = inner.map.remove(&old) {
                        if !s.trace_id.is_empty() {
                            inner.by_tid.remove(&s.trace_id);
                        }
                    }
                }
            }
            inner.order.push_back(id);
            inner.map.insert(id, Span::new(id));
        }
        let mut tid_add: Option<String> = None;
        if let Some(span) = inner.map.get_mut(&id) {
            let before = span.trace_id.clone();
            f(span);
            if span.trace_id != before && !span.trace_id.is_empty() {
                tid_add = Some(span.trace_id.clone());
            }
        }
        if let Some(tid) = tid_add {
            inner.by_tid.insert(tid, id);
        }
    }

    pub fn get(&self, id: u64) -> Option<Span> {
        self.inner.lock().unwrap().map.get(&id).cloned()
    }

    /// Look up by the externally visible trace-ID string; falls back to
    /// parsing `key` as a numeric engine id.
    pub fn lookup(&self, key: &str) -> Option<Span> {
        let inner = self.inner.lock().unwrap();
        if let Some(&id) = inner.by_tid.get(key) {
            return inner.map.get(&id).cloned();
        }
        key.parse::<u64>().ok().and_then(|id| inner.map.get(&id).cloned())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upsert_and_lookup_by_both_keys() {
        let t = TraceStore::new(8);
        t.update(7, |s| {
            s.trace_id = "req-abc".into();
            s.prompt_len = 3;
        });
        t.update(7, |s| s.tokens = 5);
        let by_id = t.get(7).unwrap();
        assert_eq!(by_id.prompt_len, 3);
        assert_eq!(by_id.tokens, 5);
        assert_eq!(t.lookup("req-abc").unwrap().id, 7);
        assert_eq!(t.lookup("7").unwrap().trace_id, "req-abc");
        assert!(t.lookup("nope").is_none());
    }

    #[test]
    fn eviction_drops_oldest_span_and_its_tid() {
        let t = TraceStore::new(2);
        t.update(1, |s| s.trace_id = "a".into());
        t.update(2, |s| s.trace_id = "b".into());
        t.update(3, |s| s.trace_id = "c".into());
        assert_eq!(t.len(), 2);
        assert!(t.get(1).is_none());
        assert!(t.lookup("a").is_none());
        assert!(t.lookup("b").is_some());
        assert!(t.lookup("c").is_some());
    }
}
