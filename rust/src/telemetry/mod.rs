//! End-to-end telemetry: counters, latency histograms, request spans, an
//! event journal, and Prometheus text exposition. Dependency-light by
//! design — `std` only — because it is compiled into the
//! `--no-default-features` deployment build.
//!
//! The whole subsystem hangs off [`Recorder`], a cloneable handle that is
//! either *live* (wraps an `Arc<Telemetry>`) or *disabled* (`None`, the
//! `Default`). Every recording method starts with an inline `None` check,
//! so a disabled recorder costs one branch and — crucially — never reads
//! the clock: the offline engine keeps its no-wall-clock property and the
//! bit-stability contract is untouched either way (telemetry only ever
//! observes, it cannot influence scheduling or math).
//!
//! Layout: [`hist`] (log-scale mergeable histograms), [`journal`] (bounded
//! ring of events), [`trace`] (per-request spans), [`kernel`]
//! (process-global sampled GEMM/head timing), [`numeric`] (sampled
//! per-layer activation stats, calibration-drift detection, cross-bit-width
//! divergence accounting).

pub mod hist;
pub mod journal;
pub mod kernel;
pub mod numeric;
pub mod trace;

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use hist::{bucket_bound_ns, HistSnapshot, Histogram, BUCKETS};
pub use journal::{Event, Journal};
pub use trace::{Span, TraceStore};

/// How many spans `/v1/trace/<id>` can look back over.
pub const TRACE_CAP: usize = 256;
/// Journal ring capacity.
pub const JOURNAL_CAP: usize = 1024;

/// The shared metric registry: request-level and engine-level histograms,
/// row counters, the span store, and the event journal.
pub struct Telemetry {
    /// Submit → first generated token (the serving TTFT).
    pub ttft: Histogram,
    /// Gap between consecutive generated tokens of one sequence.
    pub inter_token: Histogram,
    /// Submit → admission into a KV slot.
    pub queue_wait: Histogram,
    /// Submit → finish (whole request).
    pub request: Histogram,
    /// One scheduler tick, wall time — total and split by phase.
    pub tick: Histogram,
    pub tick_prefill: Histogram,
    pub tick_decode: Histogram,
    pub tick_mixed: Histogram,
    pub ticks: AtomicU64,
    pub prefill_rows: AtomicU64,
    pub decode_rows: AtomicU64,
    pub traces: TraceStore,
    pub journal: Journal,
    /// Numeric-health state: live per-layer activation stats vs the baked
    /// calibration envelopes + the cross-bit-width divergence accumulator.
    pub numeric: numeric::NumericHealth,
}

impl Telemetry {
    pub fn new() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            ttft: Histogram::new(),
            inter_token: Histogram::new(),
            queue_wait: Histogram::new(),
            request: Histogram::new(),
            tick: Histogram::new(),
            tick_prefill: Histogram::new(),
            tick_decode: Histogram::new(),
            tick_mixed: Histogram::new(),
            ticks: AtomicU64::new(0),
            prefill_rows: AtomicU64::new(0),
            decode_rows: AtomicU64::new(0),
            traces: TraceStore::new(TRACE_CAP),
            journal: Journal::new(JOURNAL_CAP),
            numeric: numeric::NumericHealth::default(),
        })
    }
}

/// KV page-pool occupancy gauges + sharing counters. The engine loop
/// republishes them from `KvCache::stats()` after every tick; `/v1/stats`
/// and `/metrics` read them without touching the engine thread. Plain
/// always-on atomics like the scheduler gauges — observation only, no
/// clock reads, no influence on allocation.
#[derive(Default)]
pub struct KvPoolGauges {
    /// Pool bound in pages (allocated count when unbounded).
    pub pages_total: AtomicU64,
    pub pages_free: AtomicU64,
    /// Pages referenced by at least one live sequence.
    pub pages_resident: AtomicU64,
    /// Refcount-0 pages the prefix registry keeps reclaimable.
    pub pages_cached: AtomicU64,
    /// Pages referenced by two or more sequences right now.
    pub pages_shared: AtomicU64,
    /// Bytes sharing saves right now (duplicate copies avoided).
    pub shared_bytes: AtomicU64,
    /// K+V bytes held by live sequences.
    pub resident_bytes: AtomicU64,
    /// Cumulative copy-on-write page copies at divergence points.
    pub cow_faults: AtomicU64,
    /// Cumulative admissions that attached a shared prompt prefix.
    pub prefix_hits: AtomicU64,
    /// Cumulative prompt tokens served from shared pages (prefill skipped).
    pub shared_tokens: AtomicU64,
}

/// Cloneable recording handle; `Default` is disabled (all methods no-ops
/// that never read the clock).
#[derive(Clone, Default)]
pub struct Recorder(Option<Arc<Telemetry>>);

impl Recorder {
    pub fn new_enabled() -> Recorder {
        Recorder(Some(Telemetry::new()))
    }

    pub fn from_telemetry(t: Arc<Telemetry>) -> Recorder {
        Recorder(Some(t))
    }

    pub fn disabled() -> Recorder {
        Recorder(None)
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.0.as_ref()
    }

    /// Clock read gated on the handle being live: `None` when disabled, so
    /// callers hold `Option<Instant>` and pay nothing when telemetry is
    /// off.
    #[inline]
    pub fn now(&self) -> Option<Instant> {
        self.0.as_ref().map(|_| Instant::now())
    }

    #[inline]
    pub fn queue_wait(&self, id: u64, d: Duration) {
        if let Some(t) = &self.0 {
            t.queue_wait.record(d);
            t.traces.update(id, |s| s.queue_wait_ms = d.as_secs_f64() * 1e3);
        }
    }

    #[inline]
    pub fn ttft(&self, id: u64, d: Duration) {
        if let Some(t) = &self.0 {
            t.ttft.record(d);
            t.traces.update(id, |s| s.ttft_ms = d.as_secs_f64() * 1e3);
        }
    }

    #[inline]
    pub fn gap(&self, id: u64, d: Duration) {
        if let Some(t) = &self.0 {
            t.inter_token.record(d);
            let ms = d.as_secs_f64() * 1e3;
            t.traces.update(id, |s| {
                s.gap_count += 1;
                s.gap_sum_ms += ms;
                if ms > s.gap_max_ms {
                    s.gap_max_ms = ms;
                }
            });
        }
    }

    /// Request reached a terminal state inside the engine.
    #[inline]
    pub fn finished(&self, id: u64, outcome: &str, tokens: usize, total: Option<Duration>) {
        if let Some(t) = &self.0 {
            if let Some(d) = total {
                t.request.record(d);
            }
            let outcome = outcome.to_string();
            t.traces.update(id, |s| {
                s.tokens = tokens;
                s.outcome = outcome;
                if let Some(d) = total {
                    s.total_ms = d.as_secs_f64() * 1e3;
                }
            });
        }
    }

    /// One scheduler tick completed; `t0` is the matching [`Recorder::now`]
    /// from tick start. Rows classify the tick's phase: prefill-only,
    /// decode-only, or mixed.
    #[inline]
    pub fn tick(&self, t0: Option<Instant>, prefill_rows: usize, decode_rows: usize) {
        if let (Some(t), Some(t0)) = (&self.0, t0) {
            let d = t0.elapsed();
            t.tick.record(d);
            match (prefill_rows > 0, decode_rows > 0) {
                (true, false) => t.tick_prefill.record(d),
                (false, true) => t.tick_decode.record(d),
                (true, true) => t.tick_mixed.record(d),
                (false, false) => {}
            }
            t.ticks.fetch_add(1, Ordering::Relaxed);
            t.prefill_rows.fetch_add(prefill_rows as u64, Ordering::Relaxed);
            t.decode_rows.fetch_add(decode_rows as u64, Ordering::Relaxed);
            // drift windows close on tick boundaries; transitions journal
            t.numeric.evaluate(&t.journal);
        }
    }

    /// Numeric-health handle for the decode observation hook; `None` when
    /// telemetry is disabled, so sampling costs one branch there.
    #[inline]
    pub fn numeric(&self) -> Option<&numeric::NumericHealth> {
        self.0.as_deref().map(|t| &t.numeric)
    }

    /// Install the baked calibration envelopes at session start (no-op when
    /// disabled).
    pub fn numeric_install(
        &self,
        envelopes: Vec<numeric::Envelope>,
        serve_bits: u32,
        draft_bits: Option<u32>,
    ) {
        if let Some(t) = &self.0 {
            t.numeric.install(envelopes, serve_bits, draft_bits);
        }
    }

    /// Record one cross-bit-width divergence probe; disagreements land in
    /// the journal (they are the acceptance-rate misses).
    #[inline]
    pub fn numeric_divergence(&self, agree: bool, max_logit_delta: f32, group_delta: &[f32]) {
        if let Some(t) = &self.0 {
            t.numeric.record_divergence(agree, max_logit_delta, group_delta);
            if !agree {
                t.journal.push(
                    "numeric_divergence",
                    format!("cross-bit-width top-1 disagreement (max logit delta {max_logit_delta:.3})"),
                );
            }
        }
    }

    /// Mutate (creating if needed) the span for request `id`.
    #[inline]
    pub fn span(&self, id: u64, f: impl FnOnce(&mut Span)) {
        if let Some(t) = &self.0 {
            t.traces.update(id, f);
        }
    }

    /// Append to the post-mortem journal.
    #[inline]
    pub fn event(&self, kind: &'static str, detail: impl FnOnce() -> String) {
        if let Some(t) = &self.0 {
            t.journal.push(kind, detail());
        }
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition (format version 0.0.4)

/// Append one `# HELP`/`# TYPE` header + counter sample.
pub fn prom_counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

/// Append one gauge sample.
pub fn prom_gauge(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

/// Append one float-valued gauge sample.
pub fn prom_gauge_f64(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

/// Append the `# HELP`/`# TYPE` header for a histogram family. Call once
/// per family, then [`prom_histogram_series`] once per label set.
pub fn prom_histogram_header(out: &mut String, name: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
}

/// Append the cumulative `_bucket`/`_sum`/`_count` series for one
/// histogram, in **seconds** (the Prometheus base unit for durations).
/// `labels` is either empty or `r#"phase="prefill""#`-style pairs without
/// braces. `_count` and the `+Inf` bucket are derived from the same bucket
/// sum, so the exposition is always self-consistent even while writers
/// race.
pub fn prom_histogram_series(out: &mut String, name: &str, labels: &str, snap: &HistSnapshot) {
    let mut cum = 0u64;
    for (i, &c) in snap.counts.iter().enumerate() {
        cum += c;
        let le = if i < BUCKETS {
            format!("{}", bucket_bound_ns(i) as f64 / 1e9)
        } else {
            "+Inf".to_string()
        };
        let sep = if labels.is_empty() { String::new() } else { format!("{labels},") };
        let _ = writeln!(out, "{name}_bucket{{{sep}le=\"{le}\"}} {cum}");
    }
    let brace = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
    let _ = writeln!(out, "{name}_sum{brace} {}", snap.sum_ns as f64 / 1e9);
    let _ = writeln!(out, "{name}_count{brace} {cum}");
}

/// Convenience: header + single unlabelled series.
pub fn prom_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    prom_histogram_header(out, name, help);
    prom_histogram_series(out, name, "", &h.snapshot());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert_and_clockless() {
        let r = Recorder::default();
        assert!(!r.enabled());
        assert!(r.now().is_none());
        r.queue_wait(1, Duration::from_millis(1));
        r.ttft(1, Duration::from_millis(1));
        r.gap(1, Duration::from_millis(1));
        r.finished(1, "eos", 3, Some(Duration::from_millis(1)));
        r.tick(None, 1, 1);
        r.span(1, |s| s.tokens = 9);
        r.event("x", || unreachable!("detail closure must not run when disabled"));
        r.numeric_install(Vec::new(), 4, None);
        r.numeric_divergence(false, 1.0, &[0.5]);
        assert!(r.numeric().is_none());
        assert!(r.telemetry().is_none());
    }

    #[test]
    fn live_recorder_populates_registry_and_span() {
        let r = Recorder::new_enabled();
        let t0 = r.now();
        assert!(t0.is_some());
        r.span(42, |s| {
            s.trace_id = "req-x".into();
            s.prompt_len = 4;
        });
        r.queue_wait(42, Duration::from_micros(300));
        r.ttft(42, Duration::from_millis(2));
        r.gap(42, Duration::from_millis(1));
        r.gap(42, Duration::from_millis(3));
        r.finished(42, "eos", 3, Some(Duration::from_millis(6)));
        r.tick(t0, 2, 1);
        r.event("test", || "hello".into());

        let t = r.telemetry().unwrap();
        assert_eq!(t.ttft.count(), 1);
        assert_eq!(t.inter_token.count(), 2);
        assert_eq!(t.queue_wait.count(), 1);
        assert_eq!(t.request.count(), 1);
        assert_eq!(t.tick.count(), 1);
        assert_eq!(t.tick_mixed.count(), 1);
        assert_eq!(t.ticks.load(Ordering::Relaxed), 1);
        assert_eq!(t.prefill_rows.load(Ordering::Relaxed), 2);
        assert_eq!(t.decode_rows.load(Ordering::Relaxed), 1);
        assert_eq!(t.journal.total(), 1);

        let span = t.traces.lookup("req-x").unwrap();
        assert_eq!(span.id, 42);
        assert_eq!(span.tokens, 3);
        assert_eq!(span.outcome, "eos");
        assert_eq!(span.gap_count, 2);
        assert!(span.ttft_ms > 0.0 && span.total_ms > 0.0);
        assert!(span.gap_max_ms >= span.mean_gap_ms());
    }

    #[test]
    fn prometheus_rendering_is_consistent() {
        let h = Histogram::new();
        h.record_ns(1500);
        h.record_ns(3_000_000);
        let mut out = String::new();
        prom_histogram(&mut out, "aq_test_seconds", "test hist", &h);
        prom_counter(&mut out, "aq_test_total", "test counter", 7);
        prom_gauge(&mut out, "aq_test_active", "test gauge", 2);

        assert!(out.contains("# TYPE aq_test_seconds histogram"));
        assert!(out.contains("aq_test_seconds_count 2"));
        assert!(out.contains("le=\"+Inf\"} 2"));
        // cumulative: every bucket line is <= the +Inf value
        let infv: u64 = 2;
        for line in out.lines().filter(|l| l.starts_with("aq_test_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v <= infv);
        }
        assert!(out.contains("aq_test_total 7"));
        assert!(out.contains("# TYPE aq_test_active gauge"));

        // labelled series
        let mut out2 = String::new();
        prom_histogram_header(&mut out2, "aq_ph_seconds", "phases");
        prom_histogram_series(&mut out2, "aq_ph_seconds", r#"phase="prefill""#, &h.snapshot());
        assert!(out2.contains(r#"aq_ph_seconds_bucket{phase="prefill",le="+Inf"} 2"#));
        assert!(out2.contains(r#"aq_ph_seconds_sum{phase="prefill"}"#));
    }
}
