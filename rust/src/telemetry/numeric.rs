//! Numeric-health observability: sampled per-layer activation statistics,
//! calibration-drift detection, and cross-bit-width divergence accounting.
//!
//! PR 7's telemetry observes *latency*; this module observes *error*. At
//! pack time the AQPM header bakes per-layer calibration artifacts
//! (activation absmax/mean/var envelopes from a deterministic probe
//! forward, plus weight quantization error — see `engine/packed.rs`). At
//! serving time the scheduler samples 1-in-[`SAMPLE`] decode rows and
//! streams the residual-stream input of every layer into per-layer
//! [`Welford`] accumulators here, counts envelope outliers, and feeds a
//! hysteresis [`DriftDetector`] per layer. A cross-bit-width divergence
//! sampler (`sched.rs`) periodically re-runs a live sequence's window
//! through a lower-bit draft variant and records top-1 agreement — the
//! acceptance-rate proxy the speculative-decoding roadmap item needs.
//!
//! Everything here is observation-only: sampling happens behind the
//! zero-cost-when-disabled `Recorder`, touches no model math, reads no
//! clock, and never consumes scheduler RNG — greedy output is bit-identical
//! with numeric sampling on or off (asserted by parity tests).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::journal::Journal;

/// 1-in-N decode-row sampling rate for live activation statistics.
pub const SAMPLE: u64 = 16;
/// A sampled row is an envelope outlier when its |x| max exceeds the baked
/// calibration absmax by more than this factor (strictly greater).
pub const OUTLIER_TOL: f32 = 1.25;
/// Divergence probes: first probe after this many decode-bearing ticks…
pub const PROBE_WARMUP: u64 = 4;
/// …then one probe every this many decode-bearing ticks.
pub const PROBE_EVERY: u64 = 16;
/// Token-window cap for one divergence probe (both bit-widths re-run this
/// many trailing tokens of the sampled sequence).
pub const PROBE_WINDOW: usize = 64;
/// Layer groups divergence deltas are reported under.
pub const PROBE_GROUPS: usize = 4;

// ----------------------------------------------------------------- Welford

/// Streaming mean/variance/absmax (Welford's online algorithm). Used both
/// for the pack-time calibration envelopes and the live serving stats, so
/// the two sides of the drift comparison share one definition.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    count: u64,
    mean: f64,
    m2: f64,
    absmax: f32,
}

impl Welford {
    #[inline]
    pub fn push(&mut self, v: f32) {
        self.count += 1;
        let d = v as f64 - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (v as f64 - self.mean);
        let a = v.abs();
        if a > self.absmax {
            self.absmax = a;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (matches the two-pass `sum((x-mu)^2)/n`).
    pub fn var(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / self.count as f64
        }
    }

    pub fn absmax(&self) -> f32 {
        self.absmax
    }
}

// ---------------------------------------------------------------- envelope

/// Per-layer baked calibration artifact, loaded from the AQPM header.
/// `count == 0` means the file predates calibration baking (or the model
/// was never calibrated) — the layer then reports `no_data`, never drift.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Envelope {
    /// Max |x| over the residual-stream inputs of this layer during the
    /// calibration probe.
    pub absmax: f32,
    pub mean: f32,
    pub var: f32,
    /// Activation elements the calibration pass observed.
    pub count: u64,
    /// Mean squared dequant-vs-reference weight error over the layer's
    /// quantized linears.
    pub weight_mse: f32,
    /// Max absolute dequant-vs-reference weight error.
    pub weight_max_abs: f32,
}

impl Envelope {
    /// Is a sampled row with this |x| max outside the envelope?
    /// Strict inequality: a row *at* the tolerance boundary is in-envelope.
    #[inline]
    pub fn is_outlier(&self, row_absmax: f32) -> bool {
        self.count > 0 && row_absmax > self.absmax * OUTLIER_TOL
    }
}

// ----------------------------------------------------------- drift detector

/// Hysteresis thresholds for the per-layer drift verdict.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// Enter drift when a window's outlier fraction is >= this…
    pub enter_frac: f32,
    /// …exit when it is <= this (must be < `enter_frac`).
    pub exit_frac: f32,
    /// Consecutive qualifying windows required to arm a transition.
    pub arm: u32,
    /// Minimum sampled rows per evaluation window.
    pub min_window: u64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig { enter_frac: 0.5, exit_frac: 0.1, arm: 2, min_window: 8 }
    }
}

/// Two-threshold hysteresis state machine: a window fraction between
/// `exit_frac` and `enter_frac` resets both streaks, so oscillating input
/// can never flap the verdict.
#[derive(Clone, Copy, Debug, Default)]
pub struct DriftDetector {
    drifting: bool,
    hi_streak: u32,
    lo_streak: u32,
}

impl DriftDetector {
    /// Feed one evaluation window's outlier fraction. Returns `Some(state)`
    /// when the verdict transitions (true = entered drift).
    pub fn observe(&mut self, frac: f32, cfg: &DriftConfig) -> Option<bool> {
        if frac >= cfg.enter_frac {
            self.hi_streak += 1;
        } else {
            self.hi_streak = 0;
        }
        if frac <= cfg.exit_frac {
            self.lo_streak += 1;
        } else {
            self.lo_streak = 0;
        }
        if !self.drifting && self.hi_streak >= cfg.arm {
            self.drifting = true;
            return Some(true);
        }
        if self.drifting && self.lo_streak >= cfg.arm {
            self.drifting = false;
            return Some(false);
        }
        None
    }

    pub fn drifting(&self) -> bool {
        self.drifting
    }
}

// ------------------------------------------------------------ NumericHealth

#[derive(Clone, Default)]
struct LayerLive {
    stats: Welford,
    /// Sampled rows observed (cumulative).
    rows: u64,
    /// Envelope outliers among them (cumulative).
    outliers: u64,
    /// Current evaluation window (reset by `evaluate`).
    win_rows: u64,
    win_outliers: u64,
    det: DriftDetector,
}

/// Cross-bit-width divergence accumulator (speculative-decoding
/// acceptance-rate proxy).
#[derive(Clone, Debug, Default)]
pub struct Divergence {
    pub serve_bits: u32,
    pub draft_bits: u32,
    pub probes: u64,
    /// Probes whose top-1 token agreed between the two bit-widths.
    pub agree: u64,
    pub max_logit_delta: f32,
    pub sum_logit_delta: f64,
    /// Max hidden-state |delta| seen per layer group, over all probes.
    pub group_delta: Vec<f32>,
}

impl Divergence {
    pub fn agree_pct(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            100.0 * self.agree as f64 / self.probes as f64
        }
    }

    pub fn mean_logit_delta(&self) -> f64 {
        if self.probes == 0 {
            0.0
        } else {
            self.sum_logit_delta / self.probes as f64
        }
    }
}

struct Inner {
    envelopes: Vec<Envelope>,
    layers: Vec<LayerLive>,
    cfg: DriftConfig,
    div: Divergence,
    installed: bool,
}

/// Per-registry numeric-health state: baked envelopes, live per-layer
/// streaming stats, drift detectors, and the divergence accumulator. Lives
/// inside `Telemetry`; the decode path reaches it through
/// `Recorder::numeric()` (None when telemetry is disabled, so the hot path
/// pays a single branch).
pub struct NumericHealth {
    ticket: AtomicU64,
    sample_every: u64,
    inner: Mutex<Inner>,
}

impl Default for NumericHealth {
    fn default() -> NumericHealth {
        NumericHealth::new(SAMPLE)
    }
}

/// One layer of [`NumericHealth::snapshot`]: baked envelope + live stats.
#[derive(Clone, Debug, Default)]
pub struct LayerReport {
    pub layer: usize,
    pub env: Envelope,
    /// Sampled rows / elements folded into the live stats.
    pub rows: u64,
    pub count: u64,
    pub mean: f64,
    pub var: f64,
    pub absmax: f32,
    pub outliers: u64,
    pub outlier_frac: f64,
    pub drifting: bool,
}

impl LayerReport {
    /// `drifting` > `no_data` > `ok`.
    pub fn verdict(&self) -> &'static str {
        if self.drifting {
            "drifting"
        } else if self.env.count == 0 || self.rows == 0 {
            "no_data"
        } else {
            "ok"
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub layers: Vec<LayerReport>,
    pub div: Divergence,
}

impl NumericHealth {
    pub fn new(sample_every: u64) -> NumericHealth {
        NumericHealth {
            ticket: AtomicU64::new(0),
            sample_every: sample_every.max(1),
            inner: Mutex::new(Inner {
                envelopes: Vec::new(),
                layers: Vec::new(),
                cfg: DriftConfig::default(),
                div: Divergence::default(),
                installed: false,
            }),
        }
    }

    /// Install the baked calibration envelopes (one per layer) at session
    /// start. Idempotent; re-installing resets nothing live.
    pub fn install(&self, envelopes: Vec<Envelope>, serve_bits: u32, draft_bits: Option<u32>) {
        let mut inner = self.inner.lock().unwrap();
        inner.layers.resize(envelopes.len(), LayerLive::default());
        inner.envelopes = envelopes;
        inner.div.serve_bits = serve_bits;
        inner.div.draft_bits = draft_bits.unwrap_or(0);
        inner.installed = true;
    }

    pub fn installed(&self) -> bool {
        self.inner.lock().unwrap().installed
    }

    /// Should the next decode row be sampled? One relaxed fetch-add; the
    /// decision stream is process-deterministic per `NumericHealth`.
    #[inline]
    pub fn sample(&self) -> bool {
        self.ticket.fetch_add(1, Ordering::Relaxed) % self.sample_every == 0
    }

    /// Fold the listed rows of a layer's `(m, d)` input into its live
    /// stats. Called by `decode::layer_forward` with the residual-stream
    /// input *before* the pre-attention norm — the same quantity the
    /// calibration probe enveloped.
    pub fn record_rows(&self, layer: usize, x: &[f32], d: usize, rows: &[usize]) {
        if rows.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if layer >= inner.layers.len() {
            inner.layers.resize(layer + 1, LayerLive::default());
        }
        let env = inner.envelopes.get(layer).copied().unwrap_or_default();
        let live = &mut inner.layers[layer];
        for &r in rows {
            let row = &x[r * d..(r + 1) * d];
            let mut row_absmax = 0f32;
            for &v in row {
                live.stats.push(v);
                let a = v.abs();
                if a > row_absmax {
                    row_absmax = a;
                }
            }
            live.rows += 1;
            live.win_rows += 1;
            if env.is_outlier(row_absmax) {
                live.outliers += 1;
                live.win_outliers += 1;
            }
        }
    }

    /// Evaluate drift per layer: every layer whose current window holds at
    /// least `min_window` sampled rows feeds its outlier fraction to its
    /// hysteresis detector; transitions land in the journal. Called once
    /// per scheduler tick (cheap: n_layers compares, uncontended lock).
    pub fn evaluate(&self, journal: &Journal) {
        let mut inner = self.inner.lock().unwrap();
        let cfg = inner.cfg;
        for (li, l) in inner.layers.iter_mut().enumerate() {
            if l.win_rows < cfg.min_window {
                continue;
            }
            let frac = l.win_outliers as f32 / l.win_rows as f32;
            let wr = l.win_rows;
            l.win_rows = 0;
            l.win_outliers = 0;
            if let Some(entered) = l.det.observe(frac, &cfg) {
                let what = if entered { "entered" } else { "exited" };
                journal.push(
                    "numeric_drift",
                    format!(
                        "layer {li} {what} drift (outlier frac {frac:.2} over {wr} sampled rows)"
                    ),
                );
            }
        }
    }

    /// Record one divergence probe result.
    pub fn record_divergence(&self, agree: bool, max_logit_delta: f32, group_delta: &[f32]) {
        let mut inner = self.inner.lock().unwrap();
        let div = &mut inner.div;
        div.probes += 1;
        if agree {
            div.agree += 1;
        }
        if max_logit_delta > div.max_logit_delta {
            div.max_logit_delta = max_logit_delta;
        }
        div.sum_logit_delta += max_logit_delta as f64;
        if div.group_delta.len() < group_delta.len() {
            div.group_delta.resize(group_delta.len(), 0.0);
        }
        for (acc, &g) in div.group_delta.iter_mut().zip(group_delta) {
            if g > *acc {
                *acc = g;
            }
        }
    }

    /// Layers currently in the drifting state.
    pub fn drift_layers(&self) -> usize {
        self.inner.lock().unwrap().layers.iter().filter(|l| l.det.drifting()).count()
    }

    /// Consistent point-in-time copy of everything the surfaces render.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().unwrap();
        let n = inner.envelopes.len().max(inner.layers.len());
        let layers = (0..n)
            .map(|li| {
                let env = inner.envelopes.get(li).copied().unwrap_or_default();
                let l = inner.layers.get(li).cloned().unwrap_or_default();
                LayerReport {
                    layer: li,
                    env,
                    rows: l.rows,
                    count: l.stats.count(),
                    mean: l.stats.mean(),
                    var: l.stats.var(),
                    absmax: l.stats.absmax(),
                    outliers: l.outliers,
                    outlier_frac: if l.rows == 0 {
                        0.0
                    } else {
                        l.outliers as f64 / l.rows as f64
                    },
                    drifting: l.det.drifting(),
                }
            })
            .collect();
        Snapshot { layers, div: inner.div.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg32;

    #[test]
    fn welford_matches_two_pass_reference() {
        let mut rng = Pcg32::seeded(3);
        for n in [1usize, 2, 7, 100, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0 - 1.0) as f32).collect();
            let mut w = Welford::default();
            for &v in &xs {
                w.push(v);
            }
            let mu: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            let var: f64 =
                xs.iter().map(|&v| (v as f64 - mu) * (v as f64 - mu)).sum::<f64>() / n as f64;
            let absmax = xs.iter().fold(0f32, |a, &v| a.max(v.abs()));
            assert_eq!(w.count(), n as u64);
            assert!((w.mean() - mu).abs() <= 1e-9 * mu.abs().max(1.0), "n={n}");
            if n >= 2 {
                assert!((w.var() - var).abs() <= 1e-9 * var.max(1.0), "n={n}");
            }
            assert_eq!(w.absmax(), absmax);
        }
    }

    #[test]
    fn envelope_outlier_boundary_is_exact() {
        let env = Envelope { absmax: 2.0, count: 10, ..Default::default() };
        let edge = 2.0 * OUTLIER_TOL;
        assert!(!env.is_outlier(edge), "a row exactly at the tolerance is in-envelope");
        assert!(env.is_outlier(edge + edge * 1e-6));
        assert!(!env.is_outlier(0.0));
        // no envelope -> nothing is an outlier
        let none = Envelope::default();
        assert!(!none.is_outlier(f32::MAX));
    }

    #[test]
    fn drift_detector_hysteresis_no_flap() {
        let cfg = DriftConfig::default();
        let mut det = DriftDetector::default();
        // oscillating input straddling both thresholds must never arm
        for _ in 0..50 {
            assert_eq!(det.observe(0.9, &cfg), None);
            assert_eq!(det.observe(0.0, &cfg), None);
            assert!(!det.drifting());
        }
        // mid-band input (between exit and enter) also never transitions
        for _ in 0..50 {
            assert_eq!(det.observe(0.3, &cfg), None);
        }
        // sustained high enters after `arm` windows, exactly once
        assert_eq!(det.observe(0.8, &cfg), None);
        assert_eq!(det.observe(0.8, &cfg), Some(true));
        assert_eq!(det.observe(0.8, &cfg), None);
        assert!(det.drifting());
        // mid-band while drifting holds the state
        for _ in 0..10 {
            assert_eq!(det.observe(0.3, &cfg), None);
            assert!(det.drifting());
        }
        // sustained low exits after `arm` windows
        assert_eq!(det.observe(0.05, &cfg), None);
        assert_eq!(det.observe(0.0, &cfg), Some(false));
        assert!(!det.drifting());
    }

    #[test]
    fn record_and_snapshot_roundtrip() {
        let nh = NumericHealth::new(1);
        nh.install(
            vec![Envelope { absmax: 1.0, mean: 0.0, var: 1.0, count: 100, ..Default::default() }],
            4,
            Some(2),
        );
        assert!(nh.installed());
        // two rows: one inside the envelope, one outlier (2.0 > 1.0 * 1.25)
        let x = vec![0.5f32, -0.5, 2.0, 0.0];
        nh.record_rows(0, &x, 2, &[0, 1]);
        let snap = nh.snapshot();
        assert_eq!(snap.layers.len(), 1);
        let l = &snap.layers[0];
        assert_eq!(l.rows, 2);
        assert_eq!(l.count, 4);
        assert_eq!(l.outliers, 1);
        assert_eq!(l.verdict(), "ok");
        assert_eq!(l.absmax, 2.0);
        assert_eq!(snap.div.serve_bits, 4);
        assert_eq!(snap.div.draft_bits, 2);

        nh.record_divergence(true, 0.25, &[0.1, 0.2]);
        nh.record_divergence(false, 1.5, &[0.3, 0.1]);
        let d = nh.snapshot().div;
        assert_eq!(d.probes, 2);
        assert_eq!(d.agree, 1);
        assert_eq!(d.agree_pct(), 50.0);
        assert_eq!(d.max_logit_delta, 1.5);
        assert_eq!(d.group_delta, vec![0.3, 0.2]);
    }

    #[test]
    fn evaluate_emits_journal_transitions() {
        let journal = Journal::new(16);
        let nh = NumericHealth::new(1);
        nh.install(vec![Envelope { absmax: 0.1, count: 10, ..Default::default() }], 4, None);
        // every row is an outlier (1.0 > 0.1 * 1.25); two windows arm drift
        let x = vec![1.0f32; 8];
        for _ in 0..2 {
            for _ in 0..8 {
                nh.record_rows(0, &x, 8, &[0]);
            }
            nh.evaluate(&journal);
        }
        assert_eq!(nh.drift_layers(), 1);
        let events = journal.snapshot();
        assert!(
            events.iter().any(|e| e.kind == "numeric_drift" && e.detail.contains("entered")),
            "{events:?}"
        );
    }
}
