//! Sampled kernel timing, keyed by bit-width.
//!
//! The GEMM hot path cannot afford a clock read per call, let alone an
//! `Arc` to thread through `packed_gemm`'s call graph — so kernel timing
//! is a process-global, off by default, and *sampled*: when enabled, one
//! call in [`SAMPLE`] reads the clock. The timing path never touches the
//! math, so enabling it cannot perturb results (the bit-stability
//! contract), only add a bounded measurement cost.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use super::hist::Histogram;

/// 1-in-N sampling rate for kernel clock reads.
pub const SAMPLE: u64 = 8;

static ENABLED: AtomicBool = AtomicBool::new(false);
static TICKET: AtomicU64 = AtomicU64::new(0);
static STATS: OnceLock<KernelStats> = OnceLock::new();

/// Per-bit-width GEMM histograms plus the vocab-head projection (the
/// single most expensive per-token stage).
pub struct KernelStats {
    /// Indexed by [`bits_index`]: w2, w3, w4, w8, other.
    pub gemm: [Histogram; 5],
    pub head: Histogram,
}

pub const BITS_LABELS: [&str; 5] = ["2", "3", "4", "8", "other"];

#[inline]
pub fn bits_index(bits: u32) -> usize {
    match bits {
        2 => 0,
        3 => 1,
        4 => 2,
        8 => 3,
        _ => 4,
    }
}

pub fn stats() -> &'static KernelStats {
    STATS.get_or_init(|| KernelStats {
        gemm: std::array::from_fn(|_| Histogram::new()),
        head: Histogram::new(),
    })
}

/// Turn sampled kernel timing on or off (process-global).
pub fn enable(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// `Some(now)` if this call was sampled for timing; the common path is a
/// single relaxed load and no clock read.
#[inline]
pub fn sample_start() -> Option<Instant> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    if TICKET.fetch_add(1, Ordering::Relaxed) % SAMPLE != 0 {
        return None;
    }
    Some(Instant::now())
}

#[inline]
pub fn record_gemm(bits: u32, t0: Option<Instant>) {
    if let Some(t0) = t0 {
        stats().gemm[bits_index(bits)].record(t0.elapsed());
    }
}

#[inline]
pub fn record_head(t0: Option<Instant>) {
    if let Some(t0) = t0 {
        stats().head.record(t0.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test owns the process-global enable flag: the unit-test binary
    // runs tests on parallel threads, and other tests route through
    // `sample_start` (via packed_gemm), so cadence assertions are tolerant
    // of ticket draws racing with concurrent callers.
    #[test]
    fn sampling_gate_behaviour() {
        enable(false);
        // recording a None start is a no-op
        let before = stats().head.count();
        record_head(None);
        assert_eq!(stats().head.count(), before);
        assert!(!enabled());

        enable(true);
        let n = SAMPLE * 100;
        let mut hits: u64 = 0;
        for _ in 0..n {
            if let Some(t0) = sample_start() {
                hits += 1;
                record_gemm(4, Some(t0));
            }
        }
        enable(false);
        assert!(hits >= 1, "enabled sampling must fire");
        // concurrent callers share the ticket counter, so the exact cadence
        // races; sampling every single call would still mean it is broken
        assert!(hits < n, "sampling must thin the calls: {hits}/{n}");
        assert!(stats().gemm[bits_index(4)].count() >= hits);
    }

    #[test]
    fn bits_map_covers_packed_widths() {
        assert_eq!(bits_index(2), 0);
        assert_eq!(bits_index(3), 1);
        assert_eq!(bits_index(4), 2);
        assert_eq!(bits_index(8), 3);
        assert_eq!(bits_index(16), 4);
    }
}
