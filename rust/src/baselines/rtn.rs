//! Round-to-nearest: per-group asymmetric quantization of every linear
//! weight, no calibration data, no transforms. The floor every PTQ paper
//! measures against.

use anyhow::Result;

use crate::model::merge::{merge_block_weight_only, BlockTransforms, MergePrecision};
use crate::model::ParamStore;
use crate::quant::QuantSpec;
use crate::runtime::ModelRuntime;

pub fn quantize(rt: &ModelRuntime, fp: &ParamStore, spec: QuantSpec) -> Result<ParamStore> {
    let mut out = fp.clone();
    let bl = rt.block_layout.clone();
    let t = BlockTransforms::identity();
    for i in 0..rt.cfg.n_layers {
        merge_block_weight_only(&bl, out.block_mut(i), &t, spec, rt.cfg.n_heads, MergePrecision::F32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_dequant;

    // RTN through the merge path must equal direct quant_dequant.
    #[test]
    fn rtn_is_plain_qdq() {
        use crate::model::test_layout;
        use crate::rngx::Pcg32;
        use crate::tensor::Tensor;
        let bl = test_layout(vec![
            ("wq", vec![8, 8]),
            ("wk", vec![8, 8]),
            ("wv", vec![8, 8]),
            ("wo", vec![8, 8]),
            ("w1", vec![8, 16]),
            ("w2", vec![16, 8]),
        ]);
        let mut rng = Pcg32::seeded(3);
        let mut wb: Vec<f32> = (0..bl.size).map(|_| rng.normal() as f32).collect();
        let orig = wb.clone();
        let t = BlockTransforms::identity();
        let spec = QuantSpec::new(3, 0);
        crate::model::merge::merge_block_weight_only(&bl, &mut wb, &t, spec, 2, MergePrecision::F32);
        for name in ["wq", "wo", "w2"] {
            let w0 = bl.tensor(&orig, name);
            let want = quant_dequant(&w0, spec, None);
            let got = bl.tensor(&wb, name);
            assert_eq!(got, want, "{name}");
        }
    }
}
