//! FlexRound (Lee et al. 2023): learnable rounding via element-wise
//! division — `W_q = QDQ(W / exp(ls))·exp(ls)` with a per-element log-scale
//! `ls` (plus a per-weight global scale), optimized per block against the
//! same Eq.-4 MSE objective through the `calib_flex` artifact.
//!
//! The paper's Table 7 compares AffineQuant against FlexRound at w4a16;
//! this module is that comparator. It shares the coordinator's stream and
//! optimizer machinery but learns *rounding* rather than an equivalence
//! transform: no merge algebra is needed — the optimized element scales
//! directly produce the final quantized weights.

use anyhow::{bail, Result};

use crate::coordinator::stream;
use crate::model::ParamStore;
use crate::quant::QuantSpec;
use crate::runtime::{Arg, ModelRuntime};
use crate::train::Adam;

/// Optimize FlexRound element scales per block; returns the quantized model.
pub fn quantize(
    rt: &ModelRuntime,
    fp: &ParamStore,
    spec: QuantSpec,
    act_bits: u32,
) -> Result<ParamStore> {
    if act_bits < 16 {
        bail!("flexround baseline is weight-only (paper Table 7 is w4a16)");
    }
    let key = format!("flex_g{}", spec.group);
    let entry = format!("calib_{key}");
    if !rt.has_entry(&entry) {
        bail!("artifact {entry} missing — rebuild artifacts (make artifacts)");
    }
    let playout = rt.phi_layouts[&key].clone();
    let cfg = &rt.cfg;
    let batches = stream::calib_batches(cfg, 128, 1234);
    let mut xs = stream::embed_stream(rt, fp.globals(), &batches)?;
    let mut out = fp.clone();
    let qmax_w = [spec.qmax()];
    let epochs = 10;

    for i in 0..cfg.n_layers {
        let wb = fp.block(i).to_vec();
        let (yfp, _) = stream::capture_block(rt, &wb, &xs)?;
        // ls init 0 (exp = 1 ⇒ plain RTN starting point)
        let mut phi = vec![0.0f32; playout.size];
        let mut adam = Adam::new(playout.size, 1e-3);
        for _e in 0..epochs {
            for (x, y) in xs.iter().zip(&yfp) {
                let mut outs = rt.call(
                    &entry,
                    &[
                        Arg::F32(&x.data),
                        Arg::F32(&y.data),
                        Arg::F32(&wb),
                        Arg::F32(&phi),
                        Arg::F32(&qmax_w),
                    ],
                )?;
                let grad = outs.remove(1);
                let loss = outs.remove(0).data[0];
                if !loss.is_finite() {
                    bail!("flexround diverged at block {i}");
                }
                adam.step(&mut phi, &grad.data, 1.0);
            }
        }
        // materialize the final quantized weights through the wfq-style
        // artifact path: the flex entry also exposes them via `flex_apply`.
        let wq = rt.call(
            &format!("flex_apply_g{}", spec.group),
            &[Arg::F32(&wb), Arg::F32(&phi), Arg::F32(&qmax_w)],
        )?;
        out.block_mut(i).copy_from_slice(&wq[0].data);
        let wbm = out.block(i).to_vec();
        stream::advance(rt, &wbm, &mut xs, None)?;
    }
    Ok(out)
}
