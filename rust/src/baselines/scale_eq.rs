//! Diagonal scaling-equivalence baselines.
//!
//! * **SmoothQuant** (Xiao et al. 2023): fixed-exponent per-channel scale
//!   `s_j = max|X_j|^α / max|W_j|^{1-α}` (α = 0.5) at the LN→linear sites,
//!   folded into LN; used for the w4a4 comparison (Table 3).
//! * **AWQ** (Lin et al. 2023): the same scale family, but α grid-searched
//!   per site against the site's output MSE on calibration activations;
//!   weight-only (Tables 1/8-11).
//!
//! Both are strict subsets of the affine transform (diagonal `A`), which is
//! the paper's framing — they reuse the same merge machinery.

use anyhow::Result;

use crate::coordinator::block_opt::sq_scale;
use crate::coordinator::stream;
use crate::model::merge::{
    merge_block_a4, merge_block_weight_only, BlockTransforms, MergePrecision,
};
use crate::model::ParamStore;
use crate::quant::{quant_dequant, QuantSpec};
use crate::runtime::ModelRuntime;
use crate::tensor::Tensor;

fn site_wmax(bl: &crate::model::Layout, wb: &[f32], names: &[&str]) -> Vec<f32> {
    let mut out: Vec<f32> = Vec::new();
    for name in names {
        let w = bl.tensor(wb, name);
        let (din, dout) = w.dims2();
        if out.is_empty() {
            out = vec![0.0; din];
        }
        for r in 0..din {
            for c in 0..dout {
                out[r] = out[r].max(w.data[r * dout + c].abs());
            }
        }
    }
    out
}

fn fc1_names(opt: bool) -> &'static [&'static str] {
    if opt {
        &["w1"]
    } else {
        &["wg", "wu"]
    }
}

/// SmoothQuant: α = 0.5 diagonal scales at the two LN sites, zero shifts,
/// RTN for the out/fc2 weights; sequential over blocks with the quantized
/// activation stream.
pub fn smoothquant(
    rt: &ModelRuntime,
    fp: &ParamStore,
    spec: QuantSpec,
    act_bits: u32,
) -> Result<ParamStore> {
    let cfg = &rt.cfg;
    let opt = cfg.family == "opt";
    let batches = stream::calib_batches(cfg, 128, 1234);
    let mut xs = stream::embed_stream(rt, fp.globals(), &batches)?;
    let act_qmax = Some((1u64 << act_bits) as f32 - 1.0);
    let mut out = fp.clone();
    let bl = rt.block_layout.clone();
    for i in 0..cfg.n_layers {
        let wb = fp.block(i).to_vec();
        let (_, stats) = stream::capture_block(rt, &wb, &xs)?;
        let s_qkv = sq_scale(&stats["x_qkv"].absmax, &site_wmax(&bl, &wb, &["wq", "wk", "wv"]), 0.5);
        let s_fc1 = sq_scale(&stats["x_fc1"].absmax, &site_wmax(&bl, &wb, fc1_names(opt)), 0.5);
        let mut t = BlockTransforms::identity();
        let d = s_qkv.len();
        t.diag_qkv = Some((s_qkv, vec![0.0; d]));
        t.diag_fc1 = Some((s_fc1, vec![0.0; d]));
        merge_block_a4(&bl, out.block_mut(i), &t, spec, cfg.n_heads, MergePrecision::F32);
        let wbm = out.block(i).to_vec();
        stream::advance(rt, &wbm, &mut xs, act_qmax)?;
    }
    Ok(out)
}

/// Per-site AWQ objective: `Σ_w ‖X·W − (X/s)·QDQ(s⊙W)‖²` over a row
/// subsample of the captured activations.
fn awq_site_mse(x: &Tensor, ws: &[&Tensor], s: &[f32], spec: QuantSpec) -> f64 {
    let mut total = 0.0;
    for w in ws {
        let (din, dout) = w.dims2();
        let mut wt = (*w).clone();
        for r in 0..din {
            for c in 0..dout {
                wt.data[r * dout + c] *= s[r];
            }
        }
        let wq = quant_dequant(&wt, spec, None);
        // effective weight seen by the untransformed activation
        let mut weff = wq;
        for r in 0..din {
            for c in 0..dout {
                weff.data[r * dout + c] /= s[r];
            }
        }
        let y_fp = x.matmul(w);
        let y_q = x.matmul(&weff);
        total += y_fp.mse(&y_q);
    }
    total
}

/// AWQ: grid-search α ∈ {0, 0.05, …, 1.0} per site, apply the best scale as
/// a diagonal affine, then weight-only merge (Q(s⊙W) with s⁻¹ folded back).
pub fn awq(
    rt: &ModelRuntime,
    fp: &ParamStore,
    spec: QuantSpec,
    _act_bits: u32,
) -> Result<ParamStore> {
    let cfg = &rt.cfg;
    let opt = cfg.family == "opt";
    let batches = stream::calib_batches(cfg, 128, 1234);
    let mut xs = stream::embed_stream(rt, fp.globals(), &batches)?;
    let mut out = fp.clone();
    let bl = rt.block_layout.clone();
    let grid: Vec<f32> = (0..=20).map(|i| i as f32 * 0.05).collect();

    for i in 0..cfg.n_layers {
        let wb = fp.block(i).to_vec();
        let (_, stats) = stream::capture_block(rt, &wb, &xs)?;
        // row-subsampled activation views for the search objective
        let mut samples: Vec<Option<Tensor>> = vec![None; 3];
        stream::for_each_capture(rt, &wb, &xs[..1], |caps| {
            for (si, ci) in [(0usize, 0usize), (1, 1), (2, 2)] {
                let r = stream::rows2d(&caps[ci]);
                let keep = r.shape[0].min(128);
                samples[si] =
                    Some(Tensor::new(vec![keep, r.shape[1]], r.data[..keep * r.shape[1]].to_vec()));
            }
        })?;

        let sites: [(&str, Vec<&str>, usize); 3] = [
            ("x_qkv", vec!["wq", "wk", "wv"], 0),
            ("x_ctx", vec!["wo"], 1),
            ("x_fc1", fc1_names(opt).to_vec(), 2),
        ];
        let mut t = BlockTransforms::identity();
        for (stat_name, wnames, si) in sites {
            let wmax = site_wmax(&bl, &wb, &wnames);
            let actmax = &stats[stat_name].absmax;
            let ws: Vec<Tensor> = wnames.iter().map(|n| bl.tensor(&wb, n)).collect();
            let wrefs: Vec<&Tensor> = ws.iter().collect();
            let x = samples[si].as_ref().unwrap();
            let mut best = (f64::INFINITY, vec![1.0f32; wmax.len()]);
            for &a in &grid {
                let s = sq_scale(actmax, &wmax, a);
                let mse = awq_site_mse(x, &wrefs, &s, spec);
                if mse < best.0 {
                    best = (mse, s);
                }
            }
            let s = best.1;
            match stat_name {
                "x_qkv" => t.a_qkv = Some(diag_tensor(&s)),
                "x_fc1" => t.a_fc1 = Some(diag_tensor(&s)),
                "x_ctx" => {
                    let (h, hd) = (cfg.n_heads, cfg.head_dim);
                    let mut ao = Tensor::zeros(&[h, hd, hd]);
                    for hi in 0..h {
                        for k in 0..hd {
                            ao.data[hi * hd * hd + k * hd + k] = s[hi * hd + k];
                        }
                    }
                    t.a_out = Some(ao);
                }
                _ => unreachable!(),
            }
        }
        merge_block_weight_only(&bl, out.block_mut(i), &t, spec, cfg.n_heads, MergePrecision::F32);
        let wbm = out.block(i).to_vec();
        stream::advance(rt, &wbm, &mut xs, None)?;
    }
    Ok(out)
}

fn diag_tensor(s: &[f32]) -> Tensor {
    let n = s.len();
    let mut t = Tensor::zeros(&[n, n]);
    for i in 0..n {
        t.data[i * n + i] = s[i];
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg32;

    #[test]
    fn awq_objective_prefers_outlier_aware_scale() {
        // one activation channel with big outliers: scaling it down before
        // quantization must reduce the objective vs s = 1
        let mut rng = Pcg32::seeded(5);
        let mut x = Tensor::randn(&[64, 8], 1.0, &mut rng);
        for r in 0..64 {
            x.data[r * 8] *= 50.0; // channel-0 outliers
        }
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let spec = QuantSpec::new(3, 0);
        let ones = vec![1.0f32; 8];
        let mut s = ones.clone();
        s[0] = 8.0; // shrink activation channel 0 by 8, grow weight row 0
        let m_base = awq_site_mse(&x, &[&w], &ones, spec);
        let m_scaled = awq_site_mse(&x, &[&w], &s, spec);
        // scaling a weight row up hurts weight quant but the objective is
        // activation-free here; it must at least change the result
        assert_ne!(m_base, m_scaled);
    }

    #[test]
    fn diag_tensor_layout() {
        let t = diag_tensor(&[1.0, 2.0]);
        assert_eq!(t.data, vec![1.0, 0.0, 0.0, 2.0]);
    }
}
