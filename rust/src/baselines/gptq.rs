//! GPTQ (Frantar et al. 2022): error-compensating rounding driven by the
//! Cholesky factorization of the inverse input Hessian `H = Xᵀ X`.
//!
//! Adapted to this codebase's row-major `(in, out)` weight layout: the
//! algorithm walks input rows in order; after quantizing row `i`, the
//! remaining rows absorb the rounding error weighted by the Cholesky
//! factor of `H⁻¹` — exactly the OBS update GPTQ derives.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::coordinator::stream;
use crate::linalg::{cholesky, spd_inverse};
use crate::model::ParamStore;
use crate::quant::{QuantSpec, EPS};
use crate::runtime::ModelRuntime;
use crate::tensor::Tensor;

/// Quantize one (in, out) weight with GPTQ given the site Hessian.
pub fn gptq_weight(w: &Tensor, hess: &[f64], spec: QuantSpec) -> Result<Tensor> {
    let (din, dout) = w.dims2();
    assert_eq!(hess.len(), din * din);
    let g = spec.group_len(din);
    let qmax = spec.qmax();

    // Damped Hessian -> H^{-1} -> upper Cholesky factor U (Hinv = Uᵀ U).
    let mean_diag: f64 = (0..din).map(|i| hess[i * din + i]).sum::<f64>() / din as f64;
    let mut damp = 0.01 * mean_diag.max(1e-12);
    let u = loop {
        let mut h = hess.to_vec();
        for i in 0..din {
            h[i * din + i] += damp;
        }
        if let Some(hinv) = spd_inverse(&h, din) {
            if let Some(l) = cholesky(&hinv, din) {
                // want upper U with Hinv = Uᵀ U given Hinv = L Lᵀ ⇒ U = Lᵀ
                let mut u = vec![0.0f64; din * din];
                for i in 0..din {
                    for j in 0..=i {
                        u[j * din + i] = l[i * din + j];
                    }
                }
                break u;
            }
        }
        damp *= 10.0;
        if damp > 1e6 * mean_diag.max(1.0) {
            bail!("gptq: Hessian not invertible even with damping");
        }
    };

    let mut wq = w.clone();
    let mut scale = vec![EPS; dout];
    let mut zp = vec![0.0f32; dout];
    for i in 0..din {
        if i % g == 0 {
            // group parameters from the *current* (error-compensated) rows
            for c in 0..dout {
                let mut mn = f32::INFINITY;
                let mut mx = f32::NEG_INFINITY;
                for r in i..(i + g).min(din) {
                    let v = wq.data[r * dout + c];
                    mn = mn.min(v);
                    mx = mx.max(v);
                }
                scale[c] = ((mx - mn) / qmax).max(EPS);
                zp[c] = (-mn / scale[c]).round();
            }
        }
        let d = u[i * din + i] as f32;
        let mut err = vec![0.0f32; dout];
        for c in 0..dout {
            let v = wq.data[i * dout + c];
            let q = ((v / scale[c]).round() + zp[c]).clamp(0.0, qmax);
            let dq = (q - zp[c]) * scale[c];
            err[c] = (v - dq) / d.max(1e-12);
            wq.data[i * dout + c] = dq;
        }
        // propagate the rounding error into the not-yet-quantized rows
        for j in i + 1..din {
            let f = u[i * din + j] as f32;
            if f != 0.0 {
                for c in 0..dout {
                    wq.data[j * dout + c] -= f * err[c];
                }
            }
        }
    }
    Ok(wq)
}

/// Which capture feeds each quantized weight's Hessian.
fn site_of(name: &str) -> &'static str {
    match name {
        "wq" | "wk" | "wv" => "x_qkv",
        "wo" => "x_ctx",
        "w1" | "wg" | "wu" => "x_fc1",
        "w2" | "wd" => "x_fc2",
        other => panic!("gptq: unknown weight {other}"),
    }
}

/// Full-model GPTQ: sequential blocks on the quantized stream.
pub fn quantize(
    rt: &ModelRuntime,
    fp: &ParamStore,
    spec: QuantSpec,
    act_bits: u32,
) -> Result<ParamStore> {
    let cfg = &rt.cfg;
    let batches = stream::calib_batches(cfg, 128, 1234);
    let mut xs = stream::embed_stream(rt, fp.globals(), &batches)?;
    let act_qmax =
        if act_bits >= 16 { None } else { Some((1u64 << act_bits) as f32 - 1.0) };
    let mut out = fp.clone();
    let bl = rt.block_layout.clone();

    for i in 0..cfg.n_layers {
        let wb = fp.block(i).to_vec();
        // accumulate Hessians per capture site in f64
        let mut hess: HashMap<&'static str, Vec<f64>> = HashMap::new();
        let slow = std::env::var("AQ_GPTQ_SLOW_HESS").is_ok();
        stream::for_each_capture(rt, &wb, &xs, |caps| {
            for (ci, cname) in stream::CAPTURE_NAMES.iter().enumerate() {
                let x = stream::rows2d(&caps[ci]);
                let (rows, d) = x.dims2();
                let h = hess.entry(cname).or_insert_with(|| vec![0.0f64; d * d]);
                if slow {
                    // reference scalar path (§Perf before-measurement)
                    for r in 0..rows {
                        let row = x.row(r);
                        for a in 0..d {
                            let va = row[a] as f64;
                            if va != 0.0 {
                                let hrow = &mut h[a * d..(a + 1) * d];
                                for b in a..d {
                                    hrow[b] += va * row[b] as f64;
                                }
                            }
                        }
                    }
                } else {
                    // batch Gram matrix through the blocked matmul kernel
                    // (vectorized + cache-blocked), accumulated in f64
                    let g = x.matmul_at(&x);
                    for (hv, &gv) in h.iter_mut().zip(&g.data) {
                        *hv += gv as f64;
                    }
                }
            }
        })?;
        if slow {
            for h in hess.values_mut() {
                let d = (h.len() as f64).sqrt() as usize;
                for a in 0..d {
                    for b in 0..a {
                        h[a * d + b] = h[b * d + a];
                    }
                }
            }
        }

        let wbm = out.block_mut(i);
        for (name, _, _) in bl.entries.clone() {
            if cfg.quantized_weights().iter().any(|(n, _, _)| *n == name) {
                let w = bl.tensor(wbm, &name);
                let wq = gptq_weight(&w, &hess[site_of(&name)], spec)?;
                bl.set(wbm, &name, &wq);
            }
        }
        let wbm = out.block(i).to_vec();
        stream::advance(rt, &wbm, &mut xs, act_qmax)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_dequant;
    use crate::rngx::Pcg32;

    fn hessian(x: &Tensor) -> Vec<f64> {
        let (rows, d) = x.dims2();
        let mut h = vec![0.0f64; d * d];
        for r in 0..rows {
            for a in 0..d {
                for b in 0..d {
                    h[a * d + b] += (x.data[r * d + a] * x.data[r * d + b]) as f64;
                }
            }
        }
        h
    }

    /// GPTQ must beat RTN on the output-MSE objective it optimizes.
    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        let mut rng = Pcg32::seeded(11);
        let x = Tensor::randn(&[256, 32], 1.0, &mut rng);
        let w = Tensor::randn(&[32, 16], 1.0, &mut rng);
        let spec = QuantSpec::new(3, 0);
        let h = hessian(&x);
        let wq_gptq = gptq_weight(&w, &h, spec).unwrap();
        let wq_rtn = quant_dequant(&w, spec, None);
        let y = x.matmul(&w);
        let e_gptq = y.mse(&x.matmul(&wq_gptq));
        let e_rtn = y.mse(&x.matmul(&wq_rtn));
        assert!(e_gptq < e_rtn, "gptq {e_gptq} vs rtn {e_rtn}");
    }

    /// Grouped GPTQ keeps codes representable (dequantized values in the
    /// clip range implied by per-group scale).
    #[test]
    fn gptq_grouped_runs_and_bounds() {
        let mut rng = Pcg32::seeded(12);
        let x = Tensor::randn(&[128, 64], 1.0, &mut rng);
        let w = Tensor::randn(&[64, 8], 1.0, &mut rng);
        let h = hessian(&x);
        for group in [0usize, 32, 64] {
            let wq = gptq_weight(&w, &h, QuantSpec::new(2, group)).unwrap();
            assert_eq!(wq.shape, w.shape);
            assert!(wq.data.iter().all(|v| v.is_finite()));
        }
    }

    /// With a (near-)identity Hessian there is no cross-row interaction and
    /// GPTQ degenerates to RTN row-wise (up to group-stat drift).
    #[test]
    fn identity_hessian_first_row_matches_rtn() {
        let mut rng = Pcg32::seeded(13);
        let w = Tensor::randn(&[16, 4], 1.0, &mut rng);
        let mut h = vec![0.0f64; 16 * 16];
        for i in 0..16 {
            h[i * 16 + i] = 1.0;
        }
        let spec = QuantSpec::new(4, 0);
        let wq = gptq_weight(&w, &h, spec).unwrap();
        let rtn = quant_dequant(&w, spec, None);
        for c in 0..4 {
            assert!((wq.at2(0, c) - rtn.at2(0, c)).abs() < 1e-6);
        }
    }
}
