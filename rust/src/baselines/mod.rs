//! Comparison baselines (the paper's method set):
//!
//! * [`rtn`] — round-to-nearest, no calibration.
//! * [`scale_eq`] — SmoothQuant (fixed-alpha diagonal scaling, w4a4) and
//!   AWQ (grid-searched diagonal scaling, weight-only).
//! * [`gptq`] — Hessian-based error-compensating rounding.
//! * OmniQuant — [`crate::coordinator::CalibOptions::omniquant`], i.e. the
//!   AffineQuant coordinator restricted to diagonal transforms.
//! * [`flexround`] — learnable element-wise division rounding (Table 7).

pub mod flexround;
pub mod gptq;
pub mod rtn;
pub mod scale_eq;

use anyhow::Result;

use crate::coordinator::CalibOptions;
use crate::model::ParamStore;
use crate::quant::QuantSpec;
use crate::runtime::ModelRuntime;

/// All baseline method names in the paper's table order.
pub const METHODS_WEIGHT_ONLY: [&str; 5] = ["rtn", "gptq", "awq", "omniquant", "affinequant"];
pub const METHODS_W4A4: [&str; 4] = ["smoothquant", "omniquant", "affinequant", "fp16"];

/// Quantize `fp` with the named method. A single entry point so the table
/// benches can sweep method × config uniformly.
pub fn quantize_with(
    rt: &ModelRuntime,
    fp: &ParamStore,
    method: &str,
    spec: QuantSpec,
    act_bits: u32,
    alpha: f32,
) -> Result<ParamStore> {
    match method {
        "rtn" => rtn::quantize(rt, fp, spec),
        "gptq" => gptq::quantize(rt, fp, spec, act_bits),
        "awq" => scale_eq::awq(rt, fp, spec, act_bits),
        "smoothquant" => scale_eq::smoothquant(rt, fp, spec, act_bits),
        "omniquant" => {
            let opts = CalibOptions::omniquant(spec, act_bits);
            Ok(crate::coordinator::calibrate(rt, fp, &opts, false)?.0)
        }
        "affinequant" => {
            let mut opts = CalibOptions::affinequant(spec, act_bits);
            opts.alpha = alpha;
            Ok(crate::coordinator::calibrate(rt, fp, &opts, false)?.0)
        }
        "flexround" => flexround::quantize(rt, fp, spec, act_bits),
        other => anyhow::bail!("unknown method {other:?}"),
    }
}
