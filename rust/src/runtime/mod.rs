//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! them on the CPU PJRT client. This is the only place Layer 3 touches XLA;
//! everything above works with host [`Tensor`]s.
//!
//! The artifact manifest (`artifacts/manifest.json`) drives everything:
//! per-model entry points with input/output names, dtypes and shapes, plus
//! the flat-vector layouts (`theta`/`wb`/`phi`) shared with the L2 graphs.
//! Executables compile lazily on first use and are cached for the process
//! lifetime (compilation is the expensive part; execution is the hot path).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::jsonx::{self, Value};
use crate::model::{Layout, ModelConfig};
use crate::tensor::{numel, Tensor};
use crate::util::Timer;

/// One typed argument for an entry point.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Manifest metadata for one AOT entry point.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    /// (name, dtype, shape) per input, in call order.
    pub inputs: Vec<(String, String, Vec<usize>)>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// The compiled-executable registry for one model.
pub struct ModelRuntime {
    pub cfg: ModelConfig,
    pub globals_layout: Layout,
    pub block_layout: Layout,
    pub theta_size: usize,
    /// phi layouts per calibration mode key ("w_g0", "w_g64", "w_g128", "a4").
    pub phi_layouts: HashMap<String, Layout>,
    /// LWC layouts per group key ("g0", "g64", "g128").
    pub lwc_layouts: HashMap<String, Layout>,
    entries: HashMap<String, EntryMeta>,
    client: Rc<xla::PjRtClient>,
    root: String,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// (executions, total seconds) per entry — perf accounting.
    stats: RefCell<HashMap<String, (u64, f64)>>,
}

/// The top-level runtime: one PJRT client + per-model registries.
pub struct Runtime {
    client: Rc<xla::PjRtClient>,
    manifest: Value,
    root: String,
}

impl Runtime {
    /// Connect the CPU PJRT client and parse `<root>/manifest.json`.
    pub fn load(root: &str) -> Result<Self> {
        let client = Rc::new(xla::PjRtClient::cpu()?);
        let text = std::fs::read_to_string(format!("{root}/manifest.json"))
            .with_context(|| format!("reading {root}/manifest.json — run `make artifacts`"))?;
        let manifest = jsonx::parse(&text).map_err(|e| anyhow::anyhow!(e))?;
        Ok(Runtime { client, manifest, root: root.to_string() })
    }

    pub fn model_names(&self) -> Vec<String> {
        self.manifest
            .req("models")
            .as_obj()
            .iter()
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Build the executable registry for one model (lazy compilation).
    pub fn model(&self, name: &str) -> Result<ModelRuntime> {
        let m = self
            .manifest
            .req("models")
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))?;
        let cfg = ModelConfig::from_manifest(m.req("config"));
        let globals_layout = Layout::from_manifest(m.req("globals_layout"));
        let block_layout = Layout::from_manifest(m.req("block_layout"));
        let theta_size = m.req("theta_size").as_usize();

        let mut phi_layouts = HashMap::new();
        for (k, v) in m.req("phi_layouts").as_obj() {
            phi_layouts.insert(k.clone(), Layout::from_manifest(v.req("entries")));
        }
        let mut lwc_layouts = HashMap::new();
        for (k, v) in m.req("lwc_layouts").as_obj() {
            lwc_layouts.insert(k.clone(), Layout::from_manifest(v.req("entries")));
        }

        let mut entries = HashMap::new();
        for (ename, e) in m.req("entries").as_obj() {
            let inputs = e
                .req("inputs")
                .as_arr()
                .iter()
                .map(|i| {
                    (
                        i.req("name").as_str().to_string(),
                        i.req("dtype").as_str().to_string(),
                        i.req("shape").usize_arr(),
                    )
                })
                .collect();
            let output_shapes = e
                .req("outputs")
                .as_arr()
                .iter()
                .map(|o| o.req("shape").usize_arr())
                .collect();
            entries.insert(
                ename.clone(),
                EntryMeta {
                    name: ename.clone(),
                    file: e.req("file").as_str().to_string(),
                    inputs,
                    output_shapes,
                },
            );
        }

        Ok(ModelRuntime {
            cfg,
            globals_layout,
            block_layout,
            theta_size,
            phi_layouts,
            lwc_layouts,
            entries,
            client: Rc::clone(&self.client),
            root: self.root.clone(),
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }
}

impl ModelRuntime {
    pub fn entry(&self, name: &str) -> &EntryMeta {
        self.entries
            .get(name)
            .unwrap_or_else(|| panic!("no entry {name:?} for model {}", self.cfg.name))
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Compile (or fetch the cached) executable for `entry`.
    fn executable(&self, entry: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(entry) {
            return Ok(Rc::clone(exe));
        }
        let meta = self.entry(entry);
        let path = format!("{}/{}", self.root, meta.file);
        let t = Timer::start();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path}"))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {path}"))?;
        let exe = Rc::new(exe);
        if std::env::var("AQ_VERBOSE").is_ok() {
            eprintln!("[runtime] compiled {entry} in {:.2}s", t.secs());
        }
        self.exes.borrow_mut().insert(entry.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an entry point. Inputs are validated against the manifest;
    /// outputs come back as host tensors in manifest order.
    pub fn call(&self, entry: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let meta = self.entry(entry).clone();
        if args.len() != meta.inputs.len() {
            bail!(
                "{entry}: {} args given, expects {} ({:?})",
                args.len(),
                meta.inputs.len(),
                meta.inputs.iter().map(|(n, _, _)| n).collect::<Vec<_>>()
            );
        }
        let mut lits = Vec::with_capacity(args.len());
        for (arg, (iname, dtype, shape)) in args.iter().zip(&meta.inputs) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = match (arg, dtype.as_str()) {
                (Arg::F32(v), "float32") => {
                    check_len(entry, iname, v.len(), shape)?;
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                (Arg::I32(v), "int32") => {
                    check_len(entry, iname, v.len(), shape)?;
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                (_, want) => bail!("{entry}: input {iname} expects dtype {want}"),
            };
            lits.push(lit);
        }
        let exe = self.executable(entry)?;
        let t = Timer::start();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        {
            let mut stats = self.stats.borrow_mut();
            let e = stats.entry(entry.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += t.secs();
        }
        // All entries are lowered with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != meta.output_shapes.len() {
            bail!(
                "{entry}: got {} outputs, manifest says {}",
                parts.len(),
                meta.output_shapes.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.into_iter().zip(&meta.output_shapes) {
            let data = lit.to_vec::<f32>()?;
            if data.len() != numel(shape) {
                bail!("{entry}: output numel {} != manifest shape {shape:?}", data.len());
            }
            outs.push(Tensor::new(shape.clone(), data));
        }
        Ok(outs)
    }

    /// Per-entry (calls, total_secs) accounting since process start.
    pub fn stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, (n, s))| (k.clone(), *n, *s))
            .collect();
        v.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        v
    }

    // ------------------------------------------------- common entry sugar

    /// `embed(tokens, globals) -> hidden (B, S, d)`.
    pub fn embed(&self, tokens: &[i32], globals: &[f32]) -> Result<Tensor> {
        Ok(self.call("embed", &[Arg::I32(tokens), Arg::F32(globals)])?.remove(0))
    }

    /// `head_nll(hidden, targets, mask, globals) -> per-sequence NLL (B,)`.
    pub fn head_nll(
        &self,
        hidden: &Tensor,
        targets: &[i32],
        mask: &[f32],
        globals: &[f32],
    ) -> Result<Tensor> {
        Ok(self
            .call(
                "head_nll",
                &[Arg::F32(&hidden.data), Arg::I32(targets), Arg::F32(mask), Arg::F32(globals)],
            )?
            .remove(0))
    }

    /// FP block forward: `block_fp(x, wb) -> y`.
    pub fn block_fp(&self, x: &Tensor, wb: &[f32]) -> Result<Tensor> {
        Ok(self.call("block_fp", &[Arg::F32(&x.data), Arg::F32(wb)])?.remove(0))
    }

    /// w?a4 block forward with per-token activation fake-quant.
    pub fn block_a4(&self, x: &Tensor, wb: &[f32], qmax_a: f32) -> Result<Tensor> {
        Ok(self
            .call("block_a4", &[Arg::F32(&x.data), Arg::F32(wb), Arg::F32(&[qmax_a])])?
            .remove(0))
    }

    /// FP block forward + captured linear inputs:
    /// `(y, x_qkv, x_ctx, x_fc1, x_fc2)`.
    pub fn block_capture(&self, x: &Tensor, wb: &[f32]) -> Result<Vec<Tensor>> {
        self.call("block_capture", &[Arg::F32(&x.data), Arg::F32(wb)])
    }

    /// Weight fake-quant of a whole flat block through the pallas kernel.
    pub fn wfq(&self, group: usize, wb: &[f32], lwc: &[f32], qmax_w: f32) -> Result<Tensor> {
        Ok(self
            .call(
                &format!("wfq_g{group}"),
                &[Arg::F32(wb), Arg::F32(lwc), Arg::F32(&[qmax_w])],
            )?
            .remove(0))
    }

    /// One LM training step: `(loss, grad)`.
    pub fn train_step(
        &self,
        tokens: &[i32],
        targets: &[i32],
        theta: &[f32],
    ) -> Result<(f64, Tensor)> {
        let mut outs =
            self.call("train_step", &[Arg::I32(tokens), Arg::I32(targets), Arg::F32(theta)])?;
        let grad = outs.remove(1);
        let loss = outs.remove(0).data[0] as f64;
        Ok((loss, grad))
    }
}

fn check_len(entry: &str, iname: &str, got: usize, shape: &[usize]) -> Result<()> {
    if got != numel(shape) {
        bail!("{entry}: input {iname} has {got} elements, manifest shape {shape:?}");
    }
    Ok(())
}
