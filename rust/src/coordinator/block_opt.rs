//! Per-block calibration: the Adam loop over the affine/shift/LWC
//! learnables `phi`, driven by the AOT `calib_*` artifact (which returns
//! the paper's Eq. 4 block-MSE loss and `d loss / d phi` with the Gradual
//! Mask folded in).

use anyhow::Result;

use crate::coordinator::mask::MaskSchedule;
use crate::coordinator::stability;
use crate::coordinator::stream::SiteStats;
use crate::model::merge::BlockTransforms;
use crate::model::{Layout, ModelConfig};
use crate::quant::QuantSpec;
use crate::runtime::{Arg, ModelRuntime};
use crate::tensor::Tensor;
use crate::train::Adam;

/// Calibration configuration (one quantization run).
#[derive(Clone, Debug)]
pub struct CalibOptions {
    /// Weight quantization spec (bits + group size).
    pub spec: QuantSpec,
    /// Activation bits; 16 ⇒ weight-only mode, 4 ⇒ w?a4 mode.
    pub act_bits: u32,
    /// Target epochs `t` of the gradual mask.
    pub epochs: usize,
    /// Stability factor `alpha` (Eq. 6).
    pub alpha: f32,
    /// Adam LR on the affine entries.
    pub lr: f32,
    /// Adam LR on the LWC / shift entries.
    pub lr_lwc: f32,
    /// `false` ⇒ diagonal-only (the OmniQuant baseline / alpha→0 limit).
    pub full_affine: bool,
    /// `false` ⇒ whole band live from epoch 1 (Table 6 ablation).
    pub gradual: bool,
    /// Optional SDD re-projection after every epoch (extension).
    pub project_sdd: bool,
    /// Calibration segments (paper: 128).
    pub n_calib: usize,
    /// SmoothQuant init exponent for the diagonal.
    pub sq_alpha: f32,
    /// Numerical scheme of the final inverse+merge (paper Table 4).
    pub prec: crate::model::merge::MergePrecision,
    pub seed: u64,
}

impl CalibOptions {
    pub fn affinequant(spec: QuantSpec, act_bits: u32) -> Self {
        // `AQ_EPOCHS` / `AQ_NCALIB` scale every sweep (bench fast-mode).
        let env_usize = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        CalibOptions {
            spec,
            act_bits,
            epochs: env_usize("AQ_EPOCHS", 10),
            alpha: 0.1,
            lr: 5e-3,
            lr_lwc: 1e-2,
            full_affine: true,
            gradual: true,
            project_sdd: false,
            n_calib: env_usize("AQ_NCALIB", 128),
            sq_alpha: 0.5,
            prec: crate::model::merge::MergePrecision::F32InvF64,
            seed: 1234,
        }
    }

    /// OmniQuant = AffineQuant restricted to the diagonal (paper §3.2:
    /// "as alpha approaches 0 ... equivalent to OmniQuant").
    pub fn omniquant(spec: QuantSpec, act_bits: u32) -> Self {
        CalibOptions { full_affine: false, ..Self::affinequant(spec, act_bits) }
    }

    pub fn weight_only(&self) -> bool {
        self.act_bits >= 16
    }

    /// Manifest key of the phi layout / calib entry for this run.
    pub fn mode_key(&self) -> String {
        if self.weight_only() {
            format!("w_g{}", self.spec.group)
        } else {
            "a4".to_string()
        }
    }

    pub fn schedule(&self) -> MaskSchedule {
        MaskSchedule {
            alpha: self.alpha,
            epochs: self.epochs,
            full_affine: self.full_affine,
            gradual: self.gradual,
        }
    }

    pub fn label(&self) -> String {
        self.spec.label(self.act_bits)
    }
}

/// Outcome of one block's optimization.
pub struct BlockResult {
    /// Mean Eq.-4 loss per epoch (Fig. 3 curves).
    pub loss_curve: Vec<f64>,
    /// Minimum SDD margin across sites per epoch (Fig. 7 evidence).
    pub sdd_margins: Vec<f32>,
    /// Final (masked) transforms, merge-ready.
    pub transforms: BlockTransforms,
    /// True if the loss went NaN (Table 5's collapse rows).
    pub diverged: bool,
    pub final_loss: f64,
}

/// SmoothQuant-style diagonal init: `s_j = actmax_j^a / wmax_j^(1-a)`,
/// clamped for numerical sanity.
pub fn sq_scale(actmax: &[f32], wmax: &[f32], a: f32) -> Vec<f32> {
    actmax
        .iter()
        .zip(wmax)
        .map(|(&x, &w)| {
            let s = x.max(1e-5).powf(a) / w.max(1e-5).powf(1.0 - a);
            s.clamp(1e-2, 1e2)
        })
        .collect()
}

/// Per-input-channel max |W| across all weights sharing a site.
fn site_wmax(bl: &Layout, wb: &[f32], names: &[&str]) -> Vec<f32> {
    let mut out: Vec<f32> = Vec::new();
    for name in names {
        let w = bl.tensor(wb, name);
        let (din, dout) = w.dims2();
        if out.is_empty() {
            out = vec![0.0; din];
        }
        for r in 0..din {
            for c in 0..dout {
                out[r] = out[r].max(w.data[r * dout + c].abs());
            }
        }
    }
    out
}

/// Initialize phi: SmoothQuant scales on the diagonals, OS+ shifts, open
/// LWC logits. The affine matrices start diagonal — strictly diagonally
/// dominant by construction (Levy-Desplanques holds at epoch 0).
pub fn init_phi(
    cfg: &ModelConfig,
    playout: &Layout,
    bl: &Layout,
    wb: &[f32],
    stats: &SiteStats,
    opts: &CalibOptions,
) -> Vec<f32> {
    let mut phi = vec![0.0f32; playout.size];
    let opt_family = cfg.family == "opt";
    let qkv_w = site_wmax(bl, wb, &["wq", "wk", "wv"]);
    let fc1_names: &[&str] = if opt_family { &["w1"] } else { &["wg", "wu"] };
    let fc1_w = site_wmax(bl, wb, fc1_names);
    let out_w = site_wmax(bl, wb, &["wo"]);

    let use_shift = !opts.weight_only() && opt_family && playout.has("delta_qkv");
    let qkv_stats = &stats["x_qkv"];
    let fc1_stats = &stats["x_fc1"];
    let (qkv_act, fc1_act) = if use_shift {
        (qkv_stats.shifted_absmax(), fc1_stats.shifted_absmax())
    } else {
        (qkv_stats.absmax.clone(), fc1_stats.absmax.clone())
    };
    let s_qkv = sq_scale(&qkv_act, &qkv_w, opts.sq_alpha);
    let s_fc1 = sq_scale(&fc1_act, &fc1_w, opts.sq_alpha);
    let s_out = sq_scale(&stats["x_ctx"].absmax, &out_w, opts.sq_alpha);

    for (name, shape, _) in playout.entries.clone() {
        let r = playout.range(&name);
        match name.as_str() {
            "A_qkv" => set_diag(&mut phi[r], shape[0], &s_qkv),
            "A_fc1" => set_diag(&mut phi[r], shape[0], &s_fc1),
            "a_qkv" => phi[r].copy_from_slice(&s_qkv),
            "a_fc1" => phi[r].copy_from_slice(&s_fc1),
            "A_out" => {
                let (h, hd) = (shape[0], shape[1]);
                for hi in 0..h {
                    let s = r.start + hi * hd * hd;
                    set_diag(&mut phi[s..s + hd * hd], hd, &s_out[hi * hd..(hi + 1) * hd]);
                }
            }
            "delta_qkv" => phi[r].copy_from_slice(&qkv_stats.shift()),
            "delta_fc1" => phi[r].copy_from_slice(&fc1_stats.shift()),
            _ if name.starts_with("lwc_") => phi[r].fill(4.0), // sigmoid≈0.982
            _ => panic!("init_phi: unknown entry {name}"),
        }
    }
    phi
}

fn set_diag(a: &mut [f32], n: usize, vals: &[f32]) {
    for i in 0..n {
        a[i * n + i] = vals[i];
    }
}

/// Per-element Adam LR scale: affine entries get `1`, LWC/shift entries
/// get `lr_lwc / lr` (one Adam instance, two effective rates).
fn lr_scales(playout: &Layout, opts: &CalibOptions) -> Vec<f32> {
    let ratio = opts.lr_lwc / opts.lr;
    let mut s = vec![1.0f32; playout.size];
    for (name, _, _) in playout.entries.clone() {
        if name.starts_with("lwc_") || name.starts_with("delta_") {
            s[playout.range(&name)].fill(ratio);
        }
    }
    s
}

/// Optimize one block's phi against (xq, yfp) calibration pairs.
///
/// `record_sdd` also measures the masked transform every epoch (a host-side
/// matrix scan — cheap relative to the XLA step, but skippable).
pub fn optimize_block(
    rt: &ModelRuntime,
    opts: &CalibOptions,
    wb: &[f32],
    xs: &[Tensor],
    yfp: &[Tensor],
    stats: &SiteStats,
    record_sdd: bool,
) -> Result<BlockResult> {
    let playout = rt.phi_layouts[&opts.mode_key()].clone();
    let entry = format!("calib_{}", opts.mode_key());
    let mut phi = init_phi(&rt.cfg, &playout, &rt.block_layout, wb, stats, opts);
    let sched = opts.schedule();
    let mut adam = Adam::new(playout.size, opts.lr);
    let scales = lr_scales(&playout, opts);
    let qmax_w = [opts.spec.qmax()];
    let qmax_a = [(1u64 << opts.act_bits.min(16)) as f32 - 1.0];

    let mut loss_curve = Vec::with_capacity(opts.epochs);
    let mut sdd_margins = Vec::new();
    let mut diverged = false;

    'epochs: for e in 1..=opts.epochs {
        let mphi = sched.mphi(&playout, e);
        let mut epoch_losses = Vec::with_capacity(xs.len());
        for (x, y) in xs.iter().zip(yfp) {
            let mut args = vec![
                Arg::F32(&x.data),
                Arg::F32(&y.data),
                Arg::F32(wb),
                Arg::F32(&phi),
                Arg::F32(&mphi),
                Arg::F32(&qmax_w),
            ];
            if !opts.weight_only() {
                args.push(Arg::F32(&qmax_a));
            }
            let mut outs = rt.call(&entry, &args)?;
            let grad = outs.remove(1);
            let loss = outs.remove(0).data[0] as f64;
            if !loss.is_finite() {
                diverged = true;
                loss_curve.push(f64::NAN);
                break 'epochs;
            }
            adam.step_elem(&mut phi, &grad.data, &scales);
            epoch_losses.push(loss);
        }
        loss_curve.push(crate::util::mean(&epoch_losses));
        if opts.project_sdd {
            stability::project_phi(&playout, &mut phi, 1e-3);
        }
        if record_sdd {
            sdd_margins.push(stability::measure(&playout, &phi, &mphi_final(&sched, &playout, e)).min_margin());
        }
    }

    let mphi = mphi_final(&sched, &playout, opts.epochs);
    let transforms = transforms_from_phi(&rt.cfg, &playout, &phi, &mphi, opts);
    let final_loss = *loss_curve.last().unwrap_or(&f64::NAN);
    Ok(BlockResult { loss_curve, sdd_margins, transforms, diverged, final_loss })
}

fn mphi_final(sched: &MaskSchedule, playout: &Layout, e: usize) -> Vec<f32> {
    sched.mphi(playout, e)
}

/// Extract merge-ready transforms from the raw phi: the *effective*
/// transform the graph optimized is `phi ∘ GM_t`, so the deployed matrices
/// carry the final mask (off-diagonals damped by alpha).
pub fn transforms_from_phi(
    cfg: &ModelConfig,
    playout: &Layout,
    phi: &[f32],
    mphi: &[f32],
    opts: &CalibOptions,
) -> BlockTransforms {
    let masked = |name: &str| -> Tensor {
        let r = playout.range(name);
        let data: Vec<f32> = phi[r.clone()].iter().zip(&mphi[r]).map(|(p, m)| p * m).collect();
        Tensor::new(playout.shape(name).to_vec(), data)
    };
    let mut t = BlockTransforms::identity();
    if playout.has("A_qkv") {
        t.a_qkv = Some(masked("A_qkv"));
    }
    if playout.has("A_fc1") {
        t.a_fc1 = Some(masked("A_fc1"));
    }
    if playout.has("A_out") {
        t.a_out = Some(masked("A_out"));
    }
    if playout.has("a_qkv") {
        let a = phi[playout.range("a_qkv")].to_vec();
        let d = if playout.has("delta_qkv") {
            phi[playout.range("delta_qkv")].to_vec()
        } else {
            vec![0.0; a.len()]
        };
        t.diag_qkv = Some((a, d));
    }
    if playout.has("a_fc1") {
        let a = phi[playout.range("a_fc1")].to_vec();
        let d = if playout.has("delta_fc1") {
            phi[playout.range("delta_fc1")].to_vec()
        } else {
            vec![0.0; a.len()]
        };
        t.diag_fc1 = Some((a, d));
    }
    for (name, _, _) in playout.entries.clone() {
        if name.starts_with("lwc_") {
            t.lwc.insert(name.clone(), phi[playout.range(&name)].to_vec());
        }
    }
    let _ = (cfg, opts);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_scale_formula_and_clamp() {
        let s = sq_scale(&[4.0, 1e-9, 1e9], &[1.0, 1.0, 1e-9], 0.5);
        assert!((s[0] - 2.0).abs() < 1e-6);
        assert_eq!(s[1], 1e-2); // clamped low
        assert_eq!(s[2], 1e2); // clamped high
    }

    #[test]
    fn options_mode_keys() {
        let w = CalibOptions::affinequant(QuantSpec::new(3, 128), 16);
        assert_eq!(w.mode_key(), "w_g128");
        assert!(w.weight_only());
        let a = CalibOptions::affinequant(QuantSpec::new(4, 0), 4);
        assert_eq!(a.mode_key(), "a4");
        assert!(!a.weight_only());
        assert_eq!(a.label(), "w4a4");
        let o = CalibOptions::omniquant(QuantSpec::new(4, 0), 4);
        assert!(!o.full_affine);
    }

    #[test]
    fn set_diag_writes_diagonal_only() {
        let mut a = vec![0.0f32; 9];
        set_diag(&mut a, 3, &[1.0, 2.0, 3.0]);
        assert_eq!(a, vec![1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0]);
    }
}
