//! SDD stability monitor (paper §3.2, Appendix A.2/A.6).
//!
//! The Levy-Desplanques theorem guarantees invertibility of strictly
//! diagonally dominant matrices; the Gradual Mask is designed to keep the
//! *effective* transform `A* = A ∘ GM` SDD throughout. This monitor
//! measures that claim per epoch (the evidence behind the paper's Fig. 7
//! heat maps) and offers an optional projection back to SDD — an extension
//! the paper lists as future work, off by default.

use crate::linalg::sdd_margin;
use crate::model::Layout;

/// SDD margins of every affine site inside a masked phi vector.
#[derive(Clone, Debug, Default)]
pub struct SddReport {
    /// (site, min margin across heads for A_out).
    pub sites: Vec<(String, f32)>,
}

impl SddReport {
    pub fn min_margin(&self) -> f32 {
        self.sites.iter().map(|(_, m)| *m).fold(f32::INFINITY, f32::min)
    }

    pub fn all_sdd(&self) -> bool {
        !self.sites.is_empty() && self.min_margin() > 0.0
    }
}

/// Measure the effective transform `phi ∘ mphi` at the current epoch.
pub fn measure(playout: &Layout, phi: &[f32], mphi: &[f32]) -> SddReport {
    let mut report = SddReport::default();
    for (name, shape, _) in playout.entries.clone() {
        match name.as_str() {
            "A_qkv" | "A_fc1" => {
                let n = shape[0];
                let r = playout.range(&name);
                let a: Vec<f32> =
                    phi[r.clone()].iter().zip(&mphi[r]).map(|(p, m)| p * m).collect();
                report.sites.push((name.clone(), sdd_margin(&a, n)));
            }
            "A_out" => {
                let (h, hd) = (shape[0], shape[1]);
                let r = playout.range(&name);
                let mut worst = f32::INFINITY;
                for hi in 0..h {
                    let s = r.start + hi * hd * hd;
                    let a: Vec<f32> = phi[s..s + hd * hd]
                        .iter()
                        .zip(&mphi[s..s + hd * hd])
                        .map(|(p, m)| p * m)
                        .collect();
                    worst = worst.min(sdd_margin(&a, hd));
                }
                report.sites.push((name.clone(), worst));
            }
            _ => {}
        }
    }
    report
}

/// Project a square matrix back to SDD with margin `target` by shrinking
/// each violating row's off-diagonals (extension; preserves the diagonal).
pub fn project_sdd(a: &mut [f32], n: usize, target: f32) -> bool {
    let mut changed = false;
    for i in 0..n {
        let diag = a[i * n + i].abs();
        let off: f32 =
            (0..n).filter(|&j| j != i).map(|j| a[i * n + j].abs()).sum();
        if diag - off < target {
            let budget = (diag - target).max(0.0);
            let shrink = if off > 0.0 { budget / off } else { 0.0 };
            for j in 0..n {
                if j != i {
                    a[i * n + j] *= shrink;
                }
            }
            changed = true;
        }
    }
    changed
}

/// Apply `project_sdd` to every affine site of a raw phi vector. Because
/// the mask damps off-diagonals by `alpha`, projecting the raw `A` with
/// `target/alpha`-scaled margin would be conservative; we project the raw
/// matrix directly — callers opt in via `CalibOptions::project_sdd`.
pub fn project_phi(playout: &Layout, phi: &mut [f32], target: f32) -> bool {
    let mut changed = false;
    for (name, shape, _) in playout.entries.clone() {
        match name.as_str() {
            "A_qkv" | "A_fc1" => {
                let n = shape[0];
                let r = playout.range(&name);
                changed |= project_sdd(&mut phi[r], n, target);
            }
            "A_out" => {
                let (h, hd) = (shape[0], shape[1]);
                let r = playout.range(&name);
                for hi in 0..h {
                    let s = r.start + hi * hd * hd;
                    changed |= project_sdd(&mut phi[s..s + hd * hd], hd, target);
                }
            }
            _ => {}
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_layout;

    #[test]
    fn measure_reads_masked_matrix() {
        let pl = test_layout(vec![("A_qkv", vec![2, 2])]);
        let phi = vec![1.0, 10.0, 10.0, 1.0]; // violently non-SDD raw
        let mphi = vec![1.0, 0.01, 0.01, 1.0]; // but masked is SDD
        let rep = measure(&pl, &phi, &mphi);
        assert!(rep.all_sdd());
        assert!((rep.min_margin() - 0.9).abs() < 1e-6);
        let rep2 = measure(&pl, &phi, &[1.0; 4]);
        assert!(!rep2.all_sdd());
    }

    #[test]
    fn per_head_margin_is_worst_head() {
        let pl = test_layout(vec![("A_out", vec![2, 2, 2])]);
        // head 0 margin 0.5, head 1 margin -1
        let phi = vec![1.0, 0.5, 0.5, 1.0, 1.0, 2.0, 2.0, 1.0];
        let rep = measure(&pl, &phi, &vec![1.0; 8]);
        assert_eq!(rep.sites.len(), 1);
        assert!((rep.sites[0].1 + 1.0).abs() < 1e-6);
    }

    #[test]
    fn projection_restores_sdd() {
        let mut a = vec![1.0f32, 2.0, 3.0, -0.5, 2.0, 0.1, 0.0, 0.0, 1.0];
        assert!(sdd_margin(&a, 3) < 0.0);
        let changed = project_sdd(&mut a, 3, 0.05);
        assert!(changed);
        assert!(sdd_margin(&a, 3) >= 0.049, "{}", sdd_margin(&a, 3));
        // diagonal untouched
        assert_eq!(a[0], 1.0);
        assert_eq!(a[4], 2.0);
        // already-SDD rows untouched
        assert_eq!(a[6..9], [0.0, 0.0, 1.0]);
    }

    #[test]
    fn projection_noop_when_sdd() {
        let mut a = vec![2.0f32, 0.1, 0.1, 2.0];
        let before = a.clone();
        assert!(!project_sdd(&mut a, 2, 0.5));
        assert_eq!(a, before);
    }
}
