//! Gradual Mask (paper Eq. 6): the learning-rate regulator that keeps the
//! affine matrix strictly diagonally dominant during optimization.
//!
//! `GM_ij = 1` on the diagonal, `alpha` within the epoch-dependent band
//! `0 < |i-j| <= e/t * size`, `0` outside. The mask is element-wise
//! multiplied with `A` *inside* the L2 calibration graph (`phi* = phi ∘
//! mphi`), so the returned gradient automatically carries the Eq. 9
//! damping; this module only owns the schedule and the mask layout.

use crate::model::Layout;

/// Mask schedule parameters.
#[derive(Clone, Copy, Debug)]
pub struct MaskSchedule {
    /// Stability factor `alpha` (paper Table 5 sweeps 1e0..1e-8).
    pub alpha: f32,
    /// Target epochs `t`.
    pub epochs: usize,
    /// `false` ⇒ diagonal-only forever (OmniQuant-equivalent, alpha→0).
    pub full_affine: bool,
    /// `false` ⇒ no gradual release: the whole band opens at epoch 1
    /// (paper Table 6 "Without Gradual" ablation).
    pub gradual: bool,
}

impl MaskSchedule {
    /// Band half-width at epoch `e` (1-based) for a matrix of size `n`.
    pub fn band(&self, e: usize, n: usize) -> f32 {
        if !self.full_affine {
            return 0.0;
        }
        if !self.gradual {
            return n as f32;
        }
        (e.min(self.epochs) as f32 / self.epochs as f32) * n as f32
    }

    /// Fill a square-matrix mask for epoch `e` into `out` (row-major n×n).
    pub fn fill_square(&self, e: usize, n: usize, out: &mut [f32]) {
        let band = self.band(e, n);
        for i in 0..n {
            for j in 0..n {
                let dist = (i as f32 - j as f32).abs();
                out[i * n + j] = if i == j {
                    1.0
                } else if dist <= band {
                    self.alpha
                } else {
                    0.0
                };
            }
        }
    }

    /// Build the full `mphi` vector for one calibration phi layout at epoch
    /// `e`. Full affine entries (`A_qkv`, `A_fc1`) get the banded mask over
    /// their own size; per-head `A_out` gets it per head (paper §3.2:
    /// "within the attention module we apply a gradual mask in each
    /// attention head"); every other learnable (diagonal transforms,
    /// shifts, LWC logits) is always live (mask 1).
    pub fn mphi(&self, playout: &Layout, e: usize) -> Vec<f32> {
        let mut m = vec![1.0f32; playout.size];
        for (name, shape, _) in playout.entries.clone() {
            match name.as_str() {
                "A_qkv" | "A_fc1" => {
                    let n = shape[0];
                    self.fill_square(e, n, &mut m[playout.range(&name)]);
                }
                "A_out" => {
                    let (h, hd) = (shape[0], shape[1]);
                    let r = playout.range(&name);
                    let base = r.start;
                    for hi in 0..h {
                        self.fill_square(e, hd, &mut m[base + hi * hd * hd..base + (hi + 1) * hd * hd]);
                    }
                }
                _ => {}
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_layout;

    fn sched(alpha: f32, gradual: bool) -> MaskSchedule {
        MaskSchedule { alpha, epochs: 10, full_affine: true, gradual }
    }

    #[test]
    fn band_widens_linearly() {
        let s = sched(0.1, true);
        assert_eq!(s.band(1, 100), 10.0);
        assert_eq!(s.band(5, 100), 50.0);
        assert_eq!(s.band(10, 100), 100.0);
        assert_eq!(s.band(99, 100), 100.0); // clamped past t
    }

    #[test]
    fn square_mask_values() {
        let s = sched(0.25, true);
        let mut m = vec![0.0; 16];
        s.fill_square(2, 4, &mut m); // band = 2/10*4 = 0.8 -> only diagonal
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert_eq!(m[i * 4 + j], want, "({i},{j})");
            }
        }
        s.fill_square(10, 4, &mut m); // full band
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.25 };
                assert_eq!(m[i * 4 + j], want);
            }
        }
    }

    #[test]
    fn diag_only_mode_never_opens() {
        let s = MaskSchedule { alpha: 0.5, epochs: 10, full_affine: false, gradual: true };
        let mut m = vec![9.0; 9];
        s.fill_square(10, 3, &mut m);
        assert_eq!(m, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn no_gradual_opens_immediately() {
        let s = sched(0.3, false);
        let mut m = vec![0.0; 9];
        s.fill_square(1, 3, &mut m);
        assert!(m.iter().filter(|&&v| v == 0.3).count() == 6);
    }

    #[test]
    fn mphi_layout_rules() {
        let pl = test_layout(vec![
            ("A_qkv", vec![4, 4]),
            ("A_out", vec![2, 2, 2]),
            ("a_fc1", vec![4]),
            ("lwc_g_wq", vec![1, 4]),
        ]);
        let s = sched(0.5, true);
        let m = s.mphi(&pl, 10);
        // A_qkv: diag 1, off 0.5
        assert_eq!(m[0], 1.0);
        assert_eq!(m[1], 0.5);
        // A_out head 0: 2x2 per head
        let r = pl.range("A_out");
        assert_eq!(m[r.start], 1.0);
        assert_eq!(m[r.start + 1], 0.5);
        assert_eq!(m[r.start + 3], 1.0);
        // vectors + lwc all ones
        assert!(m[pl.range("a_fc1")].iter().all(|&v| v == 1.0));
        assert!(m[pl.range("lwc_g_wq")].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn mask_never_writes_outside_band() {
        // property: entries with |i-j| > band are exactly zero at every epoch
        let s = sched(0.9, true);
        for e in 1..=10 {
            let n = 32;
            let mut m = vec![0.0; n * n];
            s.fill_square(e, n, &mut m);
            let band = s.band(e, n);
            for i in 0..n {
                for j in 0..n {
                    let dist = (i as f32 - j as f32).abs();
                    if dist > band {
                        assert_eq!(m[i * n + j], 0.0);
                    }
                }
            }
        }
    }
}
