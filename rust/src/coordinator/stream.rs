//! Calibration activation streams + per-site statistics.
//!
//! PTQ calibration walks the model block by block: the running stream `x`
//! holds each calibration batch's input to the *current* block, propagated
//! through the already-quantized prefix (OmniQuant protocol — the
//! optimization target for block `i` is `f_i^fp(x)` computed from the same
//! quantized-stream input, paper Eq. 4). One `block_capture` pass per batch
//! yields both the FP target and the four linear-input captures that seed
//! the transform initialization (SmoothQuant scales, OS+ shifts) and the
//! GPTQ/AWQ baselines.

use anyhow::Result;
use std::collections::HashMap;

use crate::data::{self, CorpusKind};
use crate::rngx::Pcg32;
use crate::runtime::ModelRuntime;
use crate::tensor::Tensor;

/// Names of the captured linear inputs, in `block_capture` output order.
pub const CAPTURE_NAMES: [&str; 4] = ["x_qkv", "x_ctx", "x_fc1", "x_fc2"];

/// Per-channel statistics of one site's input activations, accumulated
/// over all calibration batches.
#[derive(Clone, Debug)]
pub struct ChannelStats {
    pub absmax: Vec<f32>,
    pub min: Vec<f32>,
    pub max: Vec<f32>,
}

impl ChannelStats {
    fn new(d: usize) -> Self {
        ChannelStats {
            absmax: vec![0.0; d],
            min: vec![f32::INFINITY; d],
            max: vec![f32::NEG_INFINITY; d],
        }
    }

    fn update(&mut self, x2d: &Tensor) {
        let (mn, mx) = x2d.col_min_max();
        for j in 0..self.absmax.len() {
            self.min[j] = self.min[j].min(mn[j]);
            self.max[j] = self.max[j].max(mx[j]);
            self.absmax[j] = self.absmax[j].max(mn[j].abs()).max(mx[j].abs());
        }
    }

    /// OS+ shift init: channel midpoint.
    pub fn shift(&self) -> Vec<f32> {
        self.min
            .iter()
            .zip(&self.max)
            .map(|(&a, &b)| (a + b) / 2.0)
            .collect()
    }

    /// Per-channel |x| range after shifting by `shift()`.
    pub fn shifted_absmax(&self) -> Vec<f32> {
        self.min
            .iter()
            .zip(&self.max)
            .map(|(&a, &b)| (b - a) / 2.0)
            .collect()
    }
}

/// Stats for all four capture sites of one block.
pub type SiteStats = HashMap<&'static str, ChannelStats>;

/// Flatten (B, S, d) to a (B·S, d) row view for column statistics.
pub fn rows2d(x: &Tensor) -> Tensor {
    let d = *x.shape.last().unwrap();
    Tensor::new(vec![x.numel() / d, d], x.data.clone())
}

/// The calibration token batches (fixed seed → fixed dataset, as in the
/// paper's "128 segments of 2048 tokens from the WikiText2 train set").
pub fn calib_batches(
    cfg: &crate::model::ModelConfig,
    n_segments: usize,
    seed: u64,
) -> Vec<Vec<i32>> {
    let corpus = data::gen_corpus(CorpusKind::Wt2s, 2_000_000, 1);
    let mut rng = Pcg32::seeded(seed);
    let segs = data::sample_segments(&corpus, cfg.seq, n_segments, &mut rng);
    segs.chunks(cfg.batch)
        .filter(|c| c.len() == cfg.batch)
        .map(|c| data::to_batch(c).0)
        .collect()
}

/// Embed every calibration batch: the initial stream.
pub fn embed_stream(rt: &ModelRuntime, globals: &[f32], batches: &[Vec<i32>]) -> Result<Vec<Tensor>> {
    batches.iter().map(|b| rt.embed(b, globals)).collect()
}

/// One block_capture sweep: returns the FP block outputs (the optimization
/// targets) and the accumulated per-site channel statistics.
pub fn capture_block(
    rt: &ModelRuntime,
    wb: &[f32],
    xs: &[Tensor],
) -> Result<(Vec<Tensor>, SiteStats)> {
    let mut stats: SiteStats = HashMap::new();
    let mut yfp = Vec::with_capacity(xs.len());
    for x in xs {
        let mut outs = rt.block_capture(x, wb)?;
        // outs: [y, x_qkv, x_ctx, x_fc1, x_fc2]
        for (i, name) in CAPTURE_NAMES.iter().enumerate().rev() {
            let t = outs.remove(1 + i);
            let r = rows2d(&t);
            let d = r.shape[1];
            stats.entry(name).or_insert_with(|| ChannelStats::new(d)).update(&r);
        }
        yfp.push(outs.remove(0));
    }
    Ok((yfp, stats))
}

/// Visit the raw captures batch-by-batch (GPTQ Hessian accumulation etc.)
/// without retaining them all in memory.
pub fn for_each_capture<F: FnMut(&[Tensor; 4])>(
    rt: &ModelRuntime,
    wb: &[f32],
    xs: &[Tensor],
    mut f: F,
) -> Result<()> {
    for x in xs {
        let mut outs = rt.block_capture(x, wb)?;
        let x_fc2 = outs.remove(4);
        let x_fc1 = outs.remove(3);
        let x_ctx = outs.remove(2);
        let x_qkv = outs.remove(1);
        f(&[x_qkv, x_ctx, x_fc1, x_fc2]);
    }
    Ok(())
}

/// Advance the stream through a (merged, quantized) block.
pub fn advance(
    rt: &ModelRuntime,
    wb: &[f32],
    xs: &mut [Tensor],
    act_qmax: Option<f32>,
) -> Result<()> {
    for x in xs.iter_mut() {
        *x = match act_qmax {
            Some(q) => rt.block_a4(x, wb, q)?,
            None => rt.block_fp(x, wb)?,
        };
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_stats_accumulate() {
        let mut s = ChannelStats::new(2);
        s.update(&Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.0, 0.5]));
        s.update(&Tensor::new(vec![1, 2], vec![-4.0, 0.0]));
        assert_eq!(s.absmax, vec![4.0, 2.0]);
        assert_eq!(s.min, vec![-4.0, -2.0]);
        assert_eq!(s.max, vec![3.0, 0.5]);
        assert_eq!(s.shift(), vec![-0.5, -0.75]);
        assert_eq!(s.shifted_absmax(), vec![3.5, 1.25]);
    }

    #[test]
    fn rows2d_flattens_leading_dims() {
        let x = Tensor::new(vec![2, 3, 4], (0..24).map(|v| v as f32).collect());
        let r = rows2d(&x);
        assert_eq!(r.shape, vec![6, 4]);
        assert_eq!(r.data[4], 4.0);
    }
}
