//! The AffineQuant coordinator — the paper's contribution, orchestrated.
//!
//! * [`mask`] — the Gradual Mask schedule (paper Eq. 6-9).
//! * [`stability`] — SDD margin monitoring + optional projection
//!   (Levy-Desplanques invariant, Appendix A.2 / Fig. 7).
//! * [`stream`] — calibration activation streams + per-site statistics.
//! * [`block_opt`] — the per-block Adam loop over the `calib_*` artifacts.
//! * [`pipeline`] — whole-model calibration producing a merged quantized
//!   [`crate::model::ParamStore`].

pub mod block_opt;
pub mod mask;
pub mod pipeline;
pub mod stability;
pub mod stream;

pub use block_opt::CalibOptions;
pub use pipeline::{calibrate, CalibReport};
