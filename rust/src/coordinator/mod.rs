//! The AffineQuant coordinator — the paper's contribution, orchestrated.
//!
//! * [`mask`] — the Gradual Mask schedule (paper Eq. 6-9).
//! * [`stability`] — SDD margin monitoring + optional projection
//!   (Levy-Desplanques invariant, Appendix A.2 / Fig. 7).
//! * [`stream`] — calibration activation streams + per-site statistics.
//! * [`block_opt`] — the per-block Adam loop over the `calib_*` artifacts.
//! * [`pipeline`] — whole-model calibration producing a merged quantized
//!   [`crate::model::ParamStore`].

// mask/stability are pure host math (usable without `pjrt`); the optimizer
// loop, activation streams, and pipeline step through the PJRT artifacts.
#[cfg(feature = "pjrt")]
pub mod block_opt;
pub mod mask;
#[cfg(feature = "pjrt")]
pub mod pipeline;
pub mod stability;
#[cfg(feature = "pjrt")]
pub mod stream;

#[cfg(feature = "pjrt")]
pub use block_opt::CalibOptions;
#[cfg(feature = "pjrt")]
pub use pipeline::{calibrate, CalibReport};
