//! Whole-model calibration pipeline: block-by-block AffineQuant (or the
//! diagonal-only OmniQuant mode) over a trained checkpoint, producing a
//! merged, quantized [`ParamStore`] that evaluates under the standard
//! `block_fp` / `block_a4` serving graphs with zero extra ops.

use anyhow::Result;

use crate::coordinator::block_opt::{optimize_block, CalibOptions};
use crate::coordinator::stream;
use crate::model::merge::{merge_block_a4, merge_block_weight_only};
use crate::model::ParamStore;
use crate::runtime::ModelRuntime;
use crate::util::Timer;

/// Per-block record kept for the figure benches.
pub struct BlockRecord {
    pub loss_curve: Vec<f64>,
    pub sdd_margins: Vec<f32>,
    pub final_loss: f64,
    pub diverged: bool,
    pub secs: f64,
}

pub struct CalibReport {
    pub blocks: Vec<BlockRecord>,
    pub total_secs: f64,
}

impl CalibReport {
    /// Loss of the last transformer block — the paper's model-quality proxy
    /// (Figs. 3/5/6, Pearson r ≈ 0.95 vs PPL).
    pub fn last_block_loss(&self) -> f64 {
        self.blocks.last().map(|b| b.final_loss).unwrap_or(f64::NAN)
    }

    pub fn any_diverged(&self) -> bool {
        self.blocks.iter().any(|b| b.diverged)
    }
}

/// Run the full calibration: returns the merged quantized model plus the
/// per-block optimization records. `record_sdd` additionally traces SDD
/// margins per epoch (Fig. 7).
pub fn calibrate(
    rt: &ModelRuntime,
    fp: &ParamStore,
    opts: &CalibOptions,
    record_sdd: bool,
) -> Result<(ParamStore, CalibReport)> {
    let t_all = Timer::start();
    let cfg = &rt.cfg;
    let batches = stream::calib_batches(cfg, opts.n_calib, opts.seed);
    let mut xs = stream::embed_stream(rt, fp.globals(), &batches)?;

    let mut merged = fp.clone();
    let mut records = Vec::with_capacity(cfg.n_layers);
    let act_qmax =
        if opts.weight_only() { None } else { Some((1u64 << opts.act_bits) as f32 - 1.0) };

    for i in 0..cfg.n_layers {
        let t = Timer::start();
        let wb = fp.block(i).to_vec();
        // FP targets + init statistics from the current quantized stream.
        let (yfp, stats) = stream::capture_block(rt, &wb, &xs)?;
        let res = optimize_block(rt, opts, &wb, &xs, &yfp, &stats, record_sdd)?;

        // Merge the learned transforms into this block's parameters.
        let bl = rt.block_layout.clone();
        let wbm = merged.block_mut(i);
        if opts.weight_only() {
            merge_block_weight_only(&bl, wbm, &res.transforms, opts.spec, cfg.n_heads, opts.prec);
        } else {
            merge_block_a4(&bl, wbm, &res.transforms, opts.spec, cfg.n_heads, opts.prec);
        }

        // Advance the calibration stream through the quantized block.
        let wbm = merged.block(i).to_vec();
        stream::advance(rt, &wbm, &mut xs, act_qmax)?;

        let secs = t.secs();
        if std::env::var("AQ_QUIET").is_err() {
            println!(
                "[calib {} {}] block {}/{} loss {:.3e}{} ({:.1}s)",
                cfg.name,
                opts.label(),
                i + 1,
                cfg.n_layers,
                res.final_loss,
                if res.diverged { " DIVERGED" } else { "" },
                secs
            );
        }
        records.push(BlockRecord {
            loss_curve: res.loss_curve,
            sdd_margins: res.sdd_margins,
            final_loss: res.final_loss,
            diverged: res.diverged,
            secs,
        });
    }
    Ok((merged, CalibReport { blocks: records, total_secs: t_all.secs() }))
}
