//! Dense linear-algebra substrate, generic over f32/f64.
//!
//! The f32/f64 duality is load-bearing: the paper's Table 4 studies how the
//! numerical precision of the affine-matrix inverse affects the merge error
//! and final perplexity. `inverse` (LU, partial pivoting) is the general
//! path; `gj_inverse_nopivot` mirrors the in-graph Gauss-Jordan used by the
//! L2 calibration step (stable only for SDD matrices — which the Gradual
//! Mask guarantees); `cholesky` backs the GPTQ baseline.

use num_traits::Float;

/// Row-major n x n matrix wrapper over a borrowed slice.
fn idx(n: usize, i: usize, j: usize) -> usize {
    i * n + j
}

/// LU-decomposition inverse with partial pivoting. Returns None if singular
/// to working precision.
pub fn inverse<T: Float>(a: &[T], n: usize) -> Option<Vec<T>> {
    assert_eq!(a.len(), n * n);
    let mut lu = a.to_vec();
    let mut perm: Vec<usize> = (0..n).collect();

    for col in 0..n {
        // pivot
        let mut p = col;
        let mut best = lu[idx(n, col, col)].abs();
        for r in col + 1..n {
            let v = lu[idx(n, r, col)].abs();
            if v > best {
                best = v;
                p = r;
            }
        }
        if best == T::zero() || !best.is_finite() {
            return None;
        }
        if p != col {
            for j in 0..n {
                lu.swap(idx(n, col, j), idx(n, p, j));
            }
            perm.swap(col, p);
        }
        let piv = lu[idx(n, col, col)];
        for r in col + 1..n {
            let f = lu[idx(n, r, col)] / piv;
            lu[idx(n, r, col)] = f;
            if f != T::zero() {
                for j in col + 1..n {
                    let v = lu[idx(n, col, j)];
                    lu[idx(n, r, j)] = lu[idx(n, r, j)] - f * v;
                }
            }
        }
    }

    // solve A X = I column-block-wise via the factorization
    let mut inv = vec![T::zero(); n * n];
    let mut col_buf = vec![T::zero(); n];
    for e in 0..n {
        // rhs = permuted unit vector e
        for (i, &pi) in perm.iter().enumerate() {
            col_buf[i] = if pi == e { T::one() } else { T::zero() };
        }
        // forward substitution (L, unit diagonal)
        for i in 0..n {
            let mut s = col_buf[i];
            for j in 0..i {
                s = s - lu[idx(n, i, j)] * col_buf[j];
            }
            col_buf[i] = s;
        }
        // back substitution (U)
        for i in (0..n).rev() {
            let mut s = col_buf[i];
            for j in i + 1..n {
                s = s - lu[idx(n, i, j)] * col_buf[j];
            }
            col_buf[i] = s / lu[idx(n, i, i)];
        }
        for i in 0..n {
            inv[idx(n, i, e)] = col_buf[i];
        }
    }
    Some(inv)
}

/// Gauss-Jordan inverse without pivoting — the exact algorithm the L2 graph
/// runs (linalg.py). Only stable for (near-)SDD matrices.
pub fn gj_inverse_nopivot<T: Float>(a: &[T], n: usize) -> Option<Vec<T>> {
    assert_eq!(a.len(), n * n);
    let mut aug = vec![T::zero(); n * 2 * n];
    for i in 0..n {
        for j in 0..n {
            aug[i * 2 * n + j] = a[idx(n, i, j)];
        }
        aug[i * 2 * n + n + i] = T::one();
    }
    for i in 0..n {
        let piv = aug[i * 2 * n + i];
        if piv == T::zero() || !piv.is_finite() {
            return None;
        }
        for j in 0..2 * n {
            aug[i * 2 * n + j] = aug[i * 2 * n + j] / piv;
        }
        for r in 0..n {
            if r == i {
                continue;
            }
            let f = aug[r * 2 * n + i];
            if f != T::zero() {
                for j in 0..2 * n {
                    let v = aug[i * 2 * n + j];
                    aug[r * 2 * n + j] = aug[r * 2 * n + j] - f * v;
                }
            }
        }
    }
    let mut inv = vec![T::zero(); n * n];
    for i in 0..n {
        for j in 0..n {
            inv[idx(n, i, j)] = aug[i * 2 * n + n + j];
        }
    }
    Some(inv)
}

/// Cholesky factorization A = L Lᵀ (lower L, row-major). None if not SPD.
pub fn cholesky<T: Float>(a: &[T], n: usize) -> Option<Vec<T>> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![T::zero(); n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[idx(n, i, j)];
            for k in 0..j {
                s = s - l[idx(n, i, k)] * l[idx(n, j, k)];
            }
            if i == j {
                if s <= T::zero() || !s.is_finite() {
                    return None;
                }
                l[idx(n, i, j)] = s.sqrt();
            } else {
                l[idx(n, i, j)] = s / l[idx(n, j, j)];
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via its Cholesky factor.
pub fn spd_inverse<T: Float>(a: &[T], n: usize) -> Option<Vec<T>> {
    let l = cholesky(a, n)?;
    // invert L (lower triangular) then A^{-1} = L^{-T} L^{-1}
    let mut linv = vec![T::zero(); n * n];
    for i in 0..n {
        linv[idx(n, i, i)] = T::one() / l[idx(n, i, i)];
        for j in 0..i {
            let mut s = T::zero();
            for k in j..i {
                s = s - l[idx(n, i, k)] * linv[idx(n, k, j)];
            }
            linv[idx(n, i, j)] = s / l[idx(n, i, i)];
        }
    }
    let mut inv = vec![T::zero(); n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = T::zero();
            for k in i.max(j)..n {
                s = s + linv[idx(n, k, i)] * linv[idx(n, k, j)];
            }
            inv[idx(n, i, j)] = s;
        }
    }
    Some(inv)
}

/// Strict-diagonal-dominance margin: min over rows of |a_ii| - Σ_{j≠i}|a_ij|.
/// Positive ⇒ SDD ⇒ invertible (Levy-Desplanques).
pub fn sdd_margin<T: Float>(a: &[T], n: usize) -> T {
    let mut margin = T::infinity();
    for i in 0..n {
        let mut off = T::zero();
        for j in 0..n {
            if j != i {
                off = off + a[idx(n, i, j)].abs();
            }
        }
        let m = a[idx(n, i, i)].abs() - off;
        if m < margin {
            margin = m;
        }
    }
    margin
}

/// 1-norm condition-number estimate ‖A‖₁·‖A⁻¹‖₁ (exact inverse, small n).
pub fn cond_1<T: Float>(a: &[T], n: usize) -> Option<T> {
    let inv = inverse(a, n)?;
    Some(norm_1(a, n) * norm_1(&inv, n))
}

/// Matrix 1-norm (max absolute column sum).
pub fn norm_1<T: Float>(a: &[T], n: usize) -> T {
    let mut best = T::zero();
    for j in 0..n {
        let mut s = T::zero();
        for i in 0..n {
            s = s + a[idx(n, i, j)].abs();
        }
        if s > best {
            best = s;
        }
    }
    best
}

/// C = A @ B for row-major n x n (small helper used by tests/merge paths).
pub fn matmul_sq<T: Float>(a: &[T], b: &[T], n: usize) -> Vec<T> {
    let mut c = vec![T::zero(); n * n];
    for i in 0..n {
        for k in 0..n {
            let av = a[idx(n, i, k)];
            if av != T::zero() {
                for j in 0..n {
                    c[idx(n, i, j)] = c[idx(n, i, j)] + av * b[idx(n, k, j)];
                }
            }
        }
    }
    c
}

/// Max |A@B - I| residual — inverse quality metric (Table 4 merge error).
pub fn inverse_residual<T: Float>(a: &[T], ainv: &[T], n: usize) -> T {
    let prod = matmul_sq(a, ainv, n);
    let mut worst = T::zero();
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { T::one() } else { T::zero() };
            let d = (prod[idx(n, i, j)] - want).abs();
            if d > worst {
                worst = d;
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg32;

    fn random_sdd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut a = vec![0.0f64; n * n];
        for v in &mut a {
            *v = rng.normal() / n as f64;
        }
        for i in 0..n {
            let off: f64 = (0..n).filter(|&j| j != i).map(|j| a[i * n + j].abs()).sum();
            a[i * n + i] = 1.5 * (off + 0.1);
        }
        a
    }

    #[test]
    fn lu_inverse_residual_small() {
        for n in [1, 2, 5, 16, 64] {
            let a = random_sdd(n, n as u64);
            let inv = inverse(&a, n).unwrap();
            assert!(inverse_residual(&a, &inv, n) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn lu_handles_pivoting() {
        // zero on the diagonal requires a row swap
        let a = vec![0.0f64, 1.0, 1.0, 0.0];
        let inv = inverse(&a, 2).unwrap();
        assert!(inverse_residual(&a, &inv, 2) < 1e-14);
    }

    #[test]
    fn singular_returns_none() {
        let a = vec![1.0f64, 2.0, 2.0, 4.0];
        assert!(inverse(&a, 2).is_none());
        assert!(gj_inverse_nopivot(&[0.0f64, 1.0, 1.0, 0.0], 2).is_none());
    }

    #[test]
    fn gj_matches_lu_on_sdd() {
        let n = 48;
        let a = random_sdd(n, 7);
        let lu = inverse(&a, n).unwrap();
        let gj = gj_inverse_nopivot(&a, n).unwrap();
        let max_diff = lu
            .iter()
            .zip(&gj)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(max_diff < 1e-10, "{max_diff}");
    }

    #[test]
    fn f32_vs_f64_inverse_error_gap() {
        // The Table 4 phenomenon: f64 inverse is orders of magnitude tighter.
        let n = 96;
        let a64 = random_sdd(n, 9);
        let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
        let r64 = inverse_residual(&a64, &inverse(&a64, n).unwrap(), n);
        let r32 = inverse_residual(&a32, &inverse(&a32, n).unwrap(), n) as f64;
        assert!(r64 < 1e-12);
        assert!(r32 > r64 * 10.0, "r32={r32} r64={r64}");
    }

    #[test]
    fn cholesky_roundtrip() {
        let n = 24;
        // SPD: H = M Mᵀ + I
        let mut rng = Pcg32::seeded(11);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut h = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                h[i * n + j] = s;
            }
        }
        let l = cholesky(&h, n).unwrap();
        // L Lᵀ == H
        let mut recon = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += l[i * n + k] * l[j * n + k];
                }
                recon[i * n + j] = s;
            }
        }
        let diff = h
            .iter()
            .zip(&recon)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(diff < 1e-9, "{diff}");
        // SPD inverse
        let inv = spd_inverse(&h, n).unwrap();
        assert!(inverse_residual(&h, &inv, n) < 1e-9);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = vec![1.0f64, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn sdd_margin_signs() {
        let a = vec![2.0f64, 1.0, -0.5, 3.0];
        assert!((sdd_margin(&a, 2) - 1.0).abs() < 1e-12);
        let b = vec![1.0f64, 2.0, 0.0, 1.0];
        assert!(sdd_margin(&b, 2) < 0.0);
    }

    #[test]
    fn cond_identity_is_one() {
        let eye: Vec<f64> = Tensor_eye(16);
        assert!((cond_1(&eye, 16).unwrap() - 1.0).abs() < 1e-12);
    }

    fn Tensor_eye(n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n * n];
        for i in 0..n {
            v[i * n + i] = 1.0;
        }
        v
    }
}
