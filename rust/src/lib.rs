//! # AffineQuant reproduction (ICLR 2024)
//!
//! Post-training quantization of transformer LMs with learnable **affine
//! equivalent transformations**: weights become `Q(A·W)` while activations
//! are multiplied by `A⁻¹`, and `A` is optimized per transformer block
//! against the MSE between the FP and quantized block outputs. A **Gradual
//! Mask** keeps `A` strictly diagonally dominant — hence invertible
//! (Levy-Desplanques) — throughout the optimization.
//!
//! Architecture (see `DESIGN.md`): this crate is Layer 3 of a three-layer
//! stack. Layer 1 (pallas kernels) and Layer 2 (jax block/calibration
//! graphs) are AOT-lowered to HLO text at build time (`make artifacts`);
//! this crate loads them through the PJRT CPU client (`runtime`), owns the
//! calibration pipeline (`coordinator`), the pre-training driver (`train`),
//! the baselines (RTN / GPTQ / AWQ / SmoothQuant / OmniQuant / FlexRound),
//! and the evaluation harnesses (perplexity + zero-shot).
//!
//! The **deployment path** is pure host: [`engine`] serves a calibrated,
//! merged model from bit-packed integer codes (`quant::pack_bits`) with
//! fused dequant-GEMM kernels, a ring-buffer KV cache, and a
//! continuous-batching scheduler — no XLA, no artifacts. It demonstrates
//! the memory/throughput win the paper's "no inference overhead" merge
//! promises, and is the only subsystem available when the crate is built
//! with `--no-default-features` (no `pjrt`). [`server`] puts an
//! overload-safe HTTP front door on it: bounded admission (429 +
//! `Retry-After`), per-request deadlines, per-client caps, token
//! streaming, and graceful drain — `affinequant serve`.
//!
//! Substrate modules (`jsonx`, `rngx`, `tensor`, `linalg`, `quant`, `data`,
//! `benchx`, `proptestx`) are implemented from scratch: the offline build
//! environment vendors only the `xla` crate closure.

#[cfg(feature = "pjrt")]
pub mod baselines;
pub mod benchx;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod eval;
#[cfg(feature = "pjrt")]
pub mod harness;
pub mod jsonx;
pub mod linalg;
pub mod model;
pub mod proptestx;
pub mod quant;
pub mod report;
pub mod rngx;
pub mod server;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod telemetry;
pub mod tensor;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
