//! Derisk smoke test: load the prototype calibration-step HLO (5 inputs,
//! 2 outputs: loss + grad-wrt-A) produced by /tmp/proto/proto.py, run it on
//! the PJRT CPU client, and compare against python golden values.
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

fn parse_golden(path: &str) -> Result<HashMap<String, Vec<f32>>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let name = it.next().context("empty line")?.to_string();
        let vals: Vec<f32> = it.map(|v| v.parse().unwrap()).collect();
        out.insert(name, vals);
    }
    Ok(out)
}

fn main() -> Result<()> {
    let hlo = std::env::args().nth(1).unwrap_or("/tmp/proto/step.hlo.txt".into());
    let golden = parse_golden("/tmp/proto/golden.txt")?;

    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&hlo)?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;

    let d = 8usize;
    let lit = |name: &str, dims: &[i64]| -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&golden[name]).reshape(dims)?)
    };
    let a = lit("a", &[d as i64, d as i64])?;
    let x = lit("x", &[16, d as i64])?;
    let w = lit("w", &[d as i64, d as i64])?;
    let mask = lit("mask", &[d as i64, d as i64])?;
    let qmax = xla::Literal::vec1(&golden["qmax"]);

    let result = exe.execute::<xla::Literal>(&[a, x, w, mask, qmax])?[0][0].to_literal_sync()?;
    let (loss_l, ga_l) = result.to_tuple2()?;
    let loss = loss_l.to_vec::<f32>()?[0];
    let ga = ga_l.to_vec::<f32>()?;

    let want_loss = golden["loss"][0];
    println!("loss rust={loss} python={want_loss}");
    if (loss - want_loss).abs() > 1e-5 {
        bail!("loss mismatch");
    }
    let want_ga = &golden["ga"];
    let mut max_diff = 0f32;
    for (g, wg) in ga.iter().zip(want_ga) {
        max_diff = max_diff.max((g - wg).abs());
    }
    println!("grad max|diff|={max_diff}");
    if max_diff > 1e-4 {
        bail!("grad mismatch");
    }
    println!("smoke_hlo OK");
    Ok(())
}
