//! Minimal JSON parser/emitter (substrate: serde is not vendored offline).
//!
//! Covers the full JSON grammar we produce/consume: the artifact manifest,
//! configuration files, and result records. Numbers parse to f64; object
//! key order is preserved (Vec of pairs) so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — manifest access is
    /// programmer-error territory, not user input.
    pub fn req(&self, key: &str) -> &Value {
        self.get(key)
            .unwrap_or_else(|| panic!("jsonx: missing key {key:?} in {self:.60?}"))
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Num(n) => *n,
            _ => panic!("jsonx: not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        self.as_f64() as usize
    }

    pub fn as_str(&self) -> &str {
        match self {
            Value::Str(s) => s,
            _ => panic!("jsonx: not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Value] {
        match self {
            Value::Arr(v) => v,
            _ => panic!("jsonx: not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> &[(String, Value)] {
        match self {
            Value::Obj(v) => v,
            _ => panic!("jsonx: not an object: {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr().iter().map(|v| v.as_usize()).collect()
    }

    pub fn obj_map(&self) -> BTreeMap<String, &Value> {
        self.as_obj().iter().map(|(k, v)| (k.clone(), v)).collect()
    }
}

// --------------------------------------------------------------- parsing

pub fn parse(text: &str) -> Result<Value, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("jsonx: trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "jsonx: expected {:?} at byte {} (got {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("jsonx: unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("jsonx: bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("jsonx: bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("jsonx: unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "jsonx: bad \\u")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "jsonx: bad \\u hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("jsonx: bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (fast path, preserves UTF-8)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(
                        |e| format!("jsonx: invalid utf-8 in string: {e}"),
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                other => return Err(format!("jsonx: expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.push((key, self.value()?));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                other => return Err(format!("jsonx: expected , or }} got {other:?}")),
            }
        }
    }
}

// -------------------------------------------------------------- emitting

pub fn emit(v: &Value) -> String {
    let mut s = String::new();
    emit_into(v, &mut s);
    s
}

fn emit_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(item, out);
            }
            out.push(']');
        }
        Value::Obj(pairs) => {
            out.push('{');
            for (i, (k, v)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(&Value::Str(k.clone()), out);
                out.push(':');
                emit_into(v, out);
            }
            out.push('}');
        }
    }
}

/// Convenience constructors for building result records.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("a").as_arr()[2].as_f64(), -300.0);
        assert_eq!(v.req("b").req("c").as_str(), "x\ny");
        let emitted = emit(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), "Aé");
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(v.as_arr()[1].as_arr()[1].as_arr()[0].as_f64(), 4.0);
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} junk").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn integer_emission() {
        assert_eq!(emit(&num(42.0)), "42");
        assert_eq!(emit(&num(0.5)), "0.5");
    }

    #[test]
    fn real_manifest_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.req("models").get("opt-s1").is_some());
        }
    }
}
