//! Deterministic PRNG substrate (the `rand` crate is not vendored offline).
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): small state, good statistical quality,
//! reproducible across platforms. Every experiment seed in the repo flows
//! through this generator so runs are bit-reproducible.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vec of standard-normal f32s scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Pick an index according to unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Pcg32::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg32::seeded(11);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[rng.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 8 * c[0] / 2, "{c:?}");
    }
}
