//! Experiment harness shared by `examples/` and `rust/benches/`: one
//! function per paper exhibit, parameterized by model/config/method lists
//! so the bench binaries can run scaled-down defaults while the examples
//! expose the full sweeps. Every function prints a markdown table and
//! saves CSV/markdown under `results/`.

use std::collections::HashMap;

use anyhow::Result;

use crate::baselines;
use crate::benchx::Table;
use crate::cli::parse_config;
use crate::coordinator::{calibrate, CalibOptions};
use crate::data::CorpusKind;
use crate::eval::{self, act_qmax, zeroshot};
use crate::model::ParamStore;
use crate::quant::QuantSpec;
use crate::report::save_table;
use crate::runtime::{ModelRuntime, Runtime};
use crate::train::{ensure_checkpoint, TrainConfig};

/// PPL eval batches (×batch×seq tokens). 8 batches ≈ 8k tokens/corpus.
pub const EVAL_BATCHES: usize = 8;
pub const ZEROSHOT_N: usize = 64;

/// Shared experiment context: runtime + trained checkpoints.
pub struct Ctx {
    pub rt_root: Runtime,
    pub ckpt_dir: String,
    cache: HashMap<String, (std::rc::Rc<ModelRuntime>, ParamStore)>,
}

impl Ctx {
    pub fn load() -> Result<Ctx> {
        let artifacts = std::env::var("AQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let ckpt_dir = std::env::var("AQ_CKPT").unwrap_or_else(|_| "checkpoints".into());
        Ok(Ctx { rt_root: Runtime::load(&artifacts)?, ckpt_dir, cache: HashMap::new() })
    }

    /// Model runtime + trained FP checkpoint (trains on first use).
    pub fn model(&mut self, name: &str) -> Result<(std::rc::Rc<ModelRuntime>, ParamStore)> {
        if let Some((rt, ps)) = self.cache.get(name) {
            return Ok((std::rc::Rc::clone(rt), ps.clone()));
        }
        let rt = std::rc::Rc::new(self.rt_root.model(name)?);
        let mut ps =
            ParamStore::new(rt.cfg.clone(), rt.globals_layout.clone(), rt.block_layout.clone());
        ensure_checkpoint(&rt, &mut ps, &self.ckpt_dir, &TrainConfig::default())?;
        self.cache.insert(name.into(), (std::rc::Rc::clone(&rt), ps.clone()));
        Ok((rt, ps))
    }
}

/// Env-var list override helper for the bench binaries
/// (`AQ_MODELS=opt-s1,opt-s2 cargo bench ...`).
pub fn env_list(key: &str, default: &[&str]) -> Vec<String> {
    match std::env::var(key) {
        Ok(v) => v.split(',').map(str::to_string).collect(),
        Err(_) => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Quantize with a method and measure PPL on the three corpora.
pub fn method_ppl(
    ctx: &mut Ctx,
    model: &str,
    method: &str,
    spec: QuantSpec,
    act_bits: u32,
) -> Result<HashMap<&'static str, f64>> {
    let (rt, fp) = ctx.model(model)?;
    let qps = if method == "fp16" {
        fp.clone()
    } else {
        baselines::quantize_with(&rt, &fp, method, spec, act_bits, default_alpha(model, spec))?
    };
    let qmax = if method == "fp16" { None } else { act_qmax(act_bits) };
    let mut out = HashMap::new();
    for kind in CorpusKind::all() {
        out.insert(kind.name(), eval::perplexity(&rt, &qps, kind, EVAL_BATCHES, qmax)?);
    }
    Ok(out)
}

/// Paper §4.1: the stability factor shrinks as models grow / bits drop.
pub fn default_alpha(model: &str, spec: QuantSpec) -> f32 {
    let small = model.ends_with("s1");
    match (small, spec.bits) {
        (true, _) => 0.1,
        (false, b) if b >= 3 => 1e-2,
        (false, _) => 1e-3,
    }
}

/// Tables 1/8/9 (OPT weight-only) and 10/11 (LLaMA weight-only): one
/// sweep, three corpus columns per (model, config, method) row.
pub fn weight_only_tables(
    ctx: &mut Ctx,
    models: &[String],
    configs: &[String],
    methods: &[String],
    stem: &str,
) -> Result<Table> {
    let mut t = Table::new(
        &format!("Weight-only PPL ({stem})"),
        &["model", "config", "method", "wt2s", "ptbs", "c4s"],
    );
    for model in models {
        for config in configs {
            let (spec, act_bits) = parse_config(config)?;
            for method in methods {
                let ppl = method_ppl(ctx, model, method, spec, act_bits)?;
                t.row(vec![
                    model.clone(),
                    config.clone(),
                    method.clone(),
                    format!("{:.3}", ppl["wt2s"]),
                    format!("{:.3}", ppl["ptbs"]),
                    format!("{:.3}", ppl["c4s"]),
                ]);
                t.print_last();
            }
        }
    }
    save_table(&t, stem)?;
    Ok(t)
}

/// Table 2: zero-shot accuracy at w4a4.
pub fn zeroshot_table(
    ctx: &mut Ctx,
    models: &[String],
    methods: &[String],
    config: &str,
    stem: &str,
) -> Result<Table> {
    let (spec, act_bits) = parse_config(config)?;
    let mut header = vec!["model".to_string(), "method".to_string()];
    header.extend(zeroshot::TASKS.iter().map(|s| s.to_string()));
    header.push("avg".into());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&format!("Zero-shot accuracy {config}"), &hrefs);
    for model in models {
        for method in methods {
            let (rt, fp) = ctx.model(model)?;
            let (qps, qmax) = if method == "fp16" {
                (fp.clone(), None)
            } else {
                let q = baselines::quantize_with(
                    &rt,
                    &fp,
                    method,
                    spec,
                    act_bits,
                    default_alpha(model, spec),
                )?;
                (q, act_qmax(act_bits))
            };
            let suite = zeroshot::suite(&rt, &qps, ZEROSHOT_N, qmax)?;
            let mut row = vec![model.clone(), method.clone()];
            row.extend(suite.iter().map(|(_, a)| format!("{a:.2}")));
            t.row(row);
            t.print_last();
        }
    }
    save_table(&t, stem)?;
    Ok(t)
}

/// Table 3: w4a4 PPL (WikiText2 + C4 analogues) across method set M2.
pub fn w4a4_ppl_table(ctx: &mut Ctx, models: &[String], methods: &[String], stem: &str) -> Result<Table> {
    let mut t = Table::new("w4a4 PPL", &["model", "method", "wt2s", "c4s"]);
    for model in models {
        for method in methods {
            let ppl = method_ppl(ctx, model, method, QuantSpec::new(4, 0), 4)?;
            t.row(vec![
                model.clone(),
                method.clone(),
                format!("{:.3}", ppl["wt2s"]),
                format!("{:.3}", ppl["c4s"]),
            ]);
            t.print_last();
        }
    }
    save_table(&t, stem)?;
    Ok(t)
}

/// Table 5: stability-factor sweep. NaN rows (training collapse) are
/// reported as "NaN", matching the paper.
pub fn alpha_sweep(
    ctx: &mut Ctx,
    model: &str,
    config: &str,
    alphas: &[f32],
    stem: &str,
) -> Result<Table> {
    let (spec, act_bits) = parse_config(config)?;
    let mut t = Table::new(
        &format!("Alpha sweep {model} {config}"),
        &["alpha", "wt2s", "ptbs", "c4s", "last_block_loss"],
    );
    let (rt, fp) = ctx.model(model)?;
    for &alpha in alphas {
        let mut opts = CalibOptions::affinequant(spec, act_bits);
        opts.alpha = alpha;
        let (qps, rep) = calibrate(&rt, &fp, &opts, false)?;
        let qmax = act_qmax(act_bits);
        let mut row = vec![format!("{alpha:.0e}")];
        if rep.any_diverged() {
            row.extend(["NaN".to_string(), "NaN".into(), "NaN".into()]);
        } else {
            for kind in CorpusKind::all() {
                row.push(format!("{:.3}", eval::perplexity(&rt, &qps, kind, EVAL_BATCHES, qmax)?));
            }
        }
        row.push(format!("{:.3e}", rep.last_block_loss()));
        t.row(row);
        t.print_last();
    }
    save_table(&t, stem)?;
    Ok(t)
}

/// Table 6: gradual mask on/off.
pub fn gradual_ablation(ctx: &mut Ctx, model: &str, config: &str, stem: &str) -> Result<Table> {
    let (spec, act_bits) = parse_config(config)?;
    let mut t = Table::new(
        &format!("Gradual mask ablation {model} {config}"),
        &["scheme", "wt2s", "ptbs", "c4s"],
    );
    let (rt, fp) = ctx.model(model)?;
    for (scheme, gradual) in [("with_gradual", true), ("without_gradual", false)] {
        let mut opts = CalibOptions::affinequant(spec, act_bits);
        // paper §4.1 uses alpha = 1 at this model scale — the regime where
        // releasing all off-diagonals at epoch 1 actually bites (Table 6)
        opts.alpha = 1.0;
        opts.gradual = gradual;
        let (qps, rep) = calibrate(&rt, &fp, &opts, false)?;
        let qmax = act_qmax(act_bits);
        let mut row = vec![scheme.to_string()];
        if rep.any_diverged() {
            row.extend(["NaN".to_string(), "NaN".into(), "NaN".into()]);
        } else {
            for kind in CorpusKind::all() {
                row.push(format!("{:.3}", eval::perplexity(&rt, &qps, kind, EVAL_BATCHES, qmax)?));
            }
        }
        t.row(row);
        t.print_last();
    }
    save_table(&t, stem)?;
    Ok(t)
}

/// Packed-engine exhibit: parity of the host engine against the PJRT
/// "merged serving" path (RTN fake-quant + `block_fp`), deployment memory
/// vs fp16, decode throughput — engine continuous batching (chunked
/// prefill, 16 prompt tokens per tick) vs the naive PJRT alternative (one
/// full `(batch, seq)` forward per generated token, the only way to decode
/// through the fixed-shape AOT graphs) — and time-to-first-token on a
/// near-table-length prompt.
pub fn engine_table(
    ctx: &mut Ctx,
    model: &str,
    configs: &[String],
    stem: &str,
) -> Result<Table> {
    use crate::engine::{Engine, PackedModel, Request, Sampler, SchedConfig};
    use crate::telemetry::Recorder;
    use crate::util::Timer;

    let (rt, fp) = ctx.model(model)?;
    let cfg = rt.cfg.clone();
    let sched = SchedConfig { prefill_chunk: 16, ..SchedConfig::default() };
    let mut t = Table::new(
        &format!("Packed engine — {model}"),
        &[
            "config",
            "kernel",
            "hidden_maxdiff",
            "mem_vs_fp16",
            "engine_tok_s_b16",
            "it_p50_ms",
            "it_p99_ms",
            "ttft_ms",
            "pjrt_naive_tok_s",
            "shed",
            "deadline_evict",
            "starved_ticks",
            "kv_pages",
            "kv_shared_bytes",
            "drift_layers",
            "w2_agree_pct",
        ],
    );

    // PJRT naive-decode baseline: a full (batch, seq) forward yields one
    // new token per sequence, i.e. `batch` tokens per forward.
    let tokens: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| ((i * 31 + 5) % 256) as i32).collect();
    let _warm = eval::forward_hidden(&rt, &fp, &tokens, None)?;
    let timer = Timer::start();
    let reps = 3;
    for _ in 0..reps {
        let _ = eval::forward_hidden(&rt, &fp, &tokens, None)?;
    }
    let pjrt_tok_s = (reps * cfg.batch) as f64 / timer.secs();

    for config in configs {
        let (spec, _) = parse_config(config)?;
        // parity vs the PJRT chain over RTN fake-quant weights
        let qps = baselines::rtn::quantize(&rt, &fp, spec)?;
        let mut h = rt.embed(&tokens, qps.globals())?;
        for b in 0..cfg.n_layers {
            h = rt.block_fp(&h, qps.block(b))?;
        }
        let pm = PackedModel::from_store(&fp, spec);
        let mut max_diff = 0.0f32;
        for s in 0..cfg.batch {
            let hh = crate::engine::hidden_full(&pm, &tokens[s * cfg.seq..(s + 1) * cfg.seq]);
            for (a, b) in hh.data.iter().zip(&h.data[s * cfg.seq * cfg.d_model..]) {
                max_diff = max_diff.max((a - b).abs());
            }
        }
        let mem_ratio = pm.fp16_linear_bytes() as f64 / pm.packed_bytes() as f64;
        // GEMM dispatch the packed linears resolved at pack time (captured
        // here — the model moves into the engine next)
        let kernel = pm.kernel_name().to_string();

        // engine throughput: 16 concurrent greedy decodes, chunked prefill;
        // a live recorder rides along so the table also reports inter-token
        // gap percentiles (telemetry never changes the sampled tokens)
        let mut engine = Engine::with_config(pm, 16, sched);
        engine.recorder = Recorder::new_enabled();
        // numeric health rides along: drift verdicts vs the baked
        // envelopes, and (when the config is above 2 bits) the w2
        // divergence sampler's top-1 agreement
        if spec.bits > 2 {
            engine.enable_draft(crate::quant::QuantSpec::new(2, spec.group));
        }
        let reqs: Vec<Request> = (0..16)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![(i * 11 % 256) as i32, 1, 2],
                max_new: 48,
                eos: None,
            })
            .collect();
        let timer = Timer::start();
        let (_, stats) = engine.generate(reqs, Sampler::Greedy, 0)?;
        let engine_tok_s = stats.tokens_processed as f64 / timer.secs();
        let (it_p50, it_p99) = engine
            .recorder
            .telemetry()
            .map(|t| (t.inter_token.percentile_ms(0.50), t.inter_token.percentile_ms(0.99)))
            .unwrap_or((0.0, 0.0));

        // TTFT: one near-table-length prompt, chunked prefill, 1 new token
        let ttft_prompt: Vec<i32> =
            (0..cfg.seq.saturating_sub(16).max(8)).map(|i| ((i * 13 + 7) % 256) as i32).collect();
        let ttft_req = vec![Request { id: 0, prompt: ttft_prompt, max_new: 1, eos: None }];
        let timer = Timer::start();
        let _ = engine.generate(ttft_req, Sampler::Greedy, 0)?;
        let ttft_ms = timer.secs() * 1e3;

        let (drift_layers, w2_agree) = engine
            .recorder
            .telemetry()
            .map(|tele| {
                let snap = tele.numeric.snapshot();
                let agree = if snap.div.probes == 0 {
                    "-".to_string()
                } else {
                    format!("{:.1}", snap.div.agree_pct())
                };
                (tele.numeric.drift_layers().to_string(), agree)
            })
            .unwrap_or_else(|| ("-".to_string(), "-".to_string()));

        t.row(vec![
            config.clone(),
            kernel,
            format!("{max_diff:.2e}"),
            format!("{mem_ratio:.2}x"),
            format!("{engine_tok_s:.0}"),
            format!("{it_p50:.3}"),
            format!("{it_p99:.3}"),
            format!("{ttft_ms:.2}"),
            format!("{pjrt_tok_s:.1}"),
            // robustness counters: zero offline, but the serving front-end
            // feeds the same RunStats — keeping the columns here makes a
            // nonzero value under `generate` an immediate red flag
            stats.shed_requests.to_string(),
            stats.deadline_evictions.to_string(),
            stats.starved_ticks.to_string(),
            // paged-KV residency: peak pages live at once and peak bytes
            // prefix sharing saved (0 here — no prompts repeat offline)
            stats.kv_pages_peak.to_string(),
            stats.kv_shared_bytes_peak.to_string(),
            drift_layers,
            w2_agree,
        ]);
        t.print_last();
    }
    save_table(&t, stem)?;
    Ok(t)
}
