//! Tiny CLI substrate (clap is not vendored offline): `--key value` /
//! `--flag` parsing plus the shared config-label grammar ("w3a16g128").

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::quant::QuantSpec;

/// Parsed command line: subcommand + options.
pub struct Cli {
    pub cmd: String,
    opts: HashMap<String, String>,
}

impl Cli {
    pub fn parse(args: &[String]) -> Result<Cli> {
        if args.is_empty() {
            bail!("no subcommand");
        }
        let cmd = args[0].clone();
        let mut opts = HashMap::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("empty option name");
                }
                // `--key=value` form: the only way to pass values starting
                // with `-` (e.g. `--temp=-1`); `=` binds tighter than the
                // space-separated form.
                if let Some((k, v)) = key.split_once('=') {
                    if k.is_empty() {
                        bail!("empty option name in {a:?}");
                    }
                    opts.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < args.len() && !args[i + 1].starts_with('-') {
                    opts.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    // boolean flag; a following `-…` token is never
                    // swallowed as its value (use `--key=-1` for that)
                    opts.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected positional argument {a:?} (negative values need --key=value)");
            }
        }
        Ok(Cli { cmd, opts })
    }

    pub fn from_env() -> Result<Cli> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Cli::parse(&args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

/// Parse a paper-notation config label: `w<bits>a<bits>[g<group>]`.
pub fn parse_config(label: &str) -> Result<(QuantSpec, u32)> {
    let rest = label
        .strip_prefix('w')
        .ok_or_else(|| anyhow::anyhow!("config must start with 'w': {label}"))?;
    let apos = rest.find('a').ok_or_else(|| anyhow::anyhow!("missing 'a' in {label}"))?;
    let wbits: u32 = rest[..apos].parse()?;
    let rest = &rest[apos + 1..];
    let (abits, group) = match rest.find('g') {
        Some(g) => (rest[..g].parse()?, rest[g + 1..].parse()?),
        None => (rest.parse()?, 0usize),
    };
    Ok((QuantSpec::new(wbits, group), abits))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_options_and_flags() {
        let c = Cli::parse(&s(&["train", "--model", "opt-s1", "--all", "--steps", "10"])).unwrap();
        assert_eq!(c.cmd, "train");
        assert_eq!(c.get("model"), Some("opt-s1"));
        assert!(c.flag("all"));
        assert_eq!(c.usize_or("steps", 0), 10);
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn equals_form_accepts_negative_values() {
        let c = Cli::parse(&s(&["gen", "--temp=-1", "--topk=40", "--greedy"])).unwrap();
        assert_eq!(c.f32_or("temp", 0.0), -1.0);
        assert_eq!(c.usize_or("topk", 0), 40);
        assert!(c.flag("greedy"));
        // a bare `-1` after a flag is rejected, not silently swallowed
        assert!(Cli::parse(&s(&["gen", "--temp", "-1"])).is_err());
        // `=` in the value is preserved
        let c = Cli::parse(&s(&["gen", "--expr=a=b"])).unwrap();
        assert_eq!(c.get("expr"), Some("a=b"));
        assert!(Cli::parse(&s(&["gen", "--=x"])).is_err());
    }

    #[test]
    fn config_labels_roundtrip() {
        for (label, bits, abits, group) in [
            ("w3a16", 3u32, 16u32, 0usize),
            ("w3a16g128", 3, 16, 128),
            ("w2a16g64", 2, 16, 64),
            ("w4a4", 4, 4, 0),
        ] {
            let (spec, a) = parse_config(label).unwrap();
            assert_eq!(spec.bits, bits, "{label}");
            assert_eq!(a, abits, "{label}");
            assert_eq!(spec.group, group, "{label}");
            assert_eq!(spec.label(a), label);
        }
        assert!(parse_config("x4a4").is_err());
    }
}
