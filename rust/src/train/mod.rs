//! LM pre-training driver: Adam over the flat `theta`, stepping through the
//! AOT `train_step` artifact (loss + grad come back from XLA; the optimizer
//! and data pipeline live here in rust).
//!
//! The paper quantizes *trained* models — PTQ error dynamics are only
//! meaningful on weight/activation distributions shaped by training — so
//! every experiment starts from a checkpoint produced here (`affinequant
//! train`).

use anyhow::Result;

use crate::data::{self, CorpusKind};
use crate::model::ParamStore;
use crate::rngx::Pcg32;
use crate::runtime::ModelRuntime;
use crate::util::Timer;

/// Adam with bias correction over one flat parameter vector.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.95, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// One update with a per-element LR scale (the calibration loop runs
    /// affine and LWC/shift entries at different rates in one instance).
    pub fn step_elem(&mut self, theta: &mut [f32], grad: &[f32], scales: &[f32]) {
        assert_eq!(theta.len(), self.m.len());
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t);
        let b2c = 1.0 - self.beta2.powi(self.t);
        for i in 0..theta.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1c;
            let vhat = self.v[i] / b2c;
            theta[i] -= self.lr * scales[i] * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// One update; `lr_scale` multiplies the base LR (schedules, GM damping
    /// is carried by the gradient itself).
    pub fn step(&mut self, theta: &mut [f32], grad: &[f32], lr_scale: f32) {
        assert_eq!(theta.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t);
        let b2c = 1.0 - self.beta2.powi(self.t);
        let lr = self.lr * lr_scale;
        for i in 0..theta.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1c;
            let vhat = self.v[i] / b2c;
            theta[i] -= lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

/// Linear warmup then cosine decay to 10% of peak.
pub fn lr_schedule(step: usize, total: usize, warmup: usize) -> f32 {
    if step < warmup {
        return (step + 1) as f32 / warmup as f32;
    }
    let p = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    0.1 + 0.45 * (1.0 + (std::f32::consts::PI * p).cos())
}

pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub corpus_bytes: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 600,
            lr: 3e-3,
            warmup: 50,
            corpus_bytes: 2_000_000,
            seed: 7,
            log_every: 50,
        }
    }
}

/// Train `ps` on the wt2s corpus; returns the loss curve (one entry per
/// logged step: (step, loss)).
pub fn train_lm(
    rt: &ModelRuntime,
    ps: &mut ParamStore,
    tc: &TrainConfig,
) -> Result<Vec<(usize, f64)>> {
    let cfg = &rt.cfg;
    let corpus = data::gen_corpus(CorpusKind::Wt2s, tc.corpus_bytes, 1);
    let mut rng = Pcg32::seeded(tc.seed);
    let mut adam = Adam::new(ps.theta.len(), tc.lr);
    let mut curve = Vec::new();
    let t = Timer::start();
    let mut window: Vec<f64> = Vec::new();
    for step in 0..tc.steps {
        let segs = data::sample_segments(&corpus, cfg.seq, cfg.train_batch, &mut rng);
        let (toks, tgts) = data::to_batch(&segs);
        let (loss, grad) = rt.train_step(&toks, &tgts, &ps.theta)?;
        adam.step(&mut ps.theta, &grad.data, lr_schedule(step, tc.steps, tc.warmup));
        window.push(loss);
        if (step + 1) % tc.log_every == 0 || step + 1 == tc.steps {
            let avg = crate::util::mean(&window);
            window.clear();
            curve.push((step + 1, avg));
            println!(
                "[train {}] step {:>5}/{} loss {:.4} ({:.1}s)",
                cfg.name,
                step + 1,
                tc.steps,
                avg,
                t.secs()
            );
        }
    }
    Ok(curve)
}

/// Checkpoint path convention shared by the CLI, examples and benches.
pub fn checkpoint_path(dir: &str, model: &str) -> String {
    format!("{dir}/{model}.aqck")
}

/// Load the checkpoint for `model`, or train + save it if missing.
pub fn ensure_checkpoint(
    rt: &ModelRuntime,
    ps: &mut ParamStore,
    dir: &str,
    tc: &TrainConfig,
) -> Result<()> {
    let path = checkpoint_path(dir, &rt.cfg.name);
    if std::path::Path::new(&path).exists() {
        ps.load_into(&path)?;
        println!("[train] loaded checkpoint {path}");
        return Ok(());
    }
    println!("[train] no checkpoint at {path}; training {} for {} steps", rt.cfg.name, tc.steps);
    ps.init(tc.seed);
    train_lm(rt, ps, tc)?;
    ps.save(&path)?;
    println!("[train] saved {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_reduces_quadratic() {
        // minimize f(x) = x² elementwise
        let mut x = vec![5.0f32, -3.0, 2.0];
        let mut adam = Adam::new(3, 0.1);
        for _ in 0..500 {
            let g: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
            adam.step(&mut x, &g, 1.0);
        }
        assert!(x.iter().all(|v| v.abs() < 1e-2), "{x:?}");
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // first step must move by ~lr regardless of gradient scale
        for scale in [1e-3f32, 1.0, 1e3] {
            let mut x = vec![0.0f32];
            let mut adam = Adam::new(1, 0.01);
            adam.step(&mut x, &[scale], 1.0);
            assert!((x[0] + 0.01).abs() < 1e-4, "scale {scale} -> {}", x[0]);
        }
    }

    #[test]
    fn schedule_shape() {
        assert!(lr_schedule(0, 100, 10) < lr_schedule(9, 100, 10));
        assert!((lr_schedule(9, 100, 10) - 1.0).abs() < 1e-6);
        assert!(lr_schedule(99, 100, 10) < 0.2);
        assert!(lr_schedule(99, 100, 10) >= 0.1);
    }
}
