//! Host-side quantizer substrate.
//!
//! Bit-for-bit twin of the L1/L2 fake-quantization (python
//! ``compile/quantize.py`` / the ``group_fq`` pallas kernel): per-group
//! asymmetric weight quantization over the input dim of a row-major
//! ``(in, out)`` weight, optional learnable-clipping (LWC) logits, per-token
//! activation quantization, integer code extraction + bit-packing (for the
//! weighted-memory model behind the paper's Pareto figure).

use crate::tensor::Tensor;

pub const EPS: f32 = 1e-8;

/// Weight-quantization spec: bits + group size (0 = per-output-channel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantSpec {
    pub bits: u32,
    pub group: usize,
}

impl QuantSpec {
    pub fn new(bits: u32, group: usize) -> Self {
        QuantSpec { bits, group }
    }

    pub fn qmax(&self) -> f32 {
        (1u64 << self.bits) as f32 - 1.0
    }

    /// Effective group length for an input dim.
    pub fn group_len(&self, din: usize) -> usize {
        if self.group == 0 {
            din
        } else {
            assert_eq!(din % self.group, 0, "group {} !| din {}", self.group, din);
            self.group
        }
    }

    /// "w3a16g128"-style label (paper notation).
    pub fn label(&self, act_bits: u32) -> String {
        let g = if self.group == 0 {
            String::new()
        } else {
            format!("g{}", self.group)
        };
        format!("w{}a{}{}", self.bits, act_bits, g)
    }
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Per-group scale/zero-point for one (group, column) cell.
#[derive(Clone, Copy, Debug)]
pub struct GroupQ {
    pub scale: f32,
    pub zp: f32,
}

fn cell_params(wmin: f32, wmax: f32, gamma: f32, beta: f32, qmax: f32) -> GroupQ {
    let cmax = sigmoid(gamma) * wmax;
    let cmin = sigmoid(beta) * wmin;
    if cmax - cmin <= qmax * EPS {
        // Degenerate (constant or fully-clipped) group: the generic formula
        // would floor the scale at EPS and put the zero-point at
        // `round(-cmin/EPS)` — far outside [0, qmax], so every code clamps
        // and dequant destroys the group. Encode the *clipped* midpoint
        // exactly instead: scale = |c| with the zero-point one code away,
        // so `(q - zp) * scale == c` bit-for-bit. (Midpoint of [cmin, cmax]
        // rather than [wmin, wmax], so LWC clipping is still honored; with
        // no clipping the two coincide and a constant group roundtrips
        // exactly.)
        let c = 0.5 * (cmax + cmin);
        if c == 0.0 {
            return GroupQ { scale: EPS, zp: 0.0 };
        }
        let zp = if c > 0.0 { 0.0 } else { 1.0 };
        return GroupQ { scale: c.abs(), zp };
    }
    let scale = ((cmax - cmin) / qmax).max(EPS);
    let zp = (-cmin / scale).round();
    GroupQ { scale, zp }
}

/// Fake quant-dequant of w (in, out). `lwc` = optional (gamma, beta) with
/// shape (din/g, out) each; None means no clipping (logit +20 ⇒ sigmoid≈1).
pub fn quant_dequant(w: &Tensor, spec: QuantSpec, lwc: Option<(&[f32], &[f32])>) -> Tensor {
    let (codes, params, shape) = quantize_codes(w, spec, lwc);
    dequantize_codes(&codes, &params, &shape, spec)
}

/// Integer codes + per-(group,col) params. Codes stored one-u8-per-element
/// (packing is separate so tests can inspect codes directly).
pub fn quantize_codes(
    w: &Tensor,
    spec: QuantSpec,
    lwc: Option<(&[f32], &[f32])>,
) -> (Vec<u8>, Vec<GroupQ>, Vec<usize>) {
    let (din, dout) = w.dims2();
    let g = spec.group_len(din);
    let ngroups = din / g;
    let qmax = spec.qmax();
    assert!(qmax <= 255.0, "codes are u8; bits must be <= 8");

    let mut params = Vec::with_capacity(ngroups * dout);
    let mut codes = vec![0u8; din * dout];
    for gi in 0..ngroups {
        for col in 0..dout {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for r in 0..g {
                let v = w.data[(gi * g + r) * dout + col];
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            let (ga, be) = match lwc {
                Some((ga, be)) => (ga[gi * dout + col], be[gi * dout + col]),
                None => (20.0, 20.0),
            };
            let p = cell_params(wmin, wmax, ga, be, qmax);
            for r in 0..g {
                let v = w.data[(gi * g + r) * dout + col];
                let q = ((v / p.scale).round() + p.zp).clamp(0.0, qmax);
                codes[(gi * g + r) * dout + col] = q as u8;
            }
            params.push(p);
        }
    }
    (codes, params, vec![din, dout])
}

pub fn dequantize_codes(
    codes: &[u8],
    params: &[GroupQ],
    shape: &[usize],
    spec: QuantSpec,
) -> Tensor {
    let (din, dout) = (shape[0], shape[1]);
    let g = spec.group_len(din);
    let mut out = Tensor::zeros(shape);
    for (i, &c) in codes.iter().enumerate() {
        let row = i / dout;
        let col = i % dout;
        let p = params[(row / g) * dout + col];
        out.data[i] = (c as f32 - p.zp) * p.scale;
    }
    out
}

/// Per-token (row) asymmetric fake quantization, matching
/// ``quantize.fake_quant_act`` (range always includes zero).
pub fn act_quant_dequant(x: &Tensor, bits: u32) -> Tensor {
    let (rows, d) = x.dims2();
    let qmax = (1u64 << bits) as f32 - 1.0;
    let mut out = Tensor::zeros(&[rows, d]);
    for i in 0..rows {
        let row = x.row(i);
        let xmin = row.iter().cloned().fold(f32::INFINITY, f32::min).min(0.0);
        let xmax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max).max(0.0);
        let scale = ((xmax - xmin) / qmax).max(EPS);
        let zp = (-xmin / scale).round();
        for (o, &v) in out.row_mut(i).iter_mut().zip(row) {
            let q = ((v / scale).round() + zp).clamp(0.0, qmax);
            *o = (q - zp) * scale;
        }
    }
    out
}

// ----------------------------------------------------------- bit packing

/// Pack b-bit codes little-endian into bytes (deployment storage format).
pub fn pack_bits(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!(bits >= 1 && bits <= 8);
    let mut out = vec![0u8; (codes.len() * bits as usize).div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(u32::from(c) < (1 << bits));
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

pub fn unpack_bits(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mask = ((1u16 << bits) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    out
}

// ------------------------------------------------------- memory model

/// Deployment bytes for one quantized (in, out) weight under `spec`:
/// packed int codes + fp16 scale & zero-point per (group, col).
pub fn weight_bytes(din: usize, dout: usize, spec: QuantSpec) -> usize {
    let g = spec.group_len(din);
    let ngroups = din / g;
    let codes = (din * dout * spec.bits as usize).div_ceil(8);
    let params = ngroups * dout * 2 * 2; // scale + zp, fp16 each
    codes + params
}

/// fp16 bytes for an unquantized tensor.
pub fn fp16_bytes(numel: usize) -> usize {
    numel * 2
}

/// Weighted-memory statistics for the Pareto figure (Fig. 4): quantized
/// weight matrices + fp16 everything-else (+ optional per-layer kept
/// matrices such as A⁻¹ for weight-only deployment).
pub fn quant_error(w: &Tensor, spec: QuantSpec) -> f64 {
    quant_dequant(w, spec, None).mse(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg32;

    fn rand_w(din: usize, dout: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        Tensor::randn(&[din, dout], 1.0, &mut rng)
    }

    #[test]
    fn error_bound_half_scale() {
        let w = rand_w(128, 64, 1);
        for (bits, group) in [(2, 0), (3, 64), (4, 128), (8, 0)] {
            let spec = QuantSpec::new(bits, group);
            let (codes, params, shape) = quantize_codes(&w, spec, None);
            let dq = dequantize_codes(&codes, &params, &shape, spec);
            let g = spec.group_len(128);
            for i in 0..128 {
                for j in 0..64 {
                    let p = params[(i / g) * 64 + j];
                    let err = (dq.at2(i, j) - w.at2(i, j)).abs();
                    assert!(err <= p.scale / 2.0 + 1e-6, "{bits} {group} {err}");
                }
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let w = rand_w(256, 128, 2);
        let errs: Vec<f64> = [2u32, 3, 4, 8]
            .iter()
            .map(|&b| quant_error(&w, QuantSpec::new(b, 0)))
            .collect();
        for pair in errs.windows(2) {
            assert!(pair[0] > pair[1], "{errs:?}");
        }
    }

    #[test]
    fn smaller_groups_less_error() {
        let w = rand_w(256, 128, 3);
        let e_pc = quant_error(&w, QuantSpec::new(3, 0));
        let e_g128 = quant_error(&w, QuantSpec::new(3, 128));
        let e_g64 = quant_error(&w, QuantSpec::new(3, 64));
        assert!(e_pc >= e_g128 && e_g128 >= e_g64, "{e_pc} {e_g128} {e_g64}");
    }

    #[test]
    fn codes_in_range_and_roundtrip() {
        let w = rand_w(64, 128, 4);
        let spec = QuantSpec::new(3, 0);
        let (codes, params, shape) = quantize_codes(&w, spec, None);
        assert!(codes.iter().all(|&c| c <= 7));
        // quantizing the dequantized tensor is idempotent
        let dq = dequantize_codes(&codes, &params, &shape, spec);
        let (codes2, params2, _) = quantize_codes(&dq, spec, None);
        let dq2 = dequantize_codes(&codes2, &params2, &shape, spec);
        assert!(dq.mse(&dq2) < 1e-12);
    }

    #[test]
    fn constant_groups_roundtrip_exactly() {
        // all-zero, all-positive-equal, all-negative-equal weights: dequant
        // must reproduce the constant bit-for-bit (regression: the EPS
        // scale floor used to put the zero-point at ~1e8 and clamp every
        // code to garbage)
        for &c in &[0.0f32, 1.0, -1.0, 0.037, -2.5e-3, 1234.5] {
            let w = Tensor::new(vec![64, 8], vec![c; 64 * 8]);
            for (bits, group) in [(2u32, 0usize), (3, 32), (4, 16), (8, 64)] {
                let spec = QuantSpec::new(bits, group);
                let (codes, params, shape) = quantize_codes(&w, spec, None);
                assert!(
                    codes.iter().all(|&q| f32::from(q) <= spec.qmax()),
                    "w{bits}g{group} c={c}: code out of range"
                );
                for p in &params {
                    assert!(
                        p.zp >= 0.0 && p.zp <= spec.qmax(),
                        "w{bits}g{group} c={c}: zero-point {} outside [0, qmax]",
                        p.zp
                    );
                }
                let dq = dequantize_codes(&codes, &params, &shape, spec);
                assert!(
                    dq.data.iter().all(|&v| v == c),
                    "w{bits}g{group}: constant {c} not reproduced, got {}",
                    dq.data[0]
                );
            }
        }
    }

    #[test]
    fn mixed_constant_and_normal_groups() {
        // one constant group amid random ones must not perturb the others
        let mut w = rand_w(128, 16, 11);
        for col in 0..16 {
            for r in 0..32 {
                w.data[r * 16 + col] = 0.25; // first g=32 group constant
            }
        }
        let spec = QuantSpec::new(4, 32);
        let (codes, params, shape) = quantize_codes(&w, spec, None);
        let dq = dequantize_codes(&codes, &params, &shape, spec);
        for col in 0..16 {
            for r in 0..32 {
                assert_eq!(dq.at2(r, col), 0.25, "constant group row {r} col {col}");
            }
        }
        let g = spec.group_len(128);
        for r in 32..128 {
            for col in 0..16 {
                let p = params[(r / g) * 16 + col];
                let err = (dq.at2(r, col) - w.at2(r, col)).abs();
                assert!(err <= p.scale / 2.0 + 1e-6, "row {r} col {col}: {err}");
            }
        }
    }

    #[test]
    fn lwc_strong_clip_shrinks_range() {
        let w = rand_w(128, 64, 5);
        let n = 64;
        let wide = vec![20.0f32; n];
        let tight = vec![-1.0f32; n];
        let dq_wide = quant_dequant(&w, QuantSpec::new(4, 0), Some((&wide, &wide)));
        let dq_tight = quant_dequant(&w, QuantSpec::new(4, 0), Some((&tight, &tight)));
        assert!(dq_tight.max_abs() < dq_wide.max_abs());
    }

    #[test]
    fn pack_roundtrip_all_bits() {
        let mut rng = Pcg32::seeded(6);
        for bits in [2u32, 3, 4, 8] {
            let n = 1000;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
            assert_eq!(unpack_bits(&packed, bits, n), codes);
        }
    }

    #[test]
    fn act_quant_matches_semantics() {
        let mut rng = Pcg32::seeded(7);
        let x = Tensor::randn(&[16, 32], 2.0, &mut rng);
        let dq = act_quant_dequant(&x, 8);
        assert!(x.mse(&dq) < 1e-3);
        // zero rows stay zero
        let mut z = x.clone();
        z.row_mut(0).fill(0.0);
        let dqz = act_quant_dequant(&z, 4);
        assert!(dqz.row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn memory_model_orders_configs() {
        // w2g128 < w3g128 < w4g128 < fp16, and grouping adds param overhead
        let b2 = weight_bytes(4096, 4096, QuantSpec::new(2, 128));
        let b3 = weight_bytes(4096, 4096, QuantSpec::new(3, 128));
        let b4 = weight_bytes(4096, 4096, QuantSpec::new(4, 128));
        assert!(b2 < b3 && b3 < b4 && b4 < fp16_bytes(4096 * 4096));
        let pc = weight_bytes(4096, 4096, QuantSpec::new(4, 0));
        assert!(pc < b4);
    }

    #[test]
    fn label_matches_paper_notation() {
        assert_eq!(QuantSpec::new(3, 128).label(16), "w3a16g128");
        assert_eq!(QuantSpec::new(4, 0).label(4), "w4a4");
    }
}
