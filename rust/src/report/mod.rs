//! Result recording: CSV/markdown writers under `results/`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::benchx::Table;
use crate::util::ensure_parent;

/// Process-wide monotonic sequence for [`log_line`] stamps.
static LOG_SEQ: AtomicU64 = AtomicU64::new(0);

/// Prefix `line` with the next value of the monotonic sequence counter —
/// `[000042] line`. Lines written by concurrent threads interleave in the
/// file, but their stamps give a total order over emission.
pub fn stamp(line: &str) -> String {
    let seq = LOG_SEQ.fetch_add(1, Ordering::Relaxed);
    format!("[{seq:06}] {line}")
}

/// Write a table to `results/<stem>.md` and `results/<stem>.csv`.
pub fn save_table(table: &Table, stem: &str) -> std::io::Result<()> {
    let md = format!("results/{stem}.md");
    let csv = format!("results/{stem}.csv");
    ensure_parent(&md)?;
    std::fs::write(&md, table.to_markdown())?;
    std::fs::write(&csv, table.to_csv())?;
    println!("saved results/{stem}.{{md,csv}}");
    Ok(())
}

/// Write a JSON value to an explicit path (e.g. the `BENCH_<pr>.json`
/// perf-trajectory snapshots the ROADMAP asks for — repo-root files that
/// persist across PRs so regressions are visible at re-anchor time).
pub fn save_json(path: &str, v: &crate::jsonx::Value) -> std::io::Result<()> {
    ensure_parent(path)?;
    let mut text = crate::jsonx::emit(v);
    text.push('\n');
    std::fs::write(path, text)?;
    println!("saved {path}");
    Ok(())
}

/// Append a line to results/log.txt with a timestamp counter ([`stamp`]).
pub fn log_line(line: &str) -> std::io::Result<()> {
    use std::io::Write;
    ensure_parent("results/log.txt")?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("results/log.txt")?;
    writeln!(f, "{}", stamp(line))
}

/// Save (x, y) series as CSV for the figure benches.
pub fn save_series(stem: &str, header: &str, rows: &[(f64, f64)]) -> std::io::Result<()> {
    let path = format!("results/{stem}.csv");
    ensure_parent(&path)?;
    let mut s = String::from(header);
    s.push('\n');
    for (x, y) in rows {
        s.push_str(&format!("{x},{y}\n"));
    }
    std::fs::write(&path, s)?;
    println!("saved {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn stamp_is_monotonic_and_formatted() {
        // other tests may also draw from the shared sequence, so assert
        // strict ordering of this thread's draws rather than exact values
        let a = super::stamp("hello");
        let b = super::stamp("world");
        let seq = |s: &str| -> u64 {
            assert!(s.starts_with('['), "{s}");
            let close = s.find(']').unwrap();
            assert!(close >= 7, "zero-padded to 6 digits: {s}");
            s[1..close].parse().unwrap()
        };
        assert!(seq(&b) > seq(&a), "{a} then {b}");
        assert!(a.ends_with("] hello"));
        assert!(b.ends_with("] world"));
    }

    #[test]
    fn series_format() {
        // formatting only; file IO covered by integration tests
        let rows = [(1.0, 2.0), (3.0, 4.5)];
        let mut s = String::from("x,y\n");
        for (x, y) in rows {
            s.push_str(&format!("{x},{y}\n"));
        }
        assert_eq!(s, "x,y\n1,2\n3,4.5\n");
    }
}
