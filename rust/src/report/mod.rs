//! Result recording: CSV/markdown writers under `results/`.

use crate::benchx::Table;
use crate::util::ensure_parent;

/// Write a table to `results/<stem>.md` and `results/<stem>.csv`.
pub fn save_table(table: &Table, stem: &str) -> std::io::Result<()> {
    let md = format!("results/{stem}.md");
    let csv = format!("results/{stem}.csv");
    ensure_parent(&md)?;
    std::fs::write(&md, table.to_markdown())?;
    std::fs::write(&csv, table.to_csv())?;
    println!("saved results/{stem}.{{md,csv}}");
    Ok(())
}

/// Write a JSON value to an explicit path (e.g. the `BENCH_<pr>.json`
/// perf-trajectory snapshots the ROADMAP asks for — repo-root files that
/// persist across PRs so regressions are visible at re-anchor time).
pub fn save_json(path: &str, v: &crate::jsonx::Value) -> std::io::Result<()> {
    ensure_parent(path)?;
    let mut text = crate::jsonx::emit(v);
    text.push('\n');
    std::fs::write(path, text)?;
    println!("saved {path}");
    Ok(())
}

/// Append a line to results/log.txt with a timestamp counter.
pub fn log_line(line: &str) -> std::io::Result<()> {
    use std::io::Write;
    ensure_parent("results/log.txt")?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("results/log.txt")?;
    writeln!(f, "{line}")
}

/// Save (x, y) series as CSV for the figure benches.
pub fn save_series(stem: &str, header: &str, rows: &[(f64, f64)]) -> std::io::Result<()> {
    let path = format!("results/{stem}.csv");
    ensure_parent(&path)?;
    let mut s = String::from(header);
    s.push('\n');
    for (x, y) in rows {
        s.push_str(&format!("{x},{y}\n"));
    }
    std::fs::write(&path, s)?;
    println!("saved {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn series_format() {
        // formatting only; file IO covered by integration tests
        let rows = [(1.0, 2.0), (3.0, 4.5)];
        let mut s = String::from("x,y\n");
        for (x, y) in rows {
            s.push_str(&format!("{x},{y}\n"));
        }
        assert_eq!(s, "x,y\n1,2\n3,4.5\n");
    }
}
