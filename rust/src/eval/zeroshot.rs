//! Six synthetic zero-shot tasks (substitutes for PIQA / ARC-e / WinoGrande
//! / BoolQ / ARC-c / HellaSwag — DESIGN.md §2). Each task is a two-choice
//! continuation problem over the synthetic grammar; scoring follows the
//! standard harness: pick the continuation with the lower mean NLL. The
//! tasks probe regularities the corpus actually teaches (grammaticality,
//! bracket closing, copying, word frequency, adjective order, word class),
//! so accuracy degrades with quantization noise like the paper's suite.

use anyhow::Result;

use crate::data::{self, Vocab};
use crate::eval::forward_hidden;
use crate::model::ParamStore;
use crate::rngx::Pcg32;
use crate::runtime::ModelRuntime;

pub const TASKS: [&str; 6] = ["accept", "bracket", "copy", "freq", "order", "suffix"];

/// One two-choice example: shared prompt + (correct, wrong) continuations.
pub struct Example {
    pub prompt: String,
    pub good: String,
    pub bad: String,
}

/// Generate one example for `task`.
pub fn gen_example(task: &str, vocab: &Vocab, rng: &mut Pcg32) -> Example {
    let noun = |rng: &mut Pcg32| vocab.nouns[rng.below(vocab.nouns.len())].clone();
    let sent = |rng: &mut Pcg32| data::sentence(vocab, rng, 0);
    match task {
        // grammatical sentence vs its word-shuffled permutation
        "accept" => {
            let good = format!("{}. ", sent(rng));
            let mut words: Vec<String> =
                good.trim_end_matches(". ").split(' ').map(String::from).collect();
            // deterministic derangement: rotate by half
            let half = words.len() / 2;
            words.rotate_left(half);
            let bad = format!("{}. ", words.join(" "));
            Example { prompt: format!("{}. ", sent(rng)), good, bad }
        }
        // close the open parenthesis vs opening another
        "bracket" => {
            let prompt = format!("{}. the {} ( of the {}", sent(rng), noun(rng), noun(rng));
            Example { prompt, good: " )".into(), bad: " (".into() }
        }
        // repeated-phrase copying: "... the X and the" -> X
        "copy" => {
            let x = noun(rng);
            let mut y = noun(rng);
            while y == x {
                y = noun(rng);
            }
            let prompt = format!("{}. the {} and the", sent(rng), x);
            Example { prompt, good: format!(" {x}"), bad: format!(" {y}") }
        }
        // Zipf head vs tail noun after "the"
        "freq" => {
            let common = vocab.nouns[rng.below(3)].clone();
            let rare = vocab.nouns[vocab.nouns.len() - 1 - rng.below(3)].clone();
            let prompt = format!("{}. the", sent(rng));
            Example { prompt, good: format!(" {common}"), bad: format!(" {rare}") }
        }
        // adjective precedes noun in the grammar, never follows
        "order" => {
            let a = vocab.adjs[rng.below(vocab.adjs.len())].clone();
            let n = noun(rng);
            let prompt = format!("{}. the", sent(rng));
            Example { prompt, good: format!(" {a} {n}"), bad: format!(" {n} {a}") }
        }
        // after "the <noun>" a verb (s-suffixed) is grammatical, "the" is not
        "suffix" => {
            let v = vocab.verbs[rng.below(vocab.verbs.len())].clone();
            let prompt = format!("{}. the {}", sent(rng), noun(rng));
            Example { prompt, good: format!(" {v}"), bad: " the the".into() }
        }
        other => panic!("unknown zero-shot task {other:?}"),
    }
}

/// Build a fixed-length token sequence `[pad..., prompt, continuation]` and
/// the target-position mask over the continuation bytes.
fn build_seq(prompt: &str, cont: &str, seq: usize, pad: &[u8]) -> (Vec<i32>, Vec<f32>) {
    let p = prompt.as_bytes();
    let c = cont.as_bytes();
    assert!(p.len() + c.len() < seq, "example longer than context");
    let total = seq + 1;
    let mut bytes = Vec::with_capacity(total);
    let pad_n = total - p.len() - c.len();
    bytes.extend_from_slice(&pad[pad.len() - pad_n..]);
    bytes.extend_from_slice(p);
    bytes.extend_from_slice(c);
    let toks: Vec<i32> = bytes[..seq].iter().map(|&b| b as i32).collect();
    // target t predicts bytes[t+1]; continuation occupies the last c.len()
    let mut mask = vec![0.0f32; seq];
    for m in mask.iter_mut().skip(seq - c.len()) {
        *m = 1.0;
    }
    (toks, mask)
}

/// Accuracy of `ps` on `task` over `n` examples (must be a multiple of
/// batch/2). Candidates are scored by mean NLL over continuation tokens.
pub fn accuracy(
    rt: &ModelRuntime,
    ps: &ParamStore,
    task: &str,
    n: usize,
    act_qmax: Option<f32>,
    seed: u64,
) -> Result<f64> {
    let cfg = &ps.cfg;
    let vocab = Vocab::build(1234);
    let mut rng = Pcg32::seeded(seed);
    let pad = data::gen_corpus(data::CorpusKind::Wt2s, 4 * cfg.seq, 5);
    let per_batch = cfg.batch / 2; // two candidates per example
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut examples: Vec<Example> = (0..n).map(|_| gen_example(task, &vocab, &mut rng)).collect();
    while examples.len() % per_batch != 0 {
        examples.pop();
    }
    for chunk in examples.chunks(per_batch) {
        let mut toks = Vec::with_capacity(cfg.batch * cfg.seq);
        let mut tgts = Vec::with_capacity(cfg.batch * cfg.seq);
        let mut masks = Vec::with_capacity(cfg.batch * cfg.seq);
        let mut counts = Vec::with_capacity(cfg.batch);
        for ex in chunk {
            for cont in [&ex.good, &ex.bad] {
                let (seq_toks, mask) = build_seq(&ex.prompt, cont, cfg.seq, &pad);
                // shift: input toks[..seq], target toks[1..] + last cont byte
                let full: Vec<i32> = {
                    let mut f = seq_toks.clone();
                    f.push(*cont.as_bytes().last().unwrap() as i32);
                    f
                };
                toks.extend_from_slice(&full[..cfg.seq]);
                tgts.extend_from_slice(&full[1..]);
                counts.push(mask.iter().sum::<f32>());
                masks.extend_from_slice(&mask);
            }
        }
        let h = forward_hidden(rt, ps, &toks, act_qmax)?;
        let nll = rt.head_nll(&h, &tgts, &masks, ps.globals())?;
        for (i, _) in chunk.iter().enumerate() {
            let mean_good = nll.data[2 * i] / counts[2 * i];
            let mean_bad = nll.data[2 * i + 1] / counts[2 * i + 1];
            if mean_good < mean_bad {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(100.0 * correct as f64 / total as f64)
}

/// Average accuracy over all six tasks.
pub fn suite(
    rt: &ModelRuntime,
    ps: &ParamStore,
    n_per_task: usize,
    act_qmax: Option<f32>,
) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for (ti, task) in TASKS.iter().enumerate() {
        let acc = accuracy(rt, ps, task, n_per_task, act_qmax, 1000 + ti as u64)?;
        out.push((task.to_string(), acc));
    }
    let avg = out.iter().map(|(_, a)| *a).sum::<f64>() / out.len() as f64;
    out.push(("avg".into(), avg));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_well_formed() {
        let vocab = Vocab::build(1234);
        let mut rng = Pcg32::seeded(7);
        for task in TASKS {
            for _ in 0..20 {
                let ex = gen_example(task, &vocab, &mut rng);
                assert_ne!(ex.good, ex.bad, "{task}");
                assert!(ex.prompt.len() + ex.good.len() < 120, "{task} too long");
                assert!(ex.prompt.len() + ex.bad.len() < 120, "{task} too long");
            }
        }
    }

    #[test]
    fn build_seq_mask_covers_continuation() {
        let pad = vec![b'x'; 512];
        let (toks, mask) = build_seq("the cat", " sat", 64, &pad);
        assert_eq!(toks.len(), 64);
        assert_eq!(mask.len(), 64);
        assert_eq!(mask.iter().sum::<f32>(), 4.0);
        // masked positions are the last 4
        assert!(mask[60..].iter().all(|&m| m == 1.0));
        assert!(mask[..60].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn bracket_task_is_single_byte_fair() {
        let vocab = Vocab::build(1234);
        let mut rng = Pcg32::seeded(8);
        let ex = gen_example("bracket", &vocab, &mut rng);
        assert_eq!(ex.good.len(), ex.bad.len());
    }
}
