//! Evaluation harnesses: perplexity over the three corpora, the six
//! synthetic zero-shot tasks (paper Table 2 protocol: pick the candidate
//! continuation with the higher log-probability), and the correlation
//! statistics behind Figs. 5/6.

pub mod zeroshot;

use anyhow::Result;

use crate::data::{self, CorpusKind};
use crate::model::ParamStore;
use crate::runtime::ModelRuntime;
use crate::tensor::Tensor;

/// Forward a token batch through embed + all blocks. `act_qmax` selects the
/// serving graph: None ⇒ `block_fp`, Some ⇒ `block_a4` (per-token dynamic
/// activation fake-quant at the four linear inputs).
pub fn forward_hidden(
    rt: &ModelRuntime,
    ps: &ParamStore,
    tokens: &[i32],
    act_qmax: Option<f32>,
) -> Result<Tensor> {
    let mut h = rt.embed(tokens, ps.globals())?;
    for i in 0..ps.cfg.n_layers {
        h = match act_qmax {
            Some(q) => rt.block_a4(&h, ps.block(i), q)?,
            None => rt.block_fp(&h, ps.block(i))?,
        };
    }
    Ok(h)
}

/// Activation qmax for a bit-width (None ⇒ FP activations).
pub fn act_qmax(act_bits: u32) -> Option<f32> {
    if act_bits >= 16 {
        None
    } else {
        Some((1u64 << act_bits) as f32 - 1.0)
    }
}

/// Deterministic PPL protocol: sequential non-overlapping segments,
/// `max_batches` batches of the artifact batch size.
pub fn perplexity(
    rt: &ModelRuntime,
    ps: &ParamStore,
    kind: CorpusKind,
    max_batches: usize,
    act_qmax: Option<f32>,
) -> Result<f64> {
    let cfg = &ps.cfg;
    let corpus = data::gen_corpus(kind, (max_batches * cfg.batch * cfg.seq + cfg.seq) * 2, 99);
    let segs = data::eval_segments(&corpus, cfg.seq, max_batches * cfg.batch);
    let ones = vec![1.0f32; cfg.batch * cfg.seq];
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for chunk in segs.chunks(cfg.batch) {
        if chunk.len() < cfg.batch {
            break;
        }
        let (toks, tgts) = data::to_batch(chunk);
        let h = forward_hidden(rt, ps, &toks, act_qmax)?;
        let nll = rt.head_nll(&h, &tgts, &ones, ps.globals())?;
        total_nll += nll.data.iter().map(|&v| v as f64).sum::<f64>();
        total_tok += cfg.batch * cfg.seq;
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Pearson correlation coefficient (Figs. 5/6: loss ↔ PPL, r ≈ 0.95).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(1e-300)
}

/// Weighted deployed memory of a quantized model (Fig. 4 x-axis): packed
/// integer codes + per-group fp16 scale/zp for every quantized matrix,
/// fp16 for everything else, plus — in weight-only mode — the kept
/// `A⁻¹`/`A_out` matrices per block (d² + h·hd² fp16 each).
pub fn weighted_memory_bytes(
    ps: &ParamStore,
    spec: crate::quant::QuantSpec,
    weight_only_affine_kept: bool,
) -> usize {
    let cfg = &ps.cfg;
    let quantized: Vec<(&str, usize, usize)> = cfg.quantized_weights();
    let mut total = 0usize;
    // globals stay fp16
    total += crate::quant::fp16_bytes(ps.globals_layout.size);
    for _ in 0..cfg.n_layers {
        for (name, shape, _) in ps.block_layout.entries.clone() {
            if let Some((_, din, dout)) = quantized.iter().find(|(n, _, _)| *n == name) {
                total += crate::quant::weight_bytes(*din, *dout, spec);
            } else {
                total += crate::quant::fp16_bytes(crate::tensor::numel(&shape));
            }
        }
        if weight_only_affine_kept {
            // A_qkv⁻¹, A_fc1⁻¹ (d×d each) + per-head A_out (h·hd²)
            total += crate::quant::fp16_bytes(
                2 * cfg.d_model * cfg.d_model + cfg.n_heads * cfg.head_dim * cfg.head_dim,
            );
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_extremes() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
        let noise = [1.0, -1.0, 1.0, -1.0];
        assert!(pearson(&x, &noise).abs() < 0.5);
    }

    #[test]
    fn act_qmax_values() {
        assert_eq!(act_qmax(16), None);
        assert_eq!(act_qmax(4), Some(15.0));
        assert_eq!(act_qmax(8), Some(255.0));
    }
}
