//! Overload-safe HTTP serving front-end over the packed engine.
//!
//! The ROADMAP's north star needs a network front door that *degrades
//! gracefully*: the merged-transform serving path only stays "no overhead"
//! (FlatQuant/OstQuant's assumption) if the layer above the kernels —
//! admission, queueing, timeouts — never becomes the failure mode. Layout
//! (Actyx-style node-API / event-stream separation):
//!
//! * [`http`]        — HTTP/1.1 parsing + fixed/chunked response writers;
//! * [`admission`]   — bounded in-flight ceiling + per-client caps (429);
//! * [`engine_loop`] — the one thread that owns the model and streams
//!   tokens per scheduler tick;
//! * [`fault`]       — deterministic fault injection (delays, drops);
//! * this module     — listener, worker pool, routing, drain.
//!
//! ## Endpoints
//!
//! | endpoint               | behaviour                                       |
//! |------------------------|-------------------------------------------------|
//! | `POST /v1/completions` | OpenAI-style; `"stream": true` = SSE over chunked transfer |
//! | `GET /healthz`         | liveness + drain state                          |
//! | `GET /v1/stats`        | admission/scheduler/HTTP counters (JSON)        |
//! | `POST /admin/shutdown` | begin graceful drain (what SIGTERM also does)   |
//!
//! ## Degradation ladder
//!
//! 1. queue has room → admit; tokens stream as the scheduler ticks;
//! 2. in-flight ceiling (`max_batch + queue_cap`) or per-client cap hit →
//!    **429** + `Retry-After` (the scheduler's pending deque is bounded by
//!    construction — overload sheds, it never queues unboundedly);
//! 3. per-request deadline passes (queued or mid-decode) → evicted with
//!    [`FinishReason::Deadline`](crate::engine::FinishReason) → **504**
//!    (non-stream) or a `"finish_reason":"deadline"` terminator (stream);
//! 4. client disconnects mid-stream → the send fails → the sequence is
//!    cancelled and its KV slot freed the same tick;
//! 5. SIGTERM / `/admin/shutdown` → stop accepting (503), finish every
//!    admitted request, then exit.
//!
//! Greedy streamed tokens are bit-identical to offline
//! [`Engine::generate`] output — same scheduler, same tick, same kernels
//! (`rust/tests/server.rs` asserts this over a real socket).

pub mod admission;
pub mod engine_loop;
pub mod fault;
pub mod http;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{Completion, Engine, FinishReason, Request, Sampler, SubmitError};
use crate::jsonx::{self, Value};

use admission::{Admission, AdmitError};
use engine_loop::{EngineGauges, Job, StreamEvent};
use fault::FaultConfig;

/// Serving knobs; `Default` is a sane single-box profile.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Pending-queue bound beyond the batch slots; the in-flight ceiling
    /// is `max_batch + queue_cap`. Must be > 0 — serving without a bound
    /// is exactly the failure mode this front-end exists to prevent.
    pub queue_cap: usize,
    /// Per-client concurrent-request cap (keyed by `client_id` or peer
    /// IP); 0 = unlimited.
    pub client_cap: usize,
    /// `max_tokens` when the request omits it.
    pub default_max_new: usize,
    /// Deadline applied when the request omits `deadline_ms`; 0 = none.
    pub default_deadline_ms: u64,
    /// `Retry-After` seconds on 429/503.
    pub retry_after_s: u64,
    /// Sampler for every request (per-request sampling params are not
    /// honoured: one scheduler session shares one sampler + RNG).
    pub sampler: Sampler,
    /// RNG seed for the serving session (relevant to top-k only).
    pub seed: u64,
    pub fault: FaultConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 4,
            queue_cap: 32,
            client_cap: 8,
            default_max_new: 64,
            default_deadline_ms: 0,
            retry_after_s: 1,
            sampler: Sampler::Greedy,
            seed: 0,
            fault: FaultConfig::default(),
        }
    }
}

/// HTTP-layer counters (the engine/scheduler ones live in
/// [`EngineGauges`], admission's in [`Admission`]).
#[derive(Default)]
pub struct Metrics {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub completed_2xx: AtomicU64,
    pub bad_requests: AtomicU64,
    pub shed_429: AtomicU64,
    pub unavailable_503: AtomicU64,
    pub deadline_504: AtomicU64,
    pub disconnects: AtomicU64,
}

struct Ctx {
    cfg: ServerConfig,
    model_name: String,
    max_batch: usize,
    admission: Arc<Admission>,
    job_tx: Sender<Job>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    gauges: Arc<EngineGauges>,
}

/// A running server; dropping the handle does NOT stop it — call
/// [`shutdown`](ServerHandle::shutdown) then [`join`](ServerHandle::join).
pub struct Server;

pub struct ServerHandle {
    pub addr: SocketAddr,
    draining: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub gauges: Arc<EngineGauges>,
}

impl ServerHandle {
    /// Begin graceful drain: stop admitting, finish in-flight, exit.
    pub fn shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Wait for every thread (accept, workers, engine) to exit.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Set by the SIGTERM/SIGINT handler; every server observes it.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Route SIGTERM/SIGINT into graceful drain (unix; no-op elsewhere).
/// Kept out of `Server::spawn` so tests can run servers un-hooked.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        extern "C" fn on_signal(_sig: i32) {
            SIGNAL_DRAIN.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(15, on_signal as usize); // SIGTERM
            signal(2, on_signal as usize); // SIGINT
        }
    }
}

impl Server {
    /// Bind, spawn the accept loop + worker pool + engine thread, and
    /// return immediately. `engine.sched.queue_cap` is overwritten from
    /// `cfg.queue_cap` so the scheduler's own bound always matches the
    /// admission ceiling.
    pub fn spawn(mut engine: Engine, cfg: ServerConfig) -> Result<ServerHandle> {
        anyhow::ensure!(
            cfg.queue_cap > 0,
            "serving without a queue cap is unbounded by definition"
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr()?;

        engine.sched.queue_cap = cfg.queue_cap;
        let max_batch = engine.max_batch;
        let fault = cfg.fault.with_env();
        let draining = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::default());
        let gauges = Arc::new(EngineGauges::default());
        let admission = Admission::new(max_batch + cfg.queue_cap, cfg.client_cap);
        let (job_tx, job_rx) = channel::<Job>();
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let ctx = Arc::new(Ctx {
            model_name: engine.model.cfg.name.clone(),
            max_batch,
            admission,
            job_tx,
            next_id: AtomicU64::new(1),
            draining: Arc::clone(&draining),
            metrics: Arc::clone(&metrics),
            gauges: Arc::clone(&gauges),
            cfg: ServerConfig { fault, ..cfg },
        });

        let mut threads = Vec::new();

        // engine thread: owns the model; exits once every worker is gone
        // (job channel closed) and all admitted sequences finished
        {
            let gauges = Arc::clone(&gauges);
            let sampler = ctx.cfg.sampler;
            let seed = ctx.cfg.seed;
            threads.push(std::thread::spawn(move || {
                engine_loop::run(&mut engine, job_rx, sampler, seed, fault, &gauges);
            }));
        }

        // worker pool: drain accepted connections
        for _ in 0..ctx.cfg.workers.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let ctx = Arc::clone(&ctx);
            threads.push(std::thread::spawn(move || loop {
                let conn = {
                    let rx = conn_rx.lock().expect("conn queue lock poisoned");
                    rx.recv()
                };
                match conn {
                    Ok(stream) => handle_connection(stream, &ctx),
                    Err(_) => break, // accept loop gone and queue drained
                }
            }));
        }

        // accept loop: nonblocking so drain is noticed promptly
        {
            let draining = Arc::clone(&draining);
            threads.push(std::thread::spawn(move || {
                loop {
                    if draining.load(Ordering::SeqCst) || SIGNAL_DRAIN.load(Ordering::SeqCst) {
                        draining.store(true, Ordering::SeqCst);
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
                // dropping conn_tx ends the workers once the backlog drains
            }));
        }

        Ok(ServerHandle { addr, draining, threads, metrics, gauges })
    }
}

// ------------------------------------------------------------ connection

fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    ctx.metrics.connections.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let peer = stream.peer_addr().map(|a| a.ip().to_string()).unwrap_or_default();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;

    let req = match http::HttpRequest::read_from(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(&mut writer, 400, &[], &err_json(&e));
            return;
        }
    };
    ctx.metrics.requests.fetch_add(1, Ordering::Relaxed);

    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => handle_completions(&req, &mut writer, ctx, &peer),
        ("GET", "/healthz") => {
            let draining = ctx.draining.load(Ordering::SeqCst);
            let body = jsonx::emit(&jsonx::obj(vec![
                ("status", jsonx::s(if draining { "draining" } else { "ok" })),
                ("pending", jsonx::num(ctx.gauges.pending.load(Ordering::Relaxed) as f64)),
                ("active", jsonx::num(ctx.gauges.active.load(Ordering::Relaxed) as f64)),
            ]));
            let _ = http::write_json(&mut writer, 200, &[], &body);
        }
        ("GET", "/v1/stats") => {
            let _ = http::write_json(&mut writer, 200, &[], &stats_json(ctx));
        }
        ("POST", "/admin/shutdown") => {
            ctx.draining.store(true, Ordering::SeqCst);
            let _ = http::write_json(&mut writer, 202, &[], "{\"status\":\"draining\"}");
        }
        ("POST" | "GET", _) => {
            let _ = http::write_json(&mut writer, 404, &[], &err_json("no such endpoint"));
        }
        _ => {
            let _ = http::write_json(&mut writer, 405, &[], &err_json("method not allowed"));
        }
    }
}

// ------------------------------------------------------------ completion

/// Parsed `/v1/completions` payload.
struct CompletionParams {
    prompt: Vec<i32>,
    max_new: usize,
    stream: bool,
    eos: Option<i32>,
    deadline_ms: u64,
    client: String,
}

fn parse_completion(body: &[u8], ctx: &Ctx, peer: &str) -> Result<CompletionParams, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let v = jsonx::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let prompt = match v.get("prompt") {
        Some(Value::Str(s)) => s.bytes().map(|b| b as i32).collect(),
        Some(_) => return Err("\"prompt\" must be a string".into()),
        None => return Err("missing \"prompt\"".into()),
    };
    let max_new = match get_num(&v, &["max_tokens", "max_new"]) {
        Some(n) if n >= 0.0 => n as usize,
        Some(_) => return Err("\"max_tokens\" must be non-negative".into()),
        None => ctx.cfg.default_max_new,
    };
    let stream = matches!(v.get("stream"), Some(Value::Bool(true)));
    let eos = match v.get("eos") {
        Some(Value::Num(n)) => Some(*n as i32),
        Some(Value::Null) | None => None,
        Some(_) => return Err("\"eos\" must be a token id".into()),
    };
    let deadline_ms = match get_num(&v, &["deadline_ms"]) {
        Some(n) if n >= 0.0 => n as u64,
        Some(_) => return Err("\"deadline_ms\" must be non-negative".into()),
        None => ctx.cfg.default_deadline_ms,
    };
    let client = match v.get("client_id") {
        Some(Value::Str(s)) if !s.is_empty() => s.clone(),
        _ => peer.to_string(),
    };
    Ok(CompletionParams { prompt, max_new, stream, eos, deadline_ms, client })
}

fn get_num(v: &Value, keys: &[&str]) -> Option<f64> {
    keys.iter().find_map(|k| match v.get(k) {
        Some(Value::Num(n)) => Some(*n),
        _ => None,
    })
}

fn handle_completions(req: &http::HttpRequest, writer: &mut TcpStream, ctx: &Ctx, peer: &str) {
    if ctx.draining.load(Ordering::SeqCst) {
        ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_json(writer, 503, &retry_after(ctx), &err_json("server is draining"));
        return;
    }
    let params = match parse_completion(&req.body, ctx, peer) {
        Ok(p) => p,
        Err(e) => {
            ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(writer, 400, &[], &err_json(&e));
            return;
        }
    };
    if ctx.cfg.fault.admit_delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(ctx.cfg.fault.admit_delay_ms));
    }

    // admission: cheap shed before the engine thread is involved
    let _permit = match ctx.admission.try_admit(&params.client) {
        Ok(p) => p,
        Err(e) => {
            ctx.metrics.shed_429.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(writer, 429, &retry_after(ctx), &err_json(&e.to_string()));
            return;
        }
    };

    let id = ctx.next_id.fetch_add(1, Ordering::Relaxed);
    let deadline = (params.deadline_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(params.deadline_ms));
    let (tx, rx) = channel::<StreamEvent>();
    let job = Job {
        req: Request { id, prompt: params.prompt, max_new: params.max_new, eos: params.eos },
        deadline,
        tx,
    };
    if ctx.job_tx.send(job).is_err() {
        ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_json(writer, 503, &retry_after(ctx), &err_json("engine stopped"));
        return;
    }

    // first event decides the status line (409-free: Rejected vs tokens)
    let first = match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(ev) => ev,
        Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => {
            ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(writer, 503, &retry_after(ctx), &err_json("engine stalled"));
            return;
        }
    };
    if let StreamEvent::Rejected(e) = first {
        let (status, extra) = match e {
            // the scheduler's own cap is the backstop behind admission; a
            // race that slips past the ceiling still sheds, never queues
            SubmitError::QueueFull { .. } => {
                ctx.metrics.shed_429.fetch_add(1, Ordering::Relaxed);
                (429, retry_after(ctx))
            }
            SubmitError::EmptyPrompt | SubmitError::ZeroMaxNew => {
                ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                (400, Vec::new())
            }
        };
        let _ = http::write_json(writer, status, &extra, &err_json(&e.to_string()));
        return;
    }

    if params.stream {
        stream_response(writer, ctx, first, &rx);
    } else {
        buffered_response(writer, ctx, first, &rx);
    }
}

/// Buffered (non-streaming) mode: collect everything, one JSON response.
/// [`FinishReason::Deadline`] maps to 504 with the partial text attached.
fn buffered_response(
    writer: &mut TcpStream,
    ctx: &Ctx,
    first: StreamEvent,
    rx: &Receiver<StreamEvent>,
) {
    let mut ev = first;
    loop {
        match ev {
            StreamEvent::Done(c) => {
                let status = match c.finish {
                    FinishReason::Deadline => {
                        ctx.metrics.deadline_504.fetch_add(1, Ordering::Relaxed);
                        504
                    }
                    _ => {
                        ctx.metrics.completed_2xx.fetch_add(1, Ordering::Relaxed);
                        200
                    }
                };
                let _ = http::write_json(writer, status, &[], &completion_json(ctx, &c));
                return;
            }
            StreamEvent::Token(_) => {} // accumulated inside the Completion
            StreamEvent::Rejected(_) => unreachable!("terminal event handled by caller"),
        }
        ev = match rx.recv() {
            Ok(ev) => ev,
            Err(_) => {
                let _ = http::write_json(writer, 503, &[], &err_json("engine stopped"));
                return;
            }
        };
    }
}

/// Streaming mode: SSE events over chunked transfer, one `data:` line per
/// token as the scheduler ticks, terminated by a finish event + `[DONE]`.
/// A write failure = client disconnect: dropping `rx` makes the engine's
/// next send fail, which cancels the sequence and frees its slot.
fn stream_response(
    writer: &mut TcpStream,
    ctx: &Ctx,
    first: StreamEvent,
    rx: &Receiver<StreamEvent>,
) {
    let Ok(mut out) = http::ChunkedWriter::start(&mut *writer, 200, "text/event-stream") else {
        ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
        return;
    };
    let mut index = 0usize;
    let mut ev = first;
    loop {
        match ev {
            StreamEvent::Token(tok) => {
                let body = jsonx::emit(&jsonx::obj(vec![
                    ("index", jsonx::num(index as f64)),
                    ("token", jsonx::num(tok as f64)),
                    ("text", jsonx::s(&token_text(tok))),
                ]));
                if out.chunk(format!("data: {body}\n\n").as_bytes()).is_err() {
                    // client gone mid-stream; rx drops here → slot freed
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                index += 1;
                if ctx.cfg.fault.drop_after_tokens > 0 && index >= ctx.cfg.fault.drop_after_tokens
                {
                    // injected mid-stream failure: vanish without a
                    // terminator, exactly like a cut connection
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
            StreamEvent::Done(c) => {
                ctx.metrics.completed_2xx.fetch_add(1, Ordering::Relaxed);
                if c.finish == FinishReason::Deadline {
                    ctx.metrics.deadline_504.fetch_add(1, Ordering::Relaxed);
                }
                let fin = format!("data: {}\n\n", completion_json(ctx, &c));
                let ok = out.chunk(fin.as_bytes()).is_ok()
                    && out.chunk(b"data: [DONE]\n\n").is_ok();
                if ok {
                    let _ = out.finish();
                } else {
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
            StreamEvent::Rejected(_) => unreachable!("terminal event handled by caller"),
        }
        ev = match rx.recv() {
            Ok(ev) => ev,
            Err(_) => return, // engine stopped; stream ends without [DONE]
        };
    }
}

// -------------------------------------------------------------- payloads

fn err_json(msg: &str) -> String {
    jsonx::emit(&jsonx::obj(vec![("error", jsonx::s(msg))]))
}

fn retry_after(ctx: &Ctx) -> Vec<(&'static str, String)> {
    vec![("Retry-After", ctx.cfg.retry_after_s.to_string())]
}

fn token_text(tok: i32) -> String {
    String::from_utf8_lossy(&[tok as u8]).into_owned()
}

fn completion_json(ctx: &Ctx, c: &Completion) -> String {
    let bytes: Vec<u8> = c.tokens.iter().map(|&t| t as u8).collect();
    jsonx::emit(&jsonx::obj(vec![
        ("id", jsonx::num(c.id as f64)),
        ("object", jsonx::s("text_completion")),
        ("model", jsonx::s(&ctx.model_name)),
        ("text", jsonx::s(&String::from_utf8_lossy(&bytes))),
        (
            "tokens",
            Value::Arr(c.tokens.iter().map(|&t| jsonx::num(t as f64)).collect()),
        ),
        ("finish_reason", jsonx::s(c.finish.label())),
        ("prompt_len", jsonx::num(c.prompt_len as f64)),
        ("steps", jsonx::num(c.steps as f64)),
    ]))
}

fn stats_json(ctx: &Ctx) -> String {
    let g = &ctx.gauges;
    let m = &ctx.metrics;
    let a = &ctx.admission;
    let n = |v: u64| jsonx::num(v as f64);
    jsonx::emit(&jsonx::obj(vec![
        ("draining", Value::Bool(ctx.draining.load(Ordering::SeqCst))),
        ("max_batch", jsonx::num(ctx.max_batch as f64)),
        ("queue_cap", jsonx::num(ctx.cfg.queue_cap as f64)),
        ("in_flight", jsonx::num(a.in_flight() as f64)),
        ("pending", jsonx::num(g.pending.load(Ordering::Relaxed) as f64)),
        ("peak_pending", jsonx::num(g.peak_pending.load(Ordering::Relaxed) as f64)),
        ("active", jsonx::num(g.active.load(Ordering::Relaxed) as f64)),
        (
            "admission",
            jsonx::obj(vec![
                ("admitted", n(a.admitted.load(Ordering::Relaxed))),
                ("shed_capacity", n(a.shed_capacity.load(Ordering::Relaxed))),
                ("shed_client", n(a.shed_client.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "sched",
            jsonx::obj(vec![
                ("tokens_generated", n(g.tokens_generated.load(Ordering::Relaxed))),
                ("completed", n(g.completed.load(Ordering::Relaxed))),
                ("shed_requests", n(g.shed_requests.load(Ordering::Relaxed))),
                ("deadline_evictions", n(g.deadline_evictions.load(Ordering::Relaxed))),
                ("cancelled", n(g.cancelled.load(Ordering::Relaxed))),
                ("starved_ticks", n(g.starved_ticks.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "http",
            jsonx::obj(vec![
                ("connections", n(m.connections.load(Ordering::Relaxed))),
                ("requests", n(m.requests.load(Ordering::Relaxed))),
                ("completed_2xx", n(m.completed_2xx.load(Ordering::Relaxed))),
                ("bad_requests", n(m.bad_requests.load(Ordering::Relaxed))),
                ("shed_429", n(m.shed_429.load(Ordering::Relaxed))),
                ("unavailable_503", n(m.unavailable_503.load(Ordering::Relaxed))),
                ("deadline_504", n(m.deadline_504.load(Ordering::Relaxed))),
                ("disconnects", n(m.disconnects.load(Ordering::Relaxed))),
            ]),
        ),
    ]))
}
