//! Overload-safe HTTP serving front-end over the packed engine.
//!
//! The ROADMAP's north star needs a network front door that *degrades
//! gracefully*: the merged-transform serving path only stays "no overhead"
//! (FlatQuant/OstQuant's assumption) if the layer above the kernels —
//! admission, queueing, timeouts — never becomes the failure mode. Layout
//! (Actyx-style node-API / event-stream separation):
//!
//! * [`http`]        — HTTP/1.1 parsing + fixed/chunked response writers;
//! * [`admission`]   — bounded in-flight ceiling + per-client caps (429);
//! * [`engine_loop`] — the one thread that owns the model and streams
//!   tokens per scheduler tick;
//! * [`fault`]       — deterministic fault injection (delays, drops);
//! * this module     — listener, worker pool, routing, drain.
//!
//! ## Endpoints
//!
//! | endpoint               | behaviour                                       |
//! |------------------------|-------------------------------------------------|
//! | `POST /v1/completions` | OpenAI-style; `"stream": true` = SSE over chunked transfer |
//! | `GET /healthz`         | liveness + drain state                          |
//! | `GET /v1/stats`        | admission/scheduler/HTTP counters (JSON)        |
//! | `GET /v1/health/numeric` | per-layer drift verdicts + divergence summary (404 when telemetry off) |
//! | `POST /admin/shutdown` | begin graceful drain (what SIGTERM also does)   |
//!
//! ## Degradation ladder
//!
//! 1. queue has room → admit; tokens stream as the scheduler ticks;
//! 2. in-flight ceiling (`max_batch + queue_cap`), per-client cap, or —
//!    when `kv_pages` bounds the pool — the KV page budget hit → **429** +
//!    `Retry-After` (the scheduler's pending deque is bounded by
//!    construction — overload sheds, it never queues unboundedly, and a
//!    request is only admitted once its worst-case KV pages are reserved);
//! 3. per-request deadline passes (queued or mid-decode) → evicted with
//!    [`FinishReason::Deadline`](crate::engine::FinishReason) → **504**
//!    (non-stream) or a `"finish_reason":"deadline"` terminator (stream);
//! 4. client disconnects mid-stream → the send fails → the sequence is
//!    cancelled and its KV slot freed the same tick;
//! 5. SIGTERM / `/admin/shutdown` → stop accepting (503), finish every
//!    admitted request, then exit.
//!
//! Greedy streamed tokens are bit-identical to offline
//! [`Engine::generate`] output — same scheduler, same tick, same kernels
//! (`rust/tests/server.rs` asserts this over a real socket).

pub mod admission;
pub mod engine_loop;
pub mod fault;
pub mod http;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{
    worst_case_pages_for, Completion, Engine, FinishReason, KvConfig, Request, Sampler,
    SubmitError, DEFAULT_PAGE_TOKENS,
};
use crate::jsonx::{self, Value};
use crate::telemetry::{self, Histogram, Recorder, Span, Telemetry};

use admission::{Admission, AdmitError};
use engine_loop::{EngineGauges, Job, StreamEvent};
use fault::FaultConfig;

/// Serving knobs; `Default` is a sane single-box profile.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests).
    pub addr: String,
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Pending-queue bound beyond the batch slots; the in-flight ceiling
    /// is `max_batch + queue_cap`. Must be > 0 — serving without a bound
    /// is exactly the failure mode this front-end exists to prevent.
    pub queue_cap: usize,
    /// Per-client concurrent-request cap (keyed by `client_id` or peer
    /// IP); 0 = unlimited.
    pub client_cap: usize,
    /// `max_tokens` when the request omits it.
    pub default_max_new: usize,
    /// Deadline applied when the request omits `deadline_ms`; 0 = none.
    pub default_deadline_ms: u64,
    /// `Retry-After` seconds on 429/503.
    pub retry_after_s: u64,
    /// Bound the KV page pool to this many pages and admit a request only
    /// when its worst-case page count is reservable (429 otherwise); `0`
    /// leaves the pool growing on demand and the page gate off.
    pub kv_pages: usize,
    /// Tokens per KV page; `0` keeps the engine's default.
    pub kv_page_tokens: usize,
    /// Sampler for every request (per-request sampling params are not
    /// honoured: one scheduler session shares one sampler + RNG).
    pub sampler: Sampler,
    /// RNG seed for the serving session (relevant to top-k only).
    pub seed: u64,
    pub fault: FaultConfig,
    /// Collect latency histograms, request spans, and the event journal
    /// (`/metrics`, `/v1/trace/<id>`, `/v1/journal`). Off = the zero-cost
    /// path: counters still work, but no clock reads besides deadlines.
    pub telemetry: bool,
    /// Append one [`crate::report::log_line`] per finished completion
    /// request (stamped with the monotonic sequence counter). Off by
    /// default so embedded servers (tests) do not write `results/`.
    pub log_requests: bool,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 4,
            queue_cap: 32,
            client_cap: 8,
            default_max_new: 64,
            default_deadline_ms: 0,
            retry_after_s: 1,
            kv_pages: 0,
            kv_page_tokens: 0,
            sampler: Sampler::Greedy,
            seed: 0,
            fault: FaultConfig::default(),
            telemetry: true,
            log_requests: false,
        }
    }
}

/// HTTP-layer counters (the engine/scheduler ones live in
/// [`EngineGauges`], admission's in [`Admission`]).
#[derive(Default)]
pub struct Metrics {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub completed_2xx: AtomicU64,
    pub bad_requests: AtomicU64,
    pub shed_429: AtomicU64,
    pub unavailable_503: AtomicU64,
    pub deadline_504: AtomicU64,
    pub disconnects: AtomicU64,
}

struct Ctx {
    cfg: ServerConfig,
    model_name: String,
    max_batch: usize,
    /// Inputs to the per-request worst-case page pricing (the attention
    /// window, the pool's page size, and the scheduler's prefill chunk) —
    /// the same numbers the engine-side reservation uses.
    kv_window: usize,
    kv_page_tokens: usize,
    prefill_chunk: usize,
    admission: Arc<Admission>,
    job_tx: Sender<Job>,
    next_id: AtomicU64,
    draining: Arc<AtomicBool>,
    metrics: Arc<Metrics>,
    gauges: Arc<EngineGauges>,
    /// Live when `cfg.telemetry`; shares the registry with the engine
    /// thread's scheduler session.
    recorder: Recorder,
    /// GEMM dispatch the model's linears resolved at load (e.g.
    /// "avx2/w4g128") — captured before the engine moves to its thread.
    kernel_name: &'static str,
    /// Selection snapshot (variant, override source, fallback flag) for
    /// `/v1/stats` and the `aq_kernel_info` metric.
    kernel: crate::engine::kernels::KernelInfo,
}

/// A running server; dropping the handle does NOT stop it — call
/// [`shutdown`](ServerHandle::shutdown) then [`join`](ServerHandle::join).
pub struct Server;

pub struct ServerHandle {
    pub addr: SocketAddr,
    draining: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub gauges: Arc<EngineGauges>,
    /// The metric registry behind `/metrics`; `None` when telemetry is
    /// disabled in the config.
    pub telemetry: Option<Arc<Telemetry>>,
}

impl ServerHandle {
    /// Begin graceful drain: stop admitting, finish in-flight, exit.
    pub fn shutdown(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Wait for every thread (accept, workers, engine) to exit.
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Set by the SIGTERM/SIGINT handler; every server observes it.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Route SIGTERM/SIGINT into graceful drain (unix; no-op elsewhere).
/// Kept out of `Server::spawn` so tests can run servers un-hooked.
pub fn install_signal_handlers() {
    #[cfg(unix)]
    {
        extern "C" fn on_signal(_sig: i32) {
            SIGNAL_DRAIN.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        unsafe {
            signal(15, on_signal as usize); // SIGTERM
            signal(2, on_signal as usize); // SIGINT
        }
    }
}

impl Server {
    /// Bind, spawn the accept loop + worker pool + engine thread, and
    /// return immediately. `engine.sched.queue_cap` is overwritten from
    /// `cfg.queue_cap` so the scheduler's own bound always matches the
    /// admission ceiling.
    pub fn spawn(mut engine: Engine, cfg: ServerConfig) -> Result<ServerHandle> {
        anyhow::ensure!(
            cfg.queue_cap > 0,
            "serving without a queue cap is unbounded by definition"
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr()?;

        engine.sched.queue_cap = cfg.queue_cap;
        let max_batch = engine.max_batch;
        // bound the KV pool when asked: the admission gate prices every
        // request with the same worst-case formula the scheduler reserves
        // by, over the same (window, page size, prefill chunk) inputs
        let kv_window = engine.model.cfg.seq.max(1);
        let kv_page_tokens = match cfg.kv_page_tokens {
            0 => DEFAULT_PAGE_TOKENS,
            t => t,
        };
        if cfg.kv_pages > 0 || cfg.kv_page_tokens > 0 {
            engine.configure_kv(KvConfig {
                page_tokens: kv_page_tokens,
                max_pages: cfg.kv_pages,
                ..KvConfig::default()
            });
        }
        let fault = cfg.fault.with_env();
        let draining = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Metrics::default());
        let gauges = Arc::new(EngineGauges::default());
        let tele = cfg.telemetry.then(Telemetry::new);
        let recorder = match &tele {
            Some(t) => Recorder::from_telemetry(Arc::clone(t)),
            None => Recorder::default(),
        };
        if cfg.telemetry {
            // sampled kernel timing is process-global; a telemetry-off
            // server leaves whatever another enabled alone
            telemetry::kernel::enable(true);
        }
        let admission = Admission::with_pages(
            max_batch + cfg.queue_cap,
            cfg.client_cap,
            cfg.kv_pages,
            recorder.clone(),
        );
        let (job_tx, job_rx) = channel::<Job>();
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let ctx = Arc::new(Ctx {
            model_name: engine.model.cfg.name.clone(),
            kernel_name: engine.model.kernel_name(),
            kernel: crate::engine::kernels::info(),
            max_batch,
            kv_window,
            kv_page_tokens,
            prefill_chunk: engine.sched.prefill_chunk,
            admission,
            job_tx,
            next_id: AtomicU64::new(1),
            draining: Arc::clone(&draining),
            metrics: Arc::clone(&metrics),
            gauges: Arc::clone(&gauges),
            recorder: recorder.clone(),
            cfg: ServerConfig { fault, ..cfg },
        });

        let mut threads = Vec::new();

        // engine thread: owns the model; exits once every worker is gone
        // (job channel closed) and all admitted sequences finished
        {
            let gauges = Arc::clone(&gauges);
            let sampler = ctx.cfg.sampler;
            let seed = ctx.cfg.seed;
            let recorder = recorder.clone();
            threads.push(std::thread::spawn(move || {
                engine_loop::run(&mut engine, job_rx, sampler, seed, fault, &gauges, &recorder);
            }));
        }

        // worker pool: drain accepted connections
        for _ in 0..ctx.cfg.workers.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let ctx = Arc::clone(&ctx);
            threads.push(std::thread::spawn(move || loop {
                let conn = {
                    let rx = conn_rx.lock().expect("conn queue lock poisoned");
                    rx.recv()
                };
                match conn {
                    Ok(stream) => handle_connection(stream, &ctx),
                    Err(_) => break, // accept loop gone and queue drained
                }
            }));
        }

        // accept loop: nonblocking so drain is noticed promptly
        {
            let draining = Arc::clone(&draining);
            threads.push(std::thread::spawn(move || {
                loop {
                    if draining.load(Ordering::SeqCst) || SIGNAL_DRAIN.load(Ordering::SeqCst) {
                        draining.store(true, Ordering::SeqCst);
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                }
                // dropping conn_tx ends the workers once the backlog drains
            }));
        }

        Ok(ServerHandle { addr, draining, threads, metrics, gauges, telemetry: tele })
    }
}

// ------------------------------------------------------------ connection

fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    ctx.metrics.connections.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let peer = stream.peer_addr().map(|a| a.ip().to_string()).unwrap_or_default();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;

    let req = match http::HttpRequest::read_from(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            let _ = http::write_json(&mut writer, 400, &[], &err_json(&e));
            return;
        }
    };
    ctx.metrics.requests.fetch_add(1, Ordering::Relaxed);

    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => handle_completions(&req, &mut writer, ctx, &peer),
        ("GET", "/healthz") => {
            let draining = ctx.draining.load(Ordering::SeqCst);
            let body = jsonx::emit(&jsonx::obj(vec![
                ("status", jsonx::s(if draining { "draining" } else { "ok" })),
                ("pending", jsonx::num(ctx.gauges.pending.load(Ordering::Relaxed) as f64)),
                ("active", jsonx::num(ctx.gauges.active.load(Ordering::Relaxed) as f64)),
            ]));
            let _ = http::write_json(&mut writer, 200, &[], &body);
        }
        ("GET", "/v1/stats") => {
            let _ = http::write_json(&mut writer, 200, &[], &stats_json(ctx));
        }
        ("GET", "/metrics") => {
            let _ = http::write_response(
                &mut writer,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                &[],
                metrics_text(ctx).as_bytes(),
            );
        }
        ("GET", p) if p.starts_with("/v1/trace/") => {
            let span = http::path_param(p, "/v1/trace/")
                .and_then(|key| ctx.recorder.telemetry().and_then(|t| t.traces.lookup(key)));
            match span {
                Some(s) => {
                    let _ = http::write_json(&mut writer, 200, &[], &trace_json(&s));
                }
                None => {
                    let _ = http::write_json(&mut writer, 404, &[], &err_json("no such trace"));
                }
            }
        }
        ("GET", "/v1/journal") => match ctx.recorder.telemetry() {
            Some(t) => {
                let _ = http::write_json(&mut writer, 200, &[], &journal_json(t));
            }
            None => {
                let _ = http::write_json(&mut writer, 404, &[], &err_json("telemetry disabled"));
            }
        },
        ("GET", "/v1/health/numeric") => match ctx.recorder.telemetry() {
            Some(t) => {
                let _ = http::write_json(&mut writer, 200, &[], &numeric_health_json(t));
            }
            None => {
                let _ = http::write_json(&mut writer, 404, &[], &err_json("telemetry disabled"));
            }
        },
        ("POST", "/admin/shutdown") => {
            ctx.draining.store(true, Ordering::SeqCst);
            let _ = http::write_json(&mut writer, 202, &[], "{\"status\":\"draining\"}");
        }
        ("POST" | "GET", _) => {
            let _ = http::write_json(&mut writer, 404, &[], &err_json("no such endpoint"));
        }
        _ => {
            let _ = http::write_json(&mut writer, 405, &[], &err_json("method not allowed"));
        }
    }
}

// ------------------------------------------------------------ completion

/// Parsed `/v1/completions` payload.
struct CompletionParams {
    prompt: Vec<i32>,
    max_new: usize,
    stream: bool,
    eos: Option<i32>,
    deadline_ms: u64,
    client: String,
}

fn parse_completion(body: &[u8], ctx: &Ctx, peer: &str) -> Result<CompletionParams, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let v = jsonx::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let prompt = match v.get("prompt") {
        Some(Value::Str(s)) => s.bytes().map(|b| b as i32).collect(),
        Some(_) => return Err("\"prompt\" must be a string".into()),
        None => return Err("missing \"prompt\"".into()),
    };
    let max_new = match get_num(&v, &["max_tokens", "max_new"]) {
        Some(n) if n >= 0.0 => n as usize,
        Some(_) => return Err("\"max_tokens\" must be non-negative".into()),
        None => ctx.cfg.default_max_new,
    };
    let stream = matches!(v.get("stream"), Some(Value::Bool(true)));
    let eos = match v.get("eos") {
        Some(Value::Num(n)) => Some(*n as i32),
        Some(Value::Null) | None => None,
        Some(_) => return Err("\"eos\" must be a token id".into()),
    };
    let deadline_ms = match get_num(&v, &["deadline_ms"]) {
        Some(n) if n >= 0.0 => n as u64,
        Some(_) => return Err("\"deadline_ms\" must be non-negative".into()),
        None => ctx.cfg.default_deadline_ms,
    };
    let client = match v.get("client_id") {
        Some(Value::Str(s)) if !s.is_empty() => s.clone(),
        _ => peer.to_string(),
    };
    Ok(CompletionParams { prompt, max_new, stream, eos, deadline_ms, client })
}

fn get_num(v: &Value, keys: &[&str]) -> Option<f64> {
    keys.iter().find_map(|k| match v.get(k) {
        Some(Value::Num(n)) => Some(*n),
        _ => None,
    })
}

fn handle_completions(req: &http::HttpRequest, writer: &mut TcpStream, ctx: &Ctx, peer: &str) {
    // allocate the engine id + externally visible trace id up front, so
    // every response on this path — 2xx, 429, 504, even 400 — carries an
    // `X-Request-Id` echo and is correlatable in client logs
    let id = ctx.next_id.fetch_add(1, Ordering::Relaxed);
    let trace_id = match req.header("x-request-id") {
        Some(h) if !h.trim().is_empty() => h.trim().chars().take(120).collect::<String>(),
        _ => format!("req-{id:08x}"),
    };
    let rid = ("X-Request-Id", trace_id.clone());
    let with_retry = |ctx: &Ctx| {
        let mut h = retry_after(ctx);
        h.push(("X-Request-Id", trace_id.clone()));
        h
    };

    if ctx.draining.load(Ordering::SeqCst) {
        ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_json(
            writer,
            503,
            &with_retry(ctx),
            &err_json_id("server is draining", &trace_id),
        );
        return;
    }
    let params = match parse_completion(&req.body, ctx, peer) {
        Ok(p) => p,
        Err(e) => {
            ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
            ctx.recorder.event("bad_request", || format!("{trace_id}: {e}"));
            let _ = http::write_json(
                writer,
                400,
                std::slice::from_ref(&rid),
                &err_json_id(&e, &trace_id),
            );
            return;
        }
    };
    if ctx.cfg.fault.admit_delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(ctx.cfg.fault.admit_delay_ms));
    }

    // span identity: the engine side fills in timings keyed by the same id
    ctx.recorder.span(id, |s| {
        s.trace_id = trace_id.clone();
        s.client = params.client.clone();
    });

    // admission: cheap shed before the engine thread is involved; the
    // page price is this request's worst-case KV residency (ignored by
    // the gate unless the pool is bounded)
    let pages = worst_case_pages_for(
        ctx.kv_window,
        ctx.kv_page_tokens,
        params.prompt.len(),
        params.max_new,
        ctx.prefill_chunk,
    );
    let _permit = match ctx.admission.try_admit(&params.client, pages) {
        Ok(p) => p,
        Err(e) => {
            ctx.metrics.shed_429.fetch_add(1, Ordering::Relaxed);
            ctx.recorder.span(id, |s| s.outcome = "shed".to_string());
            let _ = http::write_json(
                writer,
                429,
                &with_retry(ctx),
                &err_json_id(&e.to_string(), &trace_id),
            );
            return;
        }
    };

    let deadline = (params.deadline_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(params.deadline_ms));
    let (tx, rx) = channel::<StreamEvent>();
    let job = Job {
        req: Request { id, prompt: params.prompt, max_new: params.max_new, eos: params.eos },
        deadline,
        tx,
    };
    if ctx.job_tx.send(job).is_err() {
        ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
        ctx.recorder.span(id, |s| s.outcome = "engine_stopped".to_string());
        let _ = http::write_json(
            writer,
            503,
            &with_retry(ctx),
            &err_json_id("engine stopped", &trace_id),
        );
        return;
    }

    // first event decides the status line (409-free: Rejected vs tokens)
    let first = match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(ev) => ev,
        Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => {
            ctx.metrics.unavailable_503.fetch_add(1, Ordering::Relaxed);
            ctx.recorder.span(id, |s| s.outcome = "engine_stalled".to_string());
            let _ = http::write_json(
                writer,
                503,
                &with_retry(ctx),
                &err_json_id("engine stalled", &trace_id),
            );
            return;
        }
    };
    if let StreamEvent::Rejected(e) = first {
        let (status, extra) = match e {
            // the scheduler's own cap is the backstop behind admission; a
            // race that slips past the ceiling still sheds, never queues
            SubmitError::QueueFull { .. } => {
                ctx.metrics.shed_429.fetch_add(1, Ordering::Relaxed);
                (429, with_retry(ctx))
            }
            SubmitError::EmptyPrompt | SubmitError::ZeroMaxNew => {
                ctx.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                (400, vec![("X-Request-Id", trace_id.clone())])
            }
        };
        ctx.recorder.span(id, |s| s.outcome = "rejected".to_string());
        let _ = http::write_json(writer, status, &extra, &err_json_id(&e.to_string(), &trace_id));
        return;
    }

    let outcome = if params.stream {
        stream_response(writer, ctx, first, &rx, &trace_id)
    } else {
        buffered_response(writer, ctx, first, &rx, &trace_id)
    };
    if ctx.cfg.log_requests {
        let _ = crate::report::log_line(&format!(
            "completion {trace_id} client={} max_new={} outcome={outcome}",
            params.client, params.max_new,
        ));
    }
}

/// Buffered (non-streaming) mode: collect everything, one JSON response.
/// [`FinishReason::Deadline`] maps to 504 with the partial text attached.
/// Returns the outcome label for the request log.
fn buffered_response(
    writer: &mut TcpStream,
    ctx: &Ctx,
    first: StreamEvent,
    rx: &Receiver<StreamEvent>,
    trace_id: &str,
) -> &'static str {
    let rid = [("X-Request-Id", trace_id.to_string())];
    let mut ev = first;
    loop {
        match ev {
            StreamEvent::Done(c) => {
                let (status, outcome) = match c.finish {
                    FinishReason::Deadline => {
                        ctx.metrics.deadline_504.fetch_add(1, Ordering::Relaxed);
                        (504, "deadline")
                    }
                    _ => {
                        ctx.metrics.completed_2xx.fetch_add(1, Ordering::Relaxed);
                        (200, c.finish.label())
                    }
                };
                let _ = http::write_json(writer, status, &rid, &completion_json(ctx, &c, trace_id));
                return outcome;
            }
            StreamEvent::Token(_) => {} // accumulated inside the Completion
            StreamEvent::Rejected(_) => unreachable!("terminal event handled by caller"),
        }
        ev = match rx.recv() {
            Ok(ev) => ev,
            Err(_) => {
                let _ =
                    http::write_json(writer, 503, &rid, &err_json_id("engine stopped", trace_id));
                return "engine_stopped";
            }
        };
    }
}

/// Streaming mode: SSE events over chunked transfer, one `data:` line per
/// token as the scheduler ticks, terminated by a finish event + `[DONE]`.
/// A write failure = client disconnect: dropping `rx` makes the engine's
/// next send fail, which cancels the sequence and frees its slot.
fn stream_response(
    writer: &mut TcpStream,
    ctx: &Ctx,
    first: StreamEvent,
    rx: &Receiver<StreamEvent>,
    trace_id: &str,
) -> &'static str {
    let rid = [("X-Request-Id", trace_id.to_string())];
    let Ok(mut out) =
        http::ChunkedWriter::start_with(&mut *writer, 200, "text/event-stream", &rid)
    else {
        ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
        return "disconnect";
    };
    let mut index = 0usize;
    let mut ev = first;
    loop {
        match ev {
            StreamEvent::Token(tok) => {
                let body = jsonx::emit(&jsonx::obj(vec![
                    ("index", jsonx::num(index as f64)),
                    ("token", jsonx::num(tok as f64)),
                    ("text", jsonx::s(&token_text(tok))),
                ]));
                if out.chunk(format!("data: {body}\n\n").as_bytes()).is_err() {
                    // client gone mid-stream; rx drops here → slot freed
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    return "disconnect";
                }
                index += 1;
                if ctx.cfg.fault.drop_after_tokens > 0 && index >= ctx.cfg.fault.drop_after_tokens
                {
                    // injected mid-stream failure: vanish without a
                    // terminator, exactly like a cut connection
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                    return "disconnect";
                }
            }
            StreamEvent::Done(c) => {
                ctx.metrics.completed_2xx.fetch_add(1, Ordering::Relaxed);
                if c.finish == FinishReason::Deadline {
                    ctx.metrics.deadline_504.fetch_add(1, Ordering::Relaxed);
                }
                let outcome = c.finish.label();
                let fin = format!("data: {}\n\n", completion_json(ctx, &c, trace_id));
                let ok = out.chunk(fin.as_bytes()).is_ok()
                    && out.chunk(b"data: [DONE]\n\n").is_ok();
                if ok {
                    let _ = out.finish();
                } else {
                    ctx.metrics.disconnects.fetch_add(1, Ordering::Relaxed);
                }
                return outcome;
            }
            StreamEvent::Rejected(_) => unreachable!("terminal event handled by caller"),
        }
        ev = match rx.recv() {
            Ok(ev) => ev,
            Err(_) => return "engine_stopped", // stream ends without [DONE]
        };
    }
}

// -------------------------------------------------------------- payloads

fn err_json(msg: &str) -> String {
    jsonx::emit(&jsonx::obj(vec![("error", jsonx::s(msg))]))
}

/// [`err_json`] carrying the request's trace id, so shed/timed-out/refused
/// requests are correlatable in client logs.
fn err_json_id(msg: &str, trace_id: &str) -> String {
    jsonx::emit(&jsonx::obj(vec![
        ("error", jsonx::s(msg)),
        ("request_id", jsonx::s(trace_id)),
    ]))
}

fn retry_after(ctx: &Ctx) -> Vec<(&'static str, String)> {
    vec![("Retry-After", ctx.cfg.retry_after_s.to_string())]
}

fn token_text(tok: i32) -> String {
    String::from_utf8_lossy(&[tok as u8]).into_owned()
}

fn completion_json(ctx: &Ctx, c: &Completion, trace_id: &str) -> String {
    let bytes: Vec<u8> = c.tokens.iter().map(|&t| t as u8).collect();
    jsonx::emit(&jsonx::obj(vec![
        ("id", jsonx::num(c.id as f64)),
        ("request_id", jsonx::s(trace_id)),
        ("object", jsonx::s("text_completion")),
        ("model", jsonx::s(&ctx.model_name)),
        ("text", jsonx::s(&String::from_utf8_lossy(&bytes))),
        (
            "tokens",
            Value::Arr(c.tokens.iter().map(|&t| jsonx::num(t as f64)).collect()),
        ),
        ("finish_reason", jsonx::s(c.finish.label())),
        ("prompt_len", jsonx::num(c.prompt_len as f64)),
        ("steps", jsonx::num(c.steps as f64)),
    ]))
}

fn stats_json(ctx: &Ctx) -> String {
    let g = &ctx.gauges;
    let m = &ctx.metrics;
    let a = &ctx.admission;
    let k = &ctx.gauges.kv;
    let n = |v: u64| jsonx::num(v as f64);
    let ki = &ctx.kernel;
    let mut fields = vec![
        ("draining", Value::Bool(ctx.draining.load(Ordering::SeqCst))),
        (
            "kernel",
            jsonx::obj(vec![
                ("name", jsonx::s(ctx.kernel_name)),
                ("variant", jsonx::s(ki.selected.name())),
                ("source", jsonx::s(ki.source)),
                ("requested", jsonx::s(ki.requested.as_deref().unwrap_or(""))),
                ("fell_back", Value::Bool(ki.fell_back)),
                (
                    "available",
                    Value::Arr(ki.available.iter().map(|v| jsonx::s(v.name())).collect()),
                ),
            ]),
        ),
        ("max_batch", jsonx::num(ctx.max_batch as f64)),
        ("queue_cap", jsonx::num(ctx.cfg.queue_cap as f64)),
        ("in_flight", jsonx::num(a.in_flight() as f64)),
        ("pending", jsonx::num(g.pending.load(Ordering::Relaxed) as f64)),
        ("peak_pending", jsonx::num(g.peak_pending.load(Ordering::Relaxed) as f64)),
        ("active", jsonx::num(g.active.load(Ordering::Relaxed) as f64)),
        (
            "admission",
            jsonx::obj(vec![
                ("admitted", n(a.admitted.load(Ordering::Relaxed))),
                ("shed_capacity", n(a.shed_capacity.load(Ordering::Relaxed))),
                ("shed_client", n(a.shed_client.load(Ordering::Relaxed))),
                ("shed_pages", n(a.shed_pages.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "kv",
            jsonx::obj(vec![
                ("kv_page_tokens", jsonx::num(ctx.kv_page_tokens as f64)),
                ("kv_page_budget", jsonx::num(a.page_budget() as f64)),
                ("kv_pages_reserved", jsonx::num(a.pages_reserved() as f64)),
                ("kv_pages_total", n(k.pages_total.load(Ordering::Relaxed))),
                ("kv_pages_free", n(k.pages_free.load(Ordering::Relaxed))),
                ("kv_pages_resident", n(k.pages_resident.load(Ordering::Relaxed))),
                ("kv_pages_cached", n(k.pages_cached.load(Ordering::Relaxed))),
                ("kv_pages_shared", n(k.pages_shared.load(Ordering::Relaxed))),
                ("kv_shared_bytes", n(k.shared_bytes.load(Ordering::Relaxed))),
                ("kv_resident_bytes", n(k.resident_bytes.load(Ordering::Relaxed))),
                ("kv_cow_faults", n(k.cow_faults.load(Ordering::Relaxed))),
                ("kv_prefix_hits", n(k.prefix_hits.load(Ordering::Relaxed))),
                ("kv_shared_tokens", n(k.shared_tokens.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "sched",
            jsonx::obj(vec![
                ("tokens_generated", n(g.tokens_generated.load(Ordering::Relaxed))),
                ("completed", n(g.completed.load(Ordering::Relaxed))),
                ("shed_requests", n(g.shed_requests.load(Ordering::Relaxed))),
                ("deadline_evictions", n(g.deadline_evictions.load(Ordering::Relaxed))),
                ("cancelled", n(g.cancelled.load(Ordering::Relaxed))),
                ("starved_ticks", n(g.starved_ticks.load(Ordering::Relaxed))),
            ]),
        ),
        (
            "http",
            jsonx::obj(vec![
                ("connections", n(m.connections.load(Ordering::Relaxed))),
                ("requests", n(m.requests.load(Ordering::Relaxed))),
                ("completed_2xx", n(m.completed_2xx.load(Ordering::Relaxed))),
                ("bad_requests", n(m.bad_requests.load(Ordering::Relaxed))),
                ("shed_429", n(m.shed_429.load(Ordering::Relaxed))),
                ("unavailable_503", n(m.unavailable_503.load(Ordering::Relaxed))),
                ("deadline_504", n(m.deadline_504.load(Ordering::Relaxed))),
                ("disconnects", n(m.disconnects.load(Ordering::Relaxed))),
            ]),
        ),
    ];
    if let Some(t) = ctx.recorder.telemetry() {
        fields.push((
            "latency",
            jsonx::obj(vec![
                ("ttft", hist_summary(&t.ttft)),
                ("inter_token", hist_summary(&t.inter_token)),
                ("queue_wait", hist_summary(&t.queue_wait)),
                ("request", hist_summary(&t.request)),
                ("tick", hist_summary(&t.tick)),
            ]),
        ));
        fields.push((
            "engine",
            jsonx::obj(vec![
                ("ticks", n(t.ticks.load(Ordering::Relaxed))),
                ("prefill_rows", n(t.prefill_rows.load(Ordering::Relaxed))),
                ("decode_rows", n(t.decode_rows.load(Ordering::Relaxed))),
            ]),
        ));
    }
    jsonx::emit(&jsonx::obj(fields))
}

/// Count + percentile summary of one histogram for the JSON surfaces.
fn hist_summary(h: &Histogram) -> Value {
    jsonx::obj(vec![
        ("count", jsonx::num(h.count() as f64)),
        ("p50_ms", jsonx::num(h.percentile_ms(0.50))),
        ("p90_ms", jsonx::num(h.percentile_ms(0.90))),
        ("p99_ms", jsonx::num(h.percentile_ms(0.99))),
        ("mean_ms", jsonx::num(h.mean_ms())),
    ])
}

/// One span rendered for `GET /v1/trace/<id>`. Negative duration fields
/// mean "not reached" and are omitted rather than rendered as -1.
fn trace_json(s: &Span) -> String {
    let mut fields = vec![
        ("id", jsonx::num(s.id as f64)),
        ("request_id", jsonx::s(&s.trace_id)),
        ("client", jsonx::s(&s.client)),
        ("prompt_len", jsonx::num(s.prompt_len as f64)),
        ("max_new", jsonx::num(s.max_new as f64)),
        ("tokens", jsonx::num(s.tokens as f64)),
        (
            "outcome",
            jsonx::s(if s.outcome.is_empty() { "in_flight" } else { &s.outcome }),
        ),
        ("gap_count", jsonx::num(s.gap_count as f64)),
        ("mean_gap_ms", jsonx::num(s.mean_gap_ms())),
        ("max_gap_ms", jsonx::num(s.gap_max_ms)),
    ];
    if s.queue_wait_ms >= 0.0 {
        fields.push(("queue_wait_ms", jsonx::num(s.queue_wait_ms)));
    }
    if s.ttft_ms >= 0.0 {
        fields.push(("ttft_ms", jsonx::num(s.ttft_ms)));
    }
    if s.total_ms >= 0.0 {
        fields.push(("total_ms", jsonx::num(s.total_ms)));
    }
    jsonx::emit(&jsonx::obj(fields))
}

/// The event journal for `GET /v1/journal` (bounded ring; `total` counts
/// everything ever pushed, so `total - events.len()` is how many wrapped).
fn journal_json(t: &Telemetry) -> String {
    let events: Vec<Value> = t
        .journal
        .snapshot()
        .iter()
        .map(|e| {
            jsonx::obj(vec![
                ("seq", jsonx::num(e.seq as f64)),
                ("at_ms", jsonx::num(e.at_ms as f64)),
                ("kind", jsonx::s(e.kind)),
                ("detail", jsonx::s(&e.detail)),
            ])
        })
        .collect();
    jsonx::emit(&jsonx::obj(vec![
        ("total", jsonx::num(t.journal.total() as f64)),
        ("capacity", jsonx::num(t.journal.capacity() as f64)),
        ("events", Value::Arr(events)),
    ]))
}

/// `GET /v1/health/numeric` — per-layer numeric-health verdicts: the baked
/// calibration envelope, the live sampled activation stats, the drift
/// verdict (`ok` / `no_data` / `drifting`), and the cross-bit-width
/// divergence summary. `status` is the worst per-layer verdict.
fn numeric_health_json(t: &Telemetry) -> String {
    let snap = t.numeric.snapshot();
    let drift_layers = snap.layers.iter().filter(|l| l.drifting).count();
    let status = if drift_layers > 0 {
        "drifting"
    } else if !t.numeric.installed() || snap.layers.is_empty() {
        "no_data"
    } else {
        "ok"
    };
    let layers: Vec<Value> = snap
        .layers
        .iter()
        .map(|l| {
            jsonx::obj(vec![
                ("layer", jsonx::num(l.layer as f64)),
                ("verdict", jsonx::s(l.verdict())),
                (
                    "baked",
                    jsonx::obj(vec![
                        ("absmax", jsonx::num(l.env.absmax as f64)),
                        ("mean", jsonx::num(l.env.mean as f64)),
                        ("var", jsonx::num(l.env.var as f64)),
                        ("count", jsonx::num(l.env.count as f64)),
                        ("weight_mse", jsonx::num(l.env.weight_mse as f64)),
                        ("weight_max_abs", jsonx::num(l.env.weight_max_abs as f64)),
                    ]),
                ),
                (
                    "live",
                    jsonx::obj(vec![
                        ("rows", jsonx::num(l.rows as f64)),
                        ("count", jsonx::num(l.count as f64)),
                        ("mean", jsonx::num(l.mean)),
                        ("var", jsonx::num(l.var)),
                        ("absmax", jsonx::num(l.absmax as f64)),
                        ("outliers", jsonx::num(l.outliers as f64)),
                        ("outlier_frac", jsonx::num(l.outlier_frac)),
                    ]),
                ),
            ])
        })
        .collect();
    let d = &snap.div;
    jsonx::emit(&jsonx::obj(vec![
        ("status", jsonx::s(status)),
        ("drift_layers", jsonx::num(drift_layers as f64)),
        ("layers", Value::Arr(layers)),
        (
            "divergence",
            jsonx::obj(vec![
                ("serve_bits", jsonx::num(d.serve_bits as f64)),
                ("draft_bits", jsonx::num(d.draft_bits as f64)),
                ("probes", jsonx::num(d.probes as f64)),
                ("agree", jsonx::num(d.agree as f64)),
                ("agree_pct", jsonx::num(d.agree_pct())),
                ("max_logit_delta", jsonx::num(d.max_logit_delta as f64)),
                ("mean_logit_delta", jsonx::num(d.mean_logit_delta())),
                (
                    "group_max_delta",
                    Value::Arr(d.group_delta.iter().map(|&g| jsonx::num(g as f64)).collect()),
                ),
            ]),
        ),
    ]))
}

/// `GET /metrics` — Prometheus text exposition 0.0.4. Counters and gauges
/// are always present (they are plain atomics); the histogram families
/// appear only when telemetry is on, and the sampled kernel families
/// whenever the process-global kernel timer has observations.
fn metrics_text(ctx: &Ctx) -> String {
    use telemetry::{
        prom_counter, prom_gauge, prom_gauge_f64, prom_histogram, prom_histogram_header,
        prom_histogram_series,
    };
    let m = &ctx.metrics;
    let g = &ctx.gauges;
    let a = &ctx.admission;
    let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
    let mut out = String::new();

    // GEMM dispatch info gauge: constant 1, labels carry the selection —
    // the Prometheus idiom for build/runtime facts (cf. node_exporter's
    // *_info families). `fell_back` flags an explicit request the CPU or
    // build could not honor.
    let ki = &ctx.kernel;
    out.push_str("# HELP aq_kernel_info active packed-GEMM kernel dispatch (constant 1; labels carry the selection)\n");
    out.push_str("# TYPE aq_kernel_info gauge\n");
    out.push_str(&format!(
        "aq_kernel_info{{variant=\"{}\",kernel=\"{}\",source=\"{}\",fell_back=\"{}\"}} 1\n",
        ki.selected.name(),
        ctx.kernel_name,
        ki.source,
        ki.fell_back,
    ));

    // HTTP front door
    prom_counter(&mut out, "aq_http_connections_total", "TCP connections accepted", ld(&m.connections));
    prom_counter(&mut out, "aq_http_requests_total", "HTTP requests parsed", ld(&m.requests));
    prom_counter(&mut out, "aq_http_completed_2xx_total", "completions answered 2xx", ld(&m.completed_2xx));
    prom_counter(&mut out, "aq_http_bad_requests_total", "requests answered 400", ld(&m.bad_requests));
    prom_counter(&mut out, "aq_http_shed_429_total", "requests shed with 429", ld(&m.shed_429));
    prom_counter(&mut out, "aq_http_unavailable_503_total", "requests answered 503", ld(&m.unavailable_503));
    prom_counter(&mut out, "aq_http_deadline_504_total", "requests past deadline (504)", ld(&m.deadline_504));
    prom_counter(&mut out, "aq_http_disconnects_total", "client disconnects mid-stream", ld(&m.disconnects));

    // admission
    prom_gauge(&mut out, "aq_in_flight", "admitted requests currently alive", a.in_flight() as u64);
    prom_counter(&mut out, "aq_admitted_total", "requests past admission", ld(&a.admitted));
    prom_counter(&mut out, "aq_shed_capacity_total", "sheds at the in-flight ceiling", ld(&a.shed_capacity));
    prom_counter(&mut out, "aq_shed_client_total", "sheds at a per-client cap", ld(&a.shed_client));
    prom_counter(&mut out, "aq_shed_pages_total", "sheds at the KV page budget", ld(&a.shed_pages));

    // KV page pool (republished from the cache every scheduler tick)
    let k = &g.kv;
    prom_gauge(&mut out, "aq_kv_pool_pages", "KV pool size in pages (allocated when unbounded)", k.pages_total.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_pages_free", "KV pages immediately allocatable", k.pages_free.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_pages_resident", "KV pages referenced by live sequences", k.pages_resident.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_pages_cached", "refcount-0 KV pages kept for prefix reuse", k.pages_cached.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_pages_shared", "KV pages referenced by two or more sequences", k.pages_shared.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_pages_reserved", "worst-case KV pages reserved by admission", a.pages_reserved() as u64);
    prom_gauge(&mut out, "aq_kv_shared_bytes", "KV bytes saved right now by prefix sharing", k.shared_bytes.load(Ordering::Relaxed));
    prom_gauge(&mut out, "aq_kv_resident_bytes", "KV bytes held by live sequences", k.resident_bytes.load(Ordering::Relaxed));
    prom_counter(&mut out, "aq_kv_cow_faults_total", "copy-on-write page copies at divergence points", k.cow_faults.load(Ordering::Relaxed));
    prom_counter(&mut out, "aq_kv_prefix_hits_total", "admissions that attached a shared prompt prefix", k.prefix_hits.load(Ordering::Relaxed));
    prom_counter(&mut out, "aq_kv_shared_tokens_total", "prompt tokens served from shared pages", k.shared_tokens.load(Ordering::Relaxed));

    // engine/scheduler
    prom_gauge(&mut out, "aq_pending", "requests queued for a KV slot", g.pending.load(Ordering::Relaxed) as u64);
    prom_gauge(&mut out, "aq_active", "sequences decoding right now", g.active.load(Ordering::Relaxed) as u64);
    prom_gauge(&mut out, "aq_peak_pending", "high-water mark of the pending queue", g.peak_pending.load(Ordering::Relaxed) as u64);
    prom_counter(&mut out, "aq_tokens_generated_total", "tokens sampled by the scheduler", ld(&g.tokens_generated));
    prom_counter(&mut out, "aq_completed_total", "sequences finished", ld(&g.completed));
    prom_counter(&mut out, "aq_sched_shed_total", "submits refused by the scheduler's own cap", ld(&g.shed_requests));
    prom_counter(&mut out, "aq_deadline_evictions_total", "sequences evicted past deadline", ld(&g.deadline_evictions));
    prom_counter(&mut out, "aq_cancelled_total", "sequences cancelled (disconnects)", ld(&g.cancelled));
    prom_counter(&mut out, "aq_starved_ticks_total", "ticks that ran below full batch with work queued", ld(&g.starved_ticks));

    if let Some(t) = ctx.recorder.telemetry() {
        prom_counter(&mut out, "aq_ticks_total", "scheduler ticks", t.ticks.load(Ordering::Relaxed));
        prom_counter(&mut out, "aq_prefill_rows_total", "prefill rows batched", t.prefill_rows.load(Ordering::Relaxed));
        prom_counter(&mut out, "aq_decode_rows_total", "decode rows batched", t.decode_rows.load(Ordering::Relaxed));
        prom_counter(&mut out, "aq_journal_events_total", "events pushed to the journal", t.journal.total());

        prom_histogram(&mut out, "aq_ttft_seconds", "submit to first generated token", &t.ttft);
        prom_histogram(&mut out, "aq_inter_token_seconds", "gap between consecutive tokens of one sequence", &t.inter_token);
        prom_histogram(&mut out, "aq_queue_wait_seconds", "submit to KV-slot admission", &t.queue_wait);
        prom_histogram(&mut out, "aq_request_seconds", "submit to finish, whole request", &t.request);

        prom_histogram_header(&mut out, "aq_tick_seconds", "one scheduler tick, by batch phase");
        prom_histogram_series(&mut out, "aq_tick_seconds", r#"phase="all""#, &t.tick.snapshot());
        prom_histogram_series(&mut out, "aq_tick_seconds", r#"phase="prefill""#, &t.tick_prefill.snapshot());
        prom_histogram_series(&mut out, "aq_tick_seconds", r#"phase="decode""#, &t.tick_decode.snapshot());
        prom_histogram_series(&mut out, "aq_tick_seconds", r#"phase="mixed""#, &t.tick_mixed.snapshot());

        // numeric health: sampled activation stats vs the baked calibration
        // envelopes, plus the cross-bit-width divergence sampler
        let ns = t.numeric.snapshot();
        let sampled_rows: u64 = ns.layers.iter().map(|l| l.rows).sum();
        let outliers: u64 = ns.layers.iter().map(|l| l.outliers).sum();
        let drift_layers = ns.layers.iter().filter(|l| l.drifting).count();
        prom_counter(&mut out, "aq_numeric_sampled_rows_total", "decode rows sampled for numeric health", sampled_rows);
        prom_counter(&mut out, "aq_numeric_outliers_total", "sampled rows outside their layer's calibration envelope", outliers);
        prom_gauge(&mut out, "aq_numeric_drift_layers", "layers currently in the drifting state", drift_layers as u64);
        if !ns.layers.is_empty() {
            out.push_str("# HELP aq_numeric_layer_drift 1 when the layer's drift detector is armed\n");
            out.push_str("# TYPE aq_numeric_layer_drift gauge\n");
            for l in &ns.layers {
                out.push_str(&format!(
                    "aq_numeric_layer_drift{{layer=\"{}\"}} {}\n",
                    l.layer,
                    u8::from(l.drifting)
                ));
            }
            out.push_str("# HELP aq_numeric_layer_outlier_frac envelope-outlier fraction of the layer's sampled rows\n");
            out.push_str("# TYPE aq_numeric_layer_outlier_frac gauge\n");
            for l in &ns.layers {
                out.push_str(&format!(
                    "aq_numeric_layer_outlier_frac{{layer=\"{}\"}} {}\n",
                    l.layer, l.outlier_frac
                ));
            }
        }
        prom_counter(&mut out, "aq_numeric_probes_total", "cross-bit-width divergence probes run", ns.div.probes);
        prom_counter(&mut out, "aq_numeric_probe_agree_total", "divergence probes whose top-1 token agreed", ns.div.agree);
        prom_gauge_f64(&mut out, "aq_numeric_top1_agree_pct", "top-1 agreement between serving and draft bit-widths (percent)", ns.div.agree_pct());
        prom_gauge_f64(&mut out, "aq_numeric_max_logit_delta", "max |logit delta| between serving and draft bit-widths", ns.div.max_logit_delta as f64);
    }

    // sampled kernel timing is process-global, not per-server
    let ks = telemetry::kernel::stats();
    if ks.head.count() > 0 || ks.gemm.iter().any(|h| h.count() > 0) {
        prom_histogram_header(&mut out, "aq_gemm_seconds", "sampled packed-GEMM kernel time by weight bit-width");
        for (i, label) in telemetry::kernel::BITS_LABELS.iter().enumerate() {
            prom_histogram_series(
                &mut out,
                "aq_gemm_seconds",
                &format!(r#"bits="{label}""#),
                &ks.gemm[i].snapshot(),
            );
        }
        prom_histogram(&mut out, "aq_head_seconds", "sampled vocab-head projection time", &ks.head);
    }
    out
}
