//! Minimal HTTP/1.1 support (substrate: hyper/axum are not vendored
//! offline): request parsing with hard size limits, fixed-length
//! responses, and a chunked-transfer writer for token streams.
//!
//! Robustness over features: every parse failure is an `Err(String)` the
//! connection worker maps to HTTP 400 — never a panic — and oversized
//! headers/bodies are refused before they are buffered, so a hostile
//! client cannot balloon server memory. One request per connection
//! (`Connection: close`): serving completions means most responses are
//! streams that end by closing anyway, and it keeps the worker loop free
//! of keep-alive state.

use std::io::{BufRead, Read, Write};

/// Largest accepted request body (a prompt payload); larger ones are
/// refused while parsing, before allocation.
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted single header line / request line.
pub const MAX_LINE_BYTES: usize = 8 << 10;
/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 64;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header with this name, case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Read one request off the wire. Errors are protocol violations or
    /// limit overruns — the caller answers 400 and closes.
    pub fn read_from<R: BufRead>(r: &mut R) -> Result<HttpRequest, String> {
        let line = read_line(r)?;
        let mut parts = line.split_whitespace();
        let method = parts.next().ok_or("empty request line")?.to_string();
        let path = parts.next().ok_or("missing request path")?.to_string();
        let version = parts.next().ok_or("missing HTTP version")?;
        if !version.starts_with("HTTP/1.") {
            return Err(format!("unsupported version {version:?}"));
        }
        let mut headers = Vec::new();
        loop {
            let line = read_line(r)?;
            if line.is_empty() {
                break;
            }
            if headers.len() >= MAX_HEADERS {
                return Err("too many headers".into());
            }
            let (k, v) = line.split_once(':').ok_or_else(|| format!("bad header {line:?}"))?;
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
        let content_len = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
            .map(|(_, v)| v.parse::<usize>().map_err(|_| format!("bad content-length {v:?}")))
            .transpose()?
            .unwrap_or(0);
        if content_len > MAX_BODY_BYTES {
            return Err(format!("body too large ({content_len} > {MAX_BODY_BYTES})"));
        }
        let mut body = vec![0u8; content_len];
        r.read_exact(&mut body).map_err(|e| format!("short body: {e}"))?;
        Ok(HttpRequest { method, path, headers, body })
    }
}

/// Read a CRLF-terminated line (LF tolerated), bounded by
/// [`MAX_LINE_BYTES`].
fn read_line<R: BufRead>(r: &mut R) -> Result<String, String> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => return Err("connection closed mid-request".into()),
            Ok(_) => {}
            Err(e) => return Err(format!("read error: {e}")),
        }
        if byte[0] == b'\n' {
            break;
        }
        buf.push(byte[0]);
        if buf.len() > MAX_LINE_BYTES {
            return Err("header line too long".into());
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| "non-utf8 header bytes".into())
}

/// Tiny route-parameter extractor: the non-empty suffix of `path` after
/// `prefix` (`path_param("/v1/trace/abc", "/v1/trace/") == Some("abc")`).
pub fn path_param<'a>(path: &'a str, prefix: &str) -> Option<&'a str> {
    path.strip_prefix(prefix).filter(|rest| !rest.is_empty())
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (`Connection: close`).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        status_reason(status),
        body.len(),
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// JSON convenience for error/result bodies.
pub fn write_json<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, String)],
    json: &str,
) -> std::io::Result<()> {
    write_response(w, status, "application/json", extra_headers, json.as_bytes())
}

/// Streaming body via `Transfer-Encoding: chunked`; each call to
/// [`chunk`](ChunkedWriter::chunk) is flushed immediately so clients see
/// tokens as the scheduler ticks, and a write failure surfaces as `Err` —
/// the disconnect signal that frees the decode slot upstream.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn start(w: W, status: u16, content_type: &str) -> std::io::Result<ChunkedWriter<W>> {
        ChunkedWriter::start_with(w, status, content_type, &[])
    }

    /// [`start`](ChunkedWriter::start) with extra response headers (e.g.
    /// the `X-Request-Id` echo on token streams).
    pub fn start_with(
        mut w: W,
        status: u16,
        content_type: &str,
        extra_headers: &[(&str, String)],
    ) -> std::io::Result<ChunkedWriter<W>> {
        write!(
            w,
            "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n",
            status_reason(status),
        )?;
        for (k, v) in extra_headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream cleanly (zero-length chunk).
    pub fn finish(mut self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}";
        let req = HttpRequest::read_from(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"{\"a\":1}");
    }

    #[test]
    fn rejects_malformed() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            b"GET /x SPDY/3\r\n\r\n",
            b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
            b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort",
        ] {
            assert!(HttpRequest::read_from(&mut Cursor::new(raw)).is_err(), "{raw:?}");
        }
    }

    #[test]
    fn rejects_oversized_body_before_reading_it() {
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err = HttpRequest::read_from(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn chunked_wire_format() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out, 200, "text/event-stream").unwrap();
        cw.chunk(b"data: hi\n\n").unwrap();
        cw.chunk(b"").unwrap(); // no-op, must not terminate the stream
        cw.chunk(b"data: [DONE]\n\n").unwrap();
        cw.finish().unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Transfer-Encoding: chunked"));
        assert!(s.contains("a\r\ndata: hi\n\n\r\n"), "{s}");
        assert!(s.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn path_param_extracts_suffix() {
        assert_eq!(path_param("/v1/trace/abc", "/v1/trace/"), Some("abc"));
        assert_eq!(path_param("/v1/trace/req-0a", "/v1/trace/"), Some("req-0a"));
        assert_eq!(path_param("/v1/trace/", "/v1/trace/"), None);
        assert_eq!(path_param("/v1/stats", "/v1/trace/"), None);
    }

    #[test]
    fn chunked_start_with_emits_extra_headers() {
        let mut out = Vec::new();
        let cw = ChunkedWriter::start_with(
            &mut out,
            200,
            "text/event-stream",
            &[("X-Request-Id", "req-7".into())],
        )
        .unwrap();
        drop(cw);
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("X-Request-Id: req-7\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\n"));
    }

    #[test]
    fn fixed_response_has_content_length() {
        let mut out = Vec::new();
        write_json(&mut out, 429, &[("Retry-After", "1".into())], "{\"error\":\"x\"}").unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(s.contains("Content-Length: 13\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
    }
}
