//! The single engine thread: owns the packed model, the KV arena, and one
//! long-lived [`Scheduler`] session; connection workers hand it jobs over
//! a channel and get tokens streamed back per scheduler tick.
//!
//! One thread, by design: the scheduler already multiplexes sequences
//! inside each tick (continuous batching), so serving concurrency comes
//! from batch slots, not from racing threads over the KV cache — and the
//! bit-stability contract (greedy streamed tokens == offline `generate`)
//! holds because this is literally the same `tick` the offline path runs.
//!
//! Robustness duties here:
//! * `submit_at` failures (malformed request, pending deque at its cap)
//!   are *replied*, not panicked — the worker maps them to HTTP 400/429;
//! * a failed token send means the worker is gone (client disconnect):
//!   the sequence is cancelled the same tick, freeing its KV slot;
//! * deadlines are swept between ticks by the scheduler itself
//!   ([`FinishReason::Deadline`]);
//! * on drain the loop stops taking jobs only when the channel closes,
//!   and keeps ticking until every admitted sequence finished.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::engine::kv::KvCache;
use crate::engine::{Completion, Engine, Request, Sampler, Scheduler, SubmitError};
use crate::rngx::Pcg32;
use crate::telemetry::{KvPoolGauges, Recorder};

use super::fault::FaultConfig;

/// What a connection worker receives over its per-request channel.
#[derive(Debug)]
pub enum StreamEvent {
    /// One sampled token (the request is still live).
    Token(i32),
    /// The request finished; terminal event.
    Done(Completion),
    /// The scheduler refused the request; terminal event.
    Rejected(SubmitError),
}

/// One admitted request travelling to the engine thread.
pub struct Job {
    pub req: Request,
    pub deadline: Option<Instant>,
    pub tx: Sender<StreamEvent>,
}

/// Live gauges + counters the stats endpoint reads while the loop runs.
#[derive(Default)]
pub struct EngineGauges {
    pub pending: AtomicUsize,
    pub active: AtomicUsize,
    pub peak_pending: AtomicUsize,
    pub tokens_generated: AtomicU64,
    pub completed: AtomicU64,
    pub shed_requests: AtomicU64,
    pub deadline_evictions: AtomicU64,
    pub cancelled: AtomicU64,
    pub starved_ticks: AtomicU64,
    /// KV page-pool occupancy, republished from the cache every tick.
    pub kv: KvPoolGauges,
}

/// How long the loop blocks for a job when idle before re-checking drain.
const IDLE_POLL: Duration = Duration::from_millis(2);

/// Run until the job channel closes and all admitted work has finished.
pub fn run(
    engine: &mut Engine,
    jobs: Receiver<Job>,
    sampler: Sampler,
    seed: u64,
    fault: FaultConfig,
    gauges: &EngineGauges,
    recorder: &Recorder,
) {
    let sched_cfg = engine.sched;
    let max_batch = engine.max_batch;
    let (model, draft, cache) = engine.parts();
    // baked calibration envelopes ground the numeric-health drift verdicts
    recorder.numeric_install(
        model.envelopes(),
        model.spec.bits,
        draft.map(|d| d.spec.bits),
    );
    let mut sched = Scheduler::with_config(max_batch, sched_cfg);
    sched.recorder = recorder.clone();
    let mut rng = Pcg32::seeded(seed);
    let mut streams: HashMap<u64, Sender<StreamEvent>> = HashMap::new();
    let mut closed = false;

    loop {
        // ---- intake: block briefly when idle, drain the backlog when busy
        if !sched.has_work() && !closed {
            match jobs.recv_timeout(IDLE_POLL) {
                Ok(job) => accept(&mut sched, &mut streams, job),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => closed = true,
            }
        }
        loop {
            match jobs.try_recv() {
                Ok(job) => accept(&mut sched, &mut streams, job),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        if !sched.has_work() {
            publish(&sched, cache, gauges);
            if closed {
                break; // drained: nothing in flight, no more submitters
            }
            continue;
        }

        // ---- one model step (deadline sweep happens inside tick)
        sched.tick_drafted(model, draft, cache, sampler, &mut rng);

        // ---- stream this tick's tokens; a dead receiver = disconnected
        // client, so reclaim the slot instead of decoding to nobody
        let mut dead: Vec<u64> = Vec::new();
        for &(id, tok) in sched.emitted() {
            if let Some(tx) = streams.get(&id) {
                if tx.send(StreamEvent::Token(tok)).is_err() {
                    dead.push(id);
                }
            }
        }
        for c in sched.take_finished() {
            gauges.completed.fetch_add(1, Ordering::Relaxed);
            if let Some(tx) = streams.remove(&c.id) {
                let _ = tx.send(StreamEvent::Done(c));
            }
        }
        for id in dead {
            sched.cancel(id, cache);
            streams.remove(&id);
        }

        if fault.tick_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(fault.tick_delay_ms));
        }
        publish(&sched, cache, gauges);
    }
}

fn accept(sched: &mut Scheduler, streams: &mut HashMap<u64, Sender<StreamEvent>>, job: Job) {
    let id = job.req.id;
    match sched.submit_at(job.req, job.deadline) {
        Ok(()) => {
            streams.insert(id, job.tx);
        }
        Err(e) => {
            let _ = job.tx.send(StreamEvent::Rejected(e));
        }
    }
}

fn publish(sched: &Scheduler, cache: &KvCache, gauges: &EngineGauges) {
    let pending = sched.pending_len();
    gauges.pending.store(pending, Ordering::Relaxed);
    gauges.peak_pending.fetch_max(pending, Ordering::Relaxed);
    gauges.active.store(sched.active_len(), Ordering::Relaxed);
    let s = &sched.stats;
    gauges.tokens_generated.store(s.tokens_generated as u64, Ordering::Relaxed);
    gauges.shed_requests.store(s.shed_requests as u64, Ordering::Relaxed);
    gauges.deadline_evictions.store(s.deadline_evictions as u64, Ordering::Relaxed);
    gauges.cancelled.store(s.cancelled as u64, Ordering::Relaxed);
    gauges.starved_ticks.store(s.starved_ticks as u64, Ordering::Relaxed);
    let ks = cache.stats();
    let total = if ks.max_pages > 0 { ks.max_pages } else { ks.pages_allocated };
    let kv = &gauges.kv;
    kv.pages_total.store(total as u64, Ordering::Relaxed);
    kv.pages_free.store(ks.pages_free as u64, Ordering::Relaxed);
    kv.pages_resident.store(ks.pages_resident as u64, Ordering::Relaxed);
    kv.pages_cached.store(ks.pages_cached as u64, Ordering::Relaxed);
    kv.pages_shared.store(ks.pages_shared as u64, Ordering::Relaxed);
    kv.shared_bytes.store(ks.shared_bytes as u64, Ordering::Relaxed);
    kv.resident_bytes.store(ks.resident_bytes as u64, Ordering::Relaxed);
    kv.cow_faults.store(ks.cow_faults, Ordering::Relaxed);
    kv.prefix_hits.store(ks.prefix_hits, Ordering::Relaxed);
    kv.shared_tokens.store(ks.shared_tokens_total, Ordering::Relaxed);
}
