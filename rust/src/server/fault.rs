//! Deterministic fault injection for the serving path.
//!
//! Overload and mid-stream-failure behaviour is only trustworthy if it is
//! *testable*: these knobs let a test (or an operator drill) slow the
//! engine down until the admission queue actually fills, delay admission
//! so concurrent clients really pile up, and cut streams off mid-flight —
//! all deterministically, with no reliance on racing real hardware.
//!
//! Sourced from explicit config (CLI flags) with environment-variable
//! overrides, so a running binary can be driven into the degraded paths
//! without a rebuild:
//!
//! | env                      | effect                                       |
//! |--------------------------|----------------------------------------------|
//! | `AQ_FAULT_TICK_MS`       | sleep after every scheduler tick (slow model)|
//! | `AQ_FAULT_ADMIT_MS`      | sleep before admission (pile-up window)      |
//! | `AQ_FAULT_DROP_AFTER`    | abort each stream after N tokens (server-side|
//! |                          | connection drop; exercises slot reclamation) |

/// All-zero = disabled (the production default).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultConfig {
    /// Sleep this long after every engine tick — makes the model
    /// arbitrarily slow so queue-growth windows are deterministic.
    pub tick_delay_ms: u64,
    /// Sleep this long in the connection worker before admission.
    pub admit_delay_ms: u64,
    /// Abort a streaming response (drop the socket without a terminator)
    /// after this many tokens; `0` = off.
    pub drop_after_tokens: usize,
}

impl FaultConfig {
    /// Apply `AQ_FAULT_*` environment overrides on top of `self`.
    pub fn with_env(mut self) -> FaultConfig {
        if let Some(v) = env_u64("AQ_FAULT_TICK_MS") {
            self.tick_delay_ms = v;
        }
        if let Some(v) = env_u64("AQ_FAULT_ADMIT_MS") {
            self.admit_delay_ms = v;
        }
        if let Some(v) = env_u64("AQ_FAULT_DROP_AFTER") {
            self.drop_after_tokens = v as usize;
        }
        self
    }

    pub fn active(&self) -> bool {
        *self != FaultConfig::default()
    }
}

fn env_u64(key: &str) -> Option<u64> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_overrides_and_active_flag() {
        assert!(!FaultConfig::default().active());
        let f = FaultConfig { tick_delay_ms: 3, ..Default::default() };
        assert!(f.active());
        // unset env leaves explicit config untouched
        assert_eq!(f.with_env().tick_delay_ms, 3);
    }
}
