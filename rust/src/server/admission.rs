//! Admission control: the front door that keeps overload out of the
//! engine. Three gates, all answered with HTTP 429 + `Retry-After`:
//!
//! * a **global in-flight ceiling** (`max_batch + queue_cap`): beyond it a
//!   request could only sit in the scheduler's pending deque past its cap,
//!   so it is shed here — cheaply, before the engine thread is touched;
//! * a **per-client concurrency cap**: one client opening hundreds of
//!   streams cannot monopolize the slots (backpressure is per-client, not
//!   just global);
//! * a **KV page budget**: each request is priced at its worst-case page
//!   count ([`crate::engine::worst_case_pages_for`] — the same formula the
//!   scheduler reserves by); when the priced total would exceed the pool,
//!   the request is shed instead of parking in the queue behind memory it
//!   may wait on indefinitely.
//!
//! Admission is a [`Permit`] (RAII): dropping it — on completion, client
//! disconnect, or any error path — releases all three counts, so leaks are
//! impossible by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::Recorder;

/// Why admission refused a request (all are 429s upstream).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The box is full: active slots + bounded queue all taken.
    Capacity { in_flight: usize, cap: usize },
    /// This client is at its concurrent-request cap.
    ClientCap { cap: usize },
    /// The KV page pool cannot cover this request's worst case.
    Pages { need: usize, free: usize },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Capacity { in_flight, cap } => {
                write!(f, "server at capacity ({in_flight}/{cap} requests in flight)")
            }
            AdmitError::ClientCap { cap } => {
                write!(f, "client at its concurrency cap ({cap})")
            }
            AdmitError::Pages { need, free } => {
                write!(f, "kv page pool exhausted (request needs {need} pages, {free} free)")
            }
        }
    }
}

pub struct Admission {
    /// `max_batch + queue_cap`; 0 = unbounded (not recommended serving).
    max_in_flight: usize,
    /// Per-client concurrent request cap; 0 = unlimited.
    client_cap: usize,
    /// KV pool size in pages backing the priced reservations; 0 = gate off.
    page_budget: usize,
    in_flight: AtomicUsize,
    pages_reserved: AtomicUsize,
    clients: Mutex<HashMap<String, usize>>,
    // counters for /v1/stats
    pub admitted: AtomicU64,
    pub shed_capacity: AtomicU64,
    pub shed_client: AtomicU64,
    pub shed_pages: AtomicU64,
    /// Journals shed decisions for post-mortems; disabled by default.
    recorder: Recorder,
}

impl Admission {
    pub fn new(max_in_flight: usize, client_cap: usize) -> Arc<Admission> {
        Admission::with_pages(max_in_flight, client_cap, 0, Recorder::default())
    }

    /// [`new`](Admission::new) with a telemetry handle: every shed —
    /// global ceiling, per-client cap, or page budget — lands in the
    /// event journal.
    pub fn with_recorder(
        max_in_flight: usize,
        client_cap: usize,
        recorder: Recorder,
    ) -> Arc<Admission> {
        Admission::with_pages(max_in_flight, client_cap, 0, recorder)
    }

    /// [`with_recorder`](Admission::with_recorder) plus a KV page budget
    /// (`0` disables the page gate — offline-style unbounded pools).
    pub fn with_pages(
        max_in_flight: usize,
        client_cap: usize,
        page_budget: usize,
        recorder: Recorder,
    ) -> Arc<Admission> {
        Arc::new(Admission {
            max_in_flight,
            client_cap,
            page_budget,
            in_flight: AtomicUsize::new(0),
            pages_reserved: AtomicUsize::new(0),
            clients: Mutex::new(HashMap::new()),
            admitted: AtomicU64::new(0),
            shed_capacity: AtomicU64::new(0),
            shed_client: AtomicU64::new(0),
            shed_pages: AtomicU64::new(0),
            recorder,
        })
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Worst-case KV pages currently reserved by held permits.
    pub fn pages_reserved(&self) -> usize {
        self.pages_reserved.load(Ordering::Relaxed)
    }

    /// Page budget the gate enforces (`0` = gate off).
    pub fn page_budget(&self) -> usize {
        self.page_budget
    }

    /// Try to admit one request for `client`, priced at `pages` worst-case
    /// KV pages (`0` = exempt from the page gate); the permit must be held
    /// for the request's whole lifetime (queue wait + decode + streaming).
    pub fn try_admit(
        self: &Arc<Admission>,
        client: &str,
        pages: usize,
    ) -> Result<Permit, AdmitError> {
        // per-client first: a greedy client is told so even when the box
        // also happens to be full
        if self.client_cap > 0 {
            let mut clients = self.clients.lock().expect("admission lock poisoned");
            let n = clients.entry(client.to_string()).or_insert(0);
            if *n >= self.client_cap {
                self.shed_client.fetch_add(1, Ordering::Relaxed);
                let cap = self.client_cap;
                self.recorder
                    .event("shed_client", || format!("client {client} at its cap ({cap})"));
                return Err(AdmitError::ClientCap { cap: self.client_cap });
            }
            *n += 1;
        }
        if self.max_in_flight > 0 {
            // CAS loop so concurrent workers cannot overshoot the ceiling
            let mut cur = self.in_flight.load(Ordering::Relaxed);
            loop {
                if cur >= self.max_in_flight {
                    self.release_client(client);
                    self.shed_capacity.fetch_add(1, Ordering::Relaxed);
                    let cap = self.max_in_flight;
                    self.recorder.event("shed_capacity", || {
                        format!("client {client}: in-flight ceiling ({cur}/{cap})")
                    });
                    return Err(AdmitError::Capacity {
                        in_flight: cur,
                        cap: self.max_in_flight,
                    });
                }
                match self.in_flight.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        } else {
            self.in_flight.fetch_add(1, Ordering::AcqRel);
        }
        let pages = if self.page_budget > 0 { pages } else { 0 };
        if pages > 0 {
            // CAS loop mirrors the in-flight ceiling: workers racing here
            // cannot over-commit the pool
            let mut cur = self.pages_reserved.load(Ordering::Relaxed);
            loop {
                if cur + pages > self.page_budget {
                    self.in_flight.fetch_sub(1, Ordering::AcqRel);
                    self.release_client(client);
                    self.shed_pages.fetch_add(1, Ordering::Relaxed);
                    let free = self.page_budget - cur;
                    self.recorder.event("shed_pages", || {
                        format!("client {client}: kv page pool exhausted (need {pages}, {free} free)")
                    });
                    return Err(AdmitError::Pages { need: pages, free });
                }
                match self.pages_reserved.compare_exchange_weak(
                    cur,
                    cur + pages,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(Permit { adm: Arc::clone(self), client: client.to_string(), pages })
    }

    fn release_client(&self, client: &str) {
        if self.client_cap == 0 {
            return;
        }
        let mut clients = self.clients.lock().expect("admission lock poisoned");
        if let Some(n) = clients.get_mut(client) {
            *n -= 1;
            if *n == 0 {
                clients.remove(client);
            }
        }
    }
}

/// A live admission; dropping it releases the global slot, the per-client
/// slot, and the request's KV page reservation.
pub struct Permit {
    adm: Arc<Admission>,
    client: String,
    pages: usize,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.adm.in_flight.fetch_sub(1, Ordering::AcqRel);
        self.adm.release_client(&self.client);
        if self.pages > 0 {
            self.adm.pages_reserved.fetch_sub(self.pages, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_ceiling_sheds_and_releases() {
        let adm = Admission::new(2, 0);
        let p1 = adm.try_admit("a", 0).unwrap();
        let _p2 = adm.try_admit("b", 0).unwrap();
        let err = adm.try_admit("c", 0).unwrap_err();
        assert!(matches!(err, AdmitError::Capacity { cap: 2, .. }));
        assert_eq!(adm.shed_capacity.load(Ordering::Relaxed), 1);
        drop(p1);
        assert!(adm.try_admit("c", 0).is_ok());
    }

    #[test]
    fn per_client_cap_is_isolated() {
        let adm = Admission::new(0, 1);
        let _p = adm.try_admit("alice", 0).unwrap();
        assert!(matches!(
            adm.try_admit("alice", 0).unwrap_err(),
            AdmitError::ClientCap { cap: 1 }
        ));
        // a different client is unaffected by alice's backlog
        assert!(adm.try_admit("bob", 0).is_ok());
        assert_eq!(adm.shed_client.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn client_count_survives_capacity_rejection() {
        // a capacity shed must roll back the per-client increment
        let adm = Admission::new(1, 5);
        let _p = adm.try_admit("a", 0).unwrap();
        let _ = adm.try_admit("b", 0).unwrap_err();
        drop(_p);
        for _ in 0..5 {
            // b's failed attempt must not have consumed a client slot
            let p = adm.try_admit("b", 0).unwrap();
            drop(p);
        }
    }

    #[test]
    fn page_budget_sheds_and_releases() {
        let adm = Admission::with_pages(0, 0, 10, Recorder::default());
        let p1 = adm.try_admit("a", 6).unwrap();
        assert_eq!(adm.pages_reserved(), 6);
        // 6 + 5 > 10: shed, and the in-flight/client increments roll back
        let err = adm.try_admit("b", 5).unwrap_err();
        assert_eq!(err, AdmitError::Pages { need: 5, free: 4 });
        assert_eq!(adm.shed_pages.load(Ordering::Relaxed), 1);
        assert_eq!(adm.in_flight(), 1);
        // page-exempt requests still pass while the pool is tight
        let p2 = adm.try_admit("b", 0).unwrap();
        drop(p1);
        assert_eq!(adm.pages_reserved(), 0);
        let p3 = adm.try_admit("b", 10).unwrap();
        drop(p2);
        drop(p3);
        assert_eq!(adm.pages_reserved(), 0);
        assert_eq!(adm.in_flight(), 0);
    }

    #[test]
    fn sheds_are_journaled() {
        let rec = Recorder::new_enabled();
        let adm = Admission::with_pages(2, 1, 4, rec.clone());
        let _p = adm.try_admit("a", 2).unwrap();
        let _ = adm.try_admit("a", 1).unwrap_err(); // per-client cap
        let p2 = adm.try_admit("b", 1).unwrap();
        let _ = adm.try_admit("c", 1).unwrap_err(); // global ceiling
        drop(p2);
        let _ = adm.try_admit("c", 3).unwrap_err(); // page budget (2 + 3 > 4)
        let t = rec.telemetry().unwrap();
        let kinds: Vec<&str> = t.journal.snapshot().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec!["shed_client", "shed_capacity", "shed_pages"]);
    }

    #[test]
    fn concurrent_admission_never_overshoots() {
        let adm = Admission::with_pages(8, 0, 16, Recorder::default());
        let mut handles = Vec::new();
        for t in 0..4 {
            let adm = Arc::clone(&adm);
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                for i in 0..64 {
                    if let Ok(p) = adm.try_admit(&format!("c{t}"), 2) {
                        got += 1;
                        assert!(adm.in_flight() <= 8, "ceiling overshoot");
                        assert!(adm.pages_reserved() <= 16, "page budget overshoot");
                        if i % 3 == 0 {
                            drop(p);
                        } else {
                            std::mem::forget(p); // hold a few permanently
                        }
                    }
                    if got >= 2 {
                        break;
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(adm.in_flight() <= 8);
        assert!(adm.pages_reserved() <= 16);
    }
}
