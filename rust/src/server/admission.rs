//! Admission control: the front door that keeps overload out of the
//! engine. Two gates, both answered with HTTP 429 + `Retry-After`:
//!
//! * a **global in-flight ceiling** (`max_batch + queue_cap`): beyond it a
//!   request could only sit in the scheduler's pending deque past its cap,
//!   so it is shed here — cheaply, before the engine thread is touched;
//! * a **per-client concurrency cap**: one client opening hundreds of
//!   streams cannot monopolize the slots (backpressure is per-client, not
//!   just global).
//!
//! Admission is a [`Permit`] (RAII): dropping it — on completion, client
//! disconnect, or any error path — releases both counts, so leaks are
//! impossible by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::Recorder;

/// Why admission refused a request (both are 429s upstream).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The box is full: active slots + bounded queue all taken.
    Capacity { in_flight: usize, cap: usize },
    /// This client is at its concurrent-request cap.
    ClientCap { cap: usize },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Capacity { in_flight, cap } => {
                write!(f, "server at capacity ({in_flight}/{cap} requests in flight)")
            }
            AdmitError::ClientCap { cap } => {
                write!(f, "client at its concurrency cap ({cap})")
            }
        }
    }
}

pub struct Admission {
    /// `max_batch + queue_cap`; 0 = unbounded (not recommended serving).
    max_in_flight: usize,
    /// Per-client concurrent request cap; 0 = unlimited.
    client_cap: usize,
    in_flight: AtomicUsize,
    clients: Mutex<HashMap<String, usize>>,
    // counters for /v1/stats
    pub admitted: AtomicU64,
    pub shed_capacity: AtomicU64,
    pub shed_client: AtomicU64,
    /// Journals shed decisions for post-mortems; disabled by default.
    recorder: Recorder,
}

impl Admission {
    pub fn new(max_in_flight: usize, client_cap: usize) -> Arc<Admission> {
        Admission::with_recorder(max_in_flight, client_cap, Recorder::default())
    }

    /// [`new`](Admission::new) with a telemetry handle: every shed —
    /// global ceiling or per-client cap — lands in the event journal.
    pub fn with_recorder(
        max_in_flight: usize,
        client_cap: usize,
        recorder: Recorder,
    ) -> Arc<Admission> {
        Arc::new(Admission {
            max_in_flight,
            client_cap,
            in_flight: AtomicUsize::new(0),
            clients: Mutex::new(HashMap::new()),
            admitted: AtomicU64::new(0),
            shed_capacity: AtomicU64::new(0),
            shed_client: AtomicU64::new(0),
            recorder,
        })
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Try to admit one request for `client`; the permit must be held for
    /// the request's whole lifetime (queue wait + decode + streaming).
    pub fn try_admit(self: &Arc<Admission>, client: &str) -> Result<Permit, AdmitError> {
        // per-client first: a greedy client is told so even when the box
        // also happens to be full
        if self.client_cap > 0 {
            let mut clients = self.clients.lock().expect("admission lock poisoned");
            let n = clients.entry(client.to_string()).or_insert(0);
            if *n >= self.client_cap {
                self.shed_client.fetch_add(1, Ordering::Relaxed);
                let cap = self.client_cap;
                self.recorder
                    .event("shed_client", || format!("client {client} at its cap ({cap})"));
                return Err(AdmitError::ClientCap { cap: self.client_cap });
            }
            *n += 1;
        }
        if self.max_in_flight > 0 {
            // CAS loop so concurrent workers cannot overshoot the ceiling
            let mut cur = self.in_flight.load(Ordering::Relaxed);
            loop {
                if cur >= self.max_in_flight {
                    self.release_client(client);
                    self.shed_capacity.fetch_add(1, Ordering::Relaxed);
                    let cap = self.max_in_flight;
                    self.recorder.event("shed_capacity", || {
                        format!("client {client}: in-flight ceiling ({cur}/{cap})")
                    });
                    return Err(AdmitError::Capacity {
                        in_flight: cur,
                        cap: self.max_in_flight,
                    });
                }
                match self.in_flight.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        } else {
            self.in_flight.fetch_add(1, Ordering::AcqRel);
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(Permit { adm: Arc::clone(self), client: client.to_string() })
    }

    fn release_client(&self, client: &str) {
        if self.client_cap == 0 {
            return;
        }
        let mut clients = self.clients.lock().expect("admission lock poisoned");
        if let Some(n) = clients.get_mut(client) {
            *n -= 1;
            if *n == 0 {
                clients.remove(client);
            }
        }
    }
}

/// A live admission; dropping it releases the global and per-client slots.
pub struct Permit {
    adm: Arc<Admission>,
    client: String,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.adm.in_flight.fetch_sub(1, Ordering::AcqRel);
        self.adm.release_client(&self.client);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_ceiling_sheds_and_releases() {
        let adm = Admission::new(2, 0);
        let p1 = adm.try_admit("a").unwrap();
        let _p2 = adm.try_admit("b").unwrap();
        let err = adm.try_admit("c").unwrap_err();
        assert!(matches!(err, AdmitError::Capacity { cap: 2, .. }));
        assert_eq!(adm.shed_capacity.load(Ordering::Relaxed), 1);
        drop(p1);
        assert!(adm.try_admit("c").is_ok());
    }

    #[test]
    fn per_client_cap_is_isolated() {
        let adm = Admission::new(0, 1);
        let _p = adm.try_admit("alice").unwrap();
        assert!(matches!(
            adm.try_admit("alice").unwrap_err(),
            AdmitError::ClientCap { cap: 1 }
        ));
        // a different client is unaffected by alice's backlog
        assert!(adm.try_admit("bob").is_ok());
        assert_eq!(adm.shed_client.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn client_count_survives_capacity_rejection() {
        // a capacity shed must roll back the per-client increment
        let adm = Admission::new(1, 5);
        let _p = adm.try_admit("a").unwrap();
        let _ = adm.try_admit("b").unwrap_err();
        drop(_p);
        for _ in 0..5 {
            // b's failed attempt must not have consumed a client slot
            let p = adm.try_admit("b").unwrap();
            drop(p);
        }
    }

    #[test]
    fn sheds_are_journaled() {
        let rec = Recorder::new_enabled();
        let adm = Admission::with_recorder(1, 1, rec.clone());
        let _p = adm.try_admit("a").unwrap();
        let _ = adm.try_admit("a").unwrap_err(); // per-client cap
        let _ = adm.try_admit("b").unwrap_err(); // global ceiling
        let t = rec.telemetry().unwrap();
        let kinds: Vec<&str> = t.journal.snapshot().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec!["shed_client", "shed_capacity"]);
    }

    #[test]
    fn concurrent_admission_never_overshoots() {
        let adm = Admission::new(8, 0);
        let mut handles = Vec::new();
        for t in 0..4 {
            let adm = Arc::clone(&adm);
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                for i in 0..64 {
                    if let Ok(p) = adm.try_admit(&format!("c{t}")) {
                        got += 1;
                        assert!(adm.in_flight() <= 8, "ceiling overshoot");
                        if i % 3 == 0 {
                            drop(p);
                        } else {
                            std::mem::forget(p); // hold a few permanently
                        }
                    }
                    if got >= 2 {
                        break;
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(adm.in_flight() <= 8);
    }
}
