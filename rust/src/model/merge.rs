//! Equivalence-transform merging (paper §3.3 "Inference Efficiency").
//!
//! After calibration, every affine transform folds into adjacent parameters
//! so the deployed model carries **no extra ops**:
//!
//! * weight-only (`w?a16`): each site's weight becomes
//!   `W_eval = A⁻¹ · QDQ(A·W)` (the affine matrix and its inverse are merged
//!   with the dequantized weight); the per-head out-proj transform folds its
//!   inverse into the value projection columns instead.
//! * weight-activation (`w4a4`): the diagonal transforms and shifts at the
//!   LayerNorm sites fold into the norm's gain/bias
//!   (`γ' = γ/a`, `β' = (β−δ)/a`) and the weight/bias
//!   (`W' = QDQ(a⊙W)`, `b' = b + δ·W_eff`), so the standard `block_a4`
//!   serving graph evaluates the quantized model unchanged.
//!
//! Precision is a parameter (paper Table 4): the inverse can be computed in
//! f32, f64, or f64-then-truncated ("float-double").

use crate::linalg;
use crate::model::Layout;
use crate::quant::{quant_dequant, QuantSpec};
use crate::tensor::Tensor;

/// Numerical scheme for the affine inverse + merge matmuls (Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MergePrecision {
    /// Everything in f32 ("float").
    F32,
    /// Inverse and merge matmuls in f64, truncate at the end ("double").
    F64,
    /// Inverse in f64, merge matmuls in f32 ("float-double").
    F32InvF64,
}

/// Invert a (n,n) matrix under the requested precision. Panics if singular
/// — callers guarantee SDD via the Gradual Mask (Levy-Desplanques).
pub fn inverse_prec(a: &Tensor, prec: MergePrecision) -> Tensor {
    let (n, n2) = a.dims2();
    assert_eq!(n, n2);
    match prec {
        MergePrecision::F32 => {
            let inv = linalg::inverse::<f32>(&a.data, n).expect("affine matrix singular (f32)");
            Tensor::new(vec![n, n], inv)
        }
        _ => {
            let a64: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
            let inv = linalg::inverse::<f64>(&a64, n).expect("affine matrix singular (f64)");
            Tensor::new(vec![n, n], inv.iter().map(|&v| v as f32).collect())
        }
    }
}

/// `A @ W` with precision-controlled accumulation.
pub fn mm_prec(a: &Tensor, w: &Tensor, prec: MergePrecision) -> Tensor {
    match prec {
        MergePrecision::F64 => {
            let (m, k) = a.dims2();
            let (k2, n) = w.dims2();
            assert_eq!(k, k2);
            let mut out = Tensor::zeros(&[m, n]);
            for i in 0..m {
                for t in 0..k {
                    let av = a.data[i * k + t] as f64;
                    if av != 0.0 {
                        for j in 0..n {
                            let cur = out.data[i * n + j] as f64;
                            out.data[i * n + j] =
                                (cur + av * w.data[t * n + j] as f64) as f32;
                        }
                    }
                }
            }
            out
        }
        _ => a.matmul(w),
    }
}

/// The per-block learnables produced by calibration, in merge-ready form.
/// Diagonal-only modes store `A` as full matrices with zero off-diagonals.
pub struct BlockTransforms {
    /// (d, d) affine at LN1→qkv (weight-only) — or None in a4 mode.
    pub a_qkv: Option<Tensor>,
    /// (d, d) affine at LN2→fc1 (weight-only) — or None in a4 mode.
    pub a_fc1: Option<Tensor>,
    /// (h, hd, hd) per-head affine at v→out (both modes).
    pub a_out: Option<Tensor>,
    /// Diagonal transform + shift at LN1 (a4 mode).
    pub diag_qkv: Option<(Vec<f32>, Vec<f32>)>,
    /// Diagonal transform + shift at LN2 (a4 mode).
    pub diag_fc1: Option<(Vec<f32>, Vec<f32>)>,
    /// LWC clipping logits keyed `lwc_{g,b}_{wname}` (flat (n_groups, out)).
    pub lwc: std::collections::HashMap<String, Vec<f32>>,
}

impl BlockTransforms {
    pub fn identity() -> Self {
        BlockTransforms {
            a_qkv: None,
            a_fc1: None,
            a_out: None,
            diag_qkv: None,
            diag_fc1: None,
            lwc: std::collections::HashMap::new(),
        }
    }

    fn lwc_for(&self, name: &str) -> Option<(&[f32], &[f32])> {
        match (self.lwc.get(&format!("lwc_g_{name}")), self.lwc.get(&format!("lwc_b_{name}"))) {
            (Some(g), Some(b)) => Some((&g[..], &b[..])),
            _ => None,
        }
    }
}

/// Quantize-dequantize one weight with its (optional) LWC logits.
fn qdq(t: &BlockTransforms, name: &str, w: &Tensor, spec: QuantSpec) -> Tensor {
    quant_dequant(w, spec, t.lwc_for(name))
}

/// Merge a weight-only (`w?a16`) block in place: replaces every quantized
/// weight in `wb` (flat block vector) by its merged eval form.
pub fn merge_block_weight_only(
    bl: &Layout,
    wb: &mut [f32],
    t: &BlockTransforms,
    spec: QuantSpec,
    n_heads: usize,
    prec: MergePrecision,
) {
    let opt = bl.has("w1");
    // --- qkv site: W_eval = A⁻¹ QDQ(A W) --------------------------------
    let qkv_names: &[&str] = &["wq", "wk", "wv"];
    if let Some(a) = &t.a_qkv {
        let ainv = inverse_prec(a, prec);
        for name in qkv_names {
            let w = bl.tensor(wb, name);
            let wq = qdq(t, name, &mm_prec(a, &w, prec), spec);
            bl.set(wb, name, &mm_prec(&ainv, &wq, prec));
        }
    } else {
        for name in qkv_names {
            let w = bl.tensor(wb, name);
            bl.set(wb, name, &qdq(t, name, &w, spec));
        }
    }
    // --- out site: per-head A_out; inverse folds into W_v columns -------
    merge_out_site(bl, wb, t, spec, n_heads, prec, None);
    // --- fc1 site ---------------------------------------------------------
    let fc1_names: &[&str] = if opt { &["w1"] } else { &["wg", "wu"] };
    if let Some(a) = &t.a_fc1 {
        let ainv = inverse_prec(a, prec);
        for name in fc1_names {
            let w = bl.tensor(wb, name);
            let wq = qdq(t, name, &mm_prec(a, &w, prec), spec);
            bl.set(wb, name, &mm_prec(&ainv, &wq, prec));
        }
    } else {
        for name in fc1_names {
            let w = bl.tensor(wb, name);
            bl.set(wb, name, &qdq(t, name, &w, spec));
        }
    }
    // --- fc2: plain quantization (no affine — paper §4.1) ----------------
    let fc2 = if opt { "w2" } else { "wd" };
    let w = bl.tensor(wb, fc2);
    bl.set(wb, fc2, &qdq(t, fc2, &w, spec));
}

/// Merge a weight-activation (`w4a4`) block in place: folds the diagonal
/// transforms + shifts into the norm parameters and biases, quantizes the
/// scaled weights. The merged block runs under `block_a4`.
pub fn merge_block_a4(
    bl: &Layout,
    wb: &mut [f32],
    t: &BlockTransforms,
    spec: QuantSpec,
    n_heads: usize,
    prec: MergePrecision,
) {
    let opt = bl.has("w1");
    // --- qkv site ---------------------------------------------------------
    let (a1, d1) = t.diag_qkv.clone().unwrap_or_else(|| {
        let d = bl.shape("wq")[0];
        (vec![1.0; d], vec![0.0; d])
    });
    fold_diag_into_norm(bl, wb, if opt { ("ln1_g", Some("ln1_b")) } else { ("rms1_g", None) }, &a1, &d1);
    for (wn, bn) in [("wq", "bq"), ("wk", "bk"), ("wv", "bv")] {
        scale_quant_shift(bl, wb, t, wn, if opt { Some(bn) } else { None }, &a1, &d1, spec);
    }
    // --- out site ---------------------------------------------------------
    merge_out_site(bl, wb, t, spec, n_heads, prec, None);
    // --- fc1 site ---------------------------------------------------------
    let (a2, d2) = t.diag_fc1.clone().unwrap_or_else(|| {
        let d = bl.shape("wq")[0];
        (vec![1.0; d], vec![0.0; d])
    });
    fold_diag_into_norm(bl, wb, if opt { ("ln2_g", Some("ln2_b")) } else { ("rms2_g", None) }, &a2, &d2);
    if opt {
        scale_quant_shift(bl, wb, t, "w1", Some("b1"), &a2, &d2, spec);
        let w = bl.tensor(wb, "w2");
        bl.set(wb, "w2", &qdq(t, "w2", &w, spec));
    } else {
        scale_quant_shift(bl, wb, t, "wg", None, &a2, &d2, spec);
        scale_quant_shift(bl, wb, t, "wu", None, &a2, &d2, spec);
        let w = bl.tensor(wb, "wd");
        bl.set(wb, "wd", &qdq(t, "wd", &w, spec));
    }
}

/// v→out per-head affine site, shared by both modes:
/// `wo ← QDQ(blockdiag(A_out)·wo)`, `W_v ← W_v·A_out⁻¹` per head (and the
/// value bias likewise). `extra_spec` lets Table-4 experiments override.
fn merge_out_site(
    bl: &Layout,
    wb: &mut [f32],
    t: &BlockTransforms,
    spec: QuantSpec,
    n_heads: usize,
    prec: MergePrecision,
    extra_spec: Option<QuantSpec>,
) {
    let spec = extra_spec.unwrap_or(spec);
    let wo = bl.tensor(wb, "wo");
    let (d, dout) = wo.dims2();
    let hd = d / n_heads;
    if let Some(ao) = &t.a_out {
        assert_eq!(ao.shape, vec![n_heads, hd, hd]);
        // wo_t[h] = A_h @ wo[h]  (wo viewed (h, hd, dout))
        let mut wo_t = Tensor::zeros(&[d, dout]);
        for h in 0..n_heads {
            let a_h = Tensor::new(vec![hd, hd], ao.data[h * hd * hd..(h + 1) * hd * hd].to_vec());
            let wo_h = Tensor::new(vec![hd, dout], wo.data[h * hd * dout..(h + 1) * hd * dout].to_vec());
            let prod = mm_prec(&a_h, &wo_h, prec);
            wo_t.data[h * hd * dout..(h + 1) * hd * dout].copy_from_slice(&prod.data);
        }
        bl.set(wb, "wo", &qdq(t, "wo", &wo_t, spec));
        // fold A⁻¹ into the value projection: W_v[:, h] ← W_v[:, h] @ A_h⁻¹
        let wv = bl.tensor(wb, "wv");
        let (din, _) = wv.dims2();
        let mut wv_new = wv.clone();
        for h in 0..n_heads {
            let a_h = Tensor::new(vec![hd, hd], ao.data[h * hd * hd..(h + 1) * hd * hd].to_vec());
            let ainv_h = inverse_prec(&a_h, prec);
            for r in 0..din {
                let row = &wv.data[r * d + h * hd..r * d + (h + 1) * hd];
                for j in 0..hd {
                    let mut s = 0.0f32;
                    for k in 0..hd {
                        s += row[k] * ainv_h.data[k * hd + j];
                    }
                    wv_new.data[r * d + h * hd + j] = s;
                }
            }
        }
        bl.set(wb, "wv", &wv_new);
        if bl.has("bv") {
            let bv = bl.tensor(wb, "bv");
            let mut bv_new = bv.clone();
            for h in 0..n_heads {
                let a_h = Tensor::new(vec![hd, hd], ao.data[h * hd * hd..(h + 1) * hd * hd].to_vec());
                let ainv_h = inverse_prec(&a_h, prec);
                for j in 0..hd {
                    let mut s = 0.0f32;
                    for k in 0..hd {
                        s += bv.data[h * hd + k] * ainv_h.data[k * hd + j];
                    }
                    bv_new.data[h * hd + j] = s;
                }
            }
            bl.set(wb, "bv", &bv_new);
        }
    } else {
        bl.set(wb, "wo", &qdq(t, "wo", &wo, spec));
    }
}

/// `γ' = γ/a`, `β' = (β−δ)/a` — the zero-overhead LN fold (paper §3.3).
fn fold_diag_into_norm(
    bl: &Layout,
    wb: &mut [f32],
    (gname, bname): (&str, Option<&str>),
    a: &[f32],
    delta: &[f32],
) {
    {
        let g = bl.view_mut(wb, gname);
        for (gv, &av) in g.iter_mut().zip(a) {
            *gv /= av;
        }
    }
    if let Some(bname) = bname {
        let b = bl.view_mut(wb, bname);
        for ((bv, &dv), &av) in b.iter_mut().zip(delta).zip(a) {
            *bv = (*bv - dv) / av;
        }
    } else {
        // no-bias families (RMSNorm) only support zero shifts
        debug_assert!(delta.iter().all(|&d| d == 0.0));
    }
}

/// `W' = QDQ(a⊙W)` rows scaled; `b' = b + δ·W_eff` with `W_eff = W'/a`.
fn scale_quant_shift(
    bl: &Layout,
    wb: &mut [f32],
    t: &BlockTransforms,
    wname: &str,
    bname: Option<&str>,
    a: &[f32],
    delta: &[f32],
    spec: QuantSpec,
) {
    let w = bl.tensor(wb, wname);
    let (din, dout) = w.dims2();
    let mut wt = w.clone();
    for r in 0..din {
        for c in 0..dout {
            wt.data[r * dout + c] *= a[r];
        }
    }
    let wq = qdq(t, wname, &wt, spec);
    if let Some(bname) = bname {
        // b + delta @ (wq / a[:,None])
        let mut badd = vec![0.0f32; dout];
        for r in 0..din {
            let dr = delta[r] / a[r];
            if dr != 0.0 {
                for c in 0..dout {
                    badd[c] += dr * wq.data[r * dout + c];
                }
            }
        }
        let b = bl.view_mut(wb, bname);
        for (bv, ad) in b.iter_mut().zip(&badd) {
            *bv += ad;
        }
    }
    bl.set(wb, wname, &wq);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_layout;
    use crate::rngx::Pcg32;

    fn opt_block_layout(d: usize, ff: usize) -> Layout {
        test_layout(vec![
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("wq", vec![d, d]),
            ("bq", vec![d]),
            ("wk", vec![d, d]),
            ("bk", vec![d]),
            ("wv", vec![d, d]),
            ("bv", vec![d]),
            ("wo", vec![d, d]),
            ("bo", vec![d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
            ("w1", vec![d, ff]),
            ("b1", vec![ff]),
            ("w2", vec![ff, d]),
            ("b2", vec![d]),
        ])
    }

    fn rand_block(bl: &Layout, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        let mut wb = vec![0.0f32; bl.size];
        for (name, shape, _) in bl.entries.clone() {
            let n = crate::tensor::numel(&shape);
            let vals = if name.ends_with("_g") {
                vec![1.0; n]
            } else {
                rng.normal_vec(n, 0.1)
            };
            bl.view_mut(&mut wb, &name).copy_from_slice(&vals);
        }
        wb
    }

    fn sdd_affine(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let mut a = Tensor::randn(&[n, n], 0.01, &mut rng);
        for i in 0..n {
            a.data[i * n + i] = 1.0 + 0.2 * rng.normal().abs() as f32;
        }
        a
    }

    /// With "infinite" bits the merged weight must equal the original:
    /// A⁻¹·Q(A·W) → A⁻¹·A·W = W.
    #[test]
    fn merge_identity_at_high_bits() {
        let d = 16;
        let bl = opt_block_layout(d, 32);
        let wb0 = rand_block(&bl, 1);
        let mut wb = wb0.clone();
        let mut t = BlockTransforms::identity();
        t.a_qkv = Some(sdd_affine(d, 2));
        t.a_fc1 = Some(sdd_affine(d, 3));
        let mut ao = Tensor::zeros(&[4, 4, 4]);
        for h in 0..4 {
            let a = sdd_affine(4, 10 + h as u64);
            ao.data[h * 16..(h + 1) * 16].copy_from_slice(&a.data);
        }
        t.a_out = Some(ao);
        merge_block_weight_only(&bl, &mut wb, &t, QuantSpec::new(8, 0), 4, MergePrecision::F64);
        // 8-bit isn't infinite, but with SDD-near-identity transforms the
        // merged weights must stay close to the originals; and wv/bv carry
        // the folded A_out⁻¹, so compare through the out-site composition:
        // (wv' per-head @ A_h) should reconstruct ~wv.
        let wq0 = bl.tensor(&wb0, "wq");
        let wq1 = bl.tensor(&wb, "wq");
        assert!(wq0.sub(&wq1).max_abs() < 0.05, "{}", wq0.sub(&wq1).max_abs());
    }

    /// Diagonal a4 merge with identity transform and huge bits is a no-op
    /// on everything except quantization noise.
    #[test]
    fn a4_merge_identity_transform() {
        let d = 16;
        let bl = opt_block_layout(d, 32);
        let wb0 = rand_block(&bl, 4);
        let mut wb = wb0.clone();
        let mut t = BlockTransforms::identity();
        t.diag_qkv = Some((vec![1.0; d], vec![0.0; d]));
        t.diag_fc1 = Some((vec![1.0; d], vec![0.0; d]));
        merge_block_a4(&bl, &mut wb, &t, QuantSpec::new(8, 0), 4, MergePrecision::F32);
        let g0 = bl.tensor(&wb0, "ln1_g");
        let g1 = bl.tensor(&wb, "ln1_g");
        assert_eq!(g0, g1);
        let w0 = bl.tensor(&wb0, "wq");
        let w1 = bl.tensor(&wb, "wq");
        assert!(w0.sub(&w1).max_abs() < 0.02);
    }

    /// The LN fold is exactly `γ/a`, `(β−δ)/a`.
    #[test]
    fn ln_fold_formula() {
        let d = 4;
        let bl = test_layout(vec![("ln1_g", vec![d]), ("ln1_b", vec![d])]);
        let mut wb = vec![2.0, 2.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0];
        let a = vec![2.0, 4.0, 1.0, 0.5];
        let delta = vec![1.0, 0.0, -1.0, 3.0];
        fold_diag_into_norm(&bl, &mut wb, ("ln1_g", Some("ln1_b")), &a, &delta);
        assert_eq!(&wb[..4], &[1.0, 0.5, 2.0, 4.0]);
        assert_eq!(&wb[4..], &[0.0, 0.25, 2.0, -4.0]);
    }

    /// a4 scale-quant-shift matches the calibration graph formula on a
    /// tiny example computed by hand at high bits.
    #[test]
    fn scale_quant_shift_bias_math() {
        let bl = test_layout(vec![("wq", vec![2, 2]), ("bq", vec![2])]);
        let mut wb = vec![1.0, 2.0, 3.0, 4.0, 0.5, 0.5];
        let t = BlockTransforms::identity();
        let a = vec![2.0, 1.0];
        let delta = vec![1.0, -1.0];
        scale_quant_shift(&bl, &mut wb, &t, "wq", Some("bq"), &a, &delta, QuantSpec::new(8, 0));
        // wt = [[2,4],[3,4]]; W_eff = wt/a = [[1,2],[3,4]] (up to quant noise)
        // b' = b + delta@W_eff = [0.5,0.5] + [1*1-1*3, 1*2-1*4] = [-1.5,-1.5]
        assert!((wb[4] - (-1.5)).abs() < 0.05, "{}", wb[4]);
        assert!((wb[5] - (-1.5)).abs() < 0.05, "{}", wb[5]);
    }

    /// f64 inverse is tighter than f32 (Table 4 merge-error phenomenon).
    #[test]
    fn precision_changes_inverse_residual() {
        let a = sdd_affine(64, 5);
        let i32v = inverse_prec(&a, MergePrecision::F32);
        let i64v = inverse_prec(&a, MergePrecision::F32InvF64);
        let r32 = crate::linalg::inverse_residual(
            &a.data.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &i32v.data.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            64,
        );
        let r64 = crate::linalg::inverse_residual(
            &a.data.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            &i64v.data.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            64,
        );
        assert!(r64 <= r32, "r64={r64} r32={r32}");
    }
}
