//! Model configuration, flat parameter store, initialization, checkpoints.
//!
//! Parameters live in one flat f32 vector (`theta`), laid out exactly as the
//! L2 graphs expect: `[globals, block0, block1, ...]`. The manifest carries
//! the per-tensor (name, shape, offset) layouts; `Layout` gives named views
//! into the flat storage so the coordinator can patch individual weights
//! (quantize, merge, fold) in place.

pub mod merge;
pub mod zoo;

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::jsonx::Value;
use crate::rngx::Pcg32;
use crate::tensor::{numel, Tensor};

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub family: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub train_batch: usize,
    pub head_dim: usize,
    pub params: usize,
}

impl ModelConfig {
    pub fn from_manifest(v: &Value) -> Self {
        let g = |k: &str| v.req(k).as_usize();
        ModelConfig {
            name: v.req("name").as_str().to_string(),
            family: v.req("family").as_str().to_string(),
            d_model: g("d_model"),
            n_heads: g("n_heads"),
            n_layers: g("n_layers"),
            d_ff: g("d_ff"),
            vocab: g("vocab"),
            seq: g("seq"),
            batch: g("batch"),
            train_batch: g("train_batch"),
            head_dim: g("head_dim"),
            params: g("params"),
        }
    }

    /// Weight matrices that get quantized, with (din, dout) shapes
    /// (mirrors configs.py quantized_weight_names).
    pub fn quantized_weights(&self) -> Vec<(&'static str, usize, usize)> {
        let d = self.d_model;
        let ff = self.d_ff;
        if self.family == "opt" {
            vec![
                ("wq", d, d), ("wk", d, d), ("wv", d, d), ("wo", d, d),
                ("w1", d, ff), ("w2", ff, d),
            ]
        } else {
            vec![
                ("wq", d, d), ("wk", d, d), ("wv", d, d), ("wo", d, d),
                ("wg", d, ff), ("wu", d, ff), ("wd", ff, d),
            ]
        }
    }

    /// Affine transform sites -> weights sharing that input (configs.py).
    pub fn affine_sites(&self) -> Vec<(&'static str, Vec<&'static str>)> {
        if self.family == "opt" {
            vec![
                ("qkv", vec!["wq", "wk", "wv"]),
                ("out", vec!["wo"]),
                ("fc1", vec!["w1"]),
            ]
        } else {
            vec![
                ("qkv", vec!["wq", "wk", "wv"]),
                ("out", vec!["wo"]),
                ("fc1", vec!["wg", "wu"]),
            ]
        }
    }
}

/// Named (shape, offset) views over a flat f32 vector.
#[derive(Clone, Debug)]
pub struct Layout {
    pub entries: Vec<(String, Vec<usize>, usize)>,
    pub size: usize,
    index: HashMap<String, usize>,
}

impl Layout {
    /// Build a layout from ordered (name, shape) pairs with offsets packed
    /// contiguously — the host-side twin of the manifest layouts, used by
    /// [`zoo`] and tests so the engine can run without artifacts.
    pub fn pack(items: &[(&str, Vec<usize>)]) -> Self {
        let mut entries = Vec::with_capacity(items.len());
        let mut size = 0usize;
        for (name, shape) in items {
            entries.push((name.to_string(), shape.clone(), size));
            size += numel(shape);
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, (n, _, _))| (n.clone(), i))
            .collect();
        Layout { entries, size, index }
    }

    pub fn from_manifest(arr: &Value) -> Self {
        let mut entries = Vec::new();
        let mut size = 0;
        for e in arr.as_arr() {
            let name = e.req("name").as_str().to_string();
            let shape = e.req("shape").usize_arr();
            let offset = e.req("offset").as_usize();
            size = size.max(offset + numel(&shape));
            entries.push((name, shape, offset));
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, (n, _, _))| (n.clone(), i))
            .collect();
        Layout { entries, size, index }
    }

    pub fn shape(&self, name: &str) -> &[usize] {
        let i = self.index[name];
        &self.entries[i].1
    }

    pub fn range(&self, name: &str) -> std::ops::Range<usize> {
        let i = *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("layout: no tensor {name:?}"));
        let (_, shape, off) = &self.entries[i];
        *off..*off + numel(shape)
    }

    pub fn has(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn view<'a>(&self, flat: &'a [f32], name: &str) -> &'a [f32] {
        &flat[self.range(name)]
    }

    pub fn view_mut<'a>(&self, flat: &'a mut [f32], name: &str) -> &'a mut [f32] {
        let r = self.range(name);
        &mut flat[r]
    }

    pub fn tensor(&self, flat: &[f32], name: &str) -> Tensor {
        Tensor::new(self.shape(name).to_vec(), self.view(flat, name).to_vec())
    }

    pub fn set(&self, flat: &mut [f32], name: &str, t: &Tensor) {
        assert_eq!(self.shape(name), &t.shape[..], "set {name}");
        self.view_mut(flat, name).copy_from_slice(&t.data);
    }
}

/// The full parameter state of one model.
#[derive(Clone)]
pub struct ParamStore {
    pub cfg: ModelConfig,
    pub globals_layout: Layout,
    pub block_layout: Layout,
    pub theta: Vec<f32>,
}

impl ParamStore {
    pub fn new(cfg: ModelConfig, globals_layout: Layout, block_layout: Layout) -> Self {
        let theta = vec![0.0; globals_layout.size + cfg.n_layers * block_layout.size];
        ParamStore { cfg, globals_layout, block_layout, theta }
    }

    pub fn globals(&self) -> &[f32] {
        &self.theta[..self.globals_layout.size]
    }

    pub fn block_range(&self, i: usize) -> std::ops::Range<usize> {
        assert!(i < self.cfg.n_layers);
        let start = self.globals_layout.size + i * self.block_layout.size;
        start..start + self.block_layout.size
    }

    pub fn block(&self, i: usize) -> &[f32] {
        &self.theta[self.block_range(i)]
    }

    pub fn block_mut(&mut self, i: usize) -> &mut [f32] {
        let r = self.block_range(i);
        &mut self.theta[r]
    }

    /// Tensor copy of one named weight in block `i`.
    pub fn block_tensor(&self, i: usize, name: &str) -> Tensor {
        self.block_layout.tensor(self.block(i), name)
    }

    /// GPT-2-style initialization: N(0, 0.02) for matrices/embeddings,
    /// ones for norm gains, zeros for biases, residual-scaled output projs.
    pub fn init(&mut self, seed: u64) {
        let resid_scale = 0.02 / (2.0 * self.cfg.n_layers as f32).sqrt();
        let mut rng = Pcg32::seeded(seed);
        let gl = self.globals_layout.clone();
        let bl = self.block_layout.clone();
        for (name, shape, _) in &gl.entries {
            let n = numel(shape);
            let vals = match name.as_str() {
                "lnf_g" | "rmsf_g" => vec![1.0; n],
                "lnf_b" => vec![0.0; n],
                _ => rng.normal_vec(n, 0.02),
            };
            self.theta[gl.range(name)].copy_from_slice(&vals);
        }
        for i in 0..self.cfg.n_layers {
            for (name, shape, _) in bl.entries.clone() {
                let n = numel(&shape);
                let vals = if name.ends_with("_g") {
                    vec![1.0; n]
                } else if name.starts_with('b') || name.ends_with("_b") {
                    vec![0.0; n]
                } else if name == "wo" || name == "w2" || name == "wd" {
                    rng.normal_vec(n, resid_scale)
                } else {
                    rng.normal_vec(n, 0.02)
                };
                let r = bl.range(&name);
                self.block_mut(i)[r].copy_from_slice(&vals);
            }
        }
    }

    // -------------------------------------------------------- checkpoints

    /// Save: magic + json header + little-endian f32 payload.
    pub fn save(&self, path: &str) -> Result<()> {
        crate::util::ensure_parent(path)?;
        let header = crate::jsonx::obj(vec![
            ("model", crate::jsonx::s(&self.cfg.name)),
            ("len", crate::jsonx::num(self.theta.len() as f64)),
        ]);
        let htext = crate::jsonx::emit(&header);
        let mut bytes = Vec::with_capacity(16 + htext.len() + self.theta.len() * 4);
        bytes.extend_from_slice(b"AQCK1\n");
        bytes.extend_from_slice(&(htext.len() as u32).to_le_bytes());
        bytes.extend_from_slice(htext.as_bytes());
        for v in &self.theta {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes).context("writing checkpoint")?;
        Ok(())
    }

    pub fn load_into(&mut self, path: &str) -> Result<()> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if !bytes.starts_with(b"AQCK1\n") {
            bail!("{path}: bad checkpoint magic");
        }
        let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        let header = crate::jsonx::parse(
            std::str::from_utf8(&bytes[10..10 + hlen]).context("header utf8")?,
        )
        .map_err(|e| anyhow::anyhow!(e))?;
        let model = header.req("model").as_str();
        if model != self.cfg.name {
            bail!("checkpoint is for {model:?}, expected {:?}", self.cfg.name);
        }
        let n = header.req("len").as_usize();
        if n != self.theta.len() {
            bail!("checkpoint len {n} != theta len {}", self.theta.len());
        }
        let payload = &bytes[10 + hlen..];
        if payload.len() != n * 4 {
            bail!("checkpoint payload truncated");
        }
        for (i, chunk) in payload.chunks_exact(4).enumerate() {
            self.theta[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }
}

#[cfg(test)]
pub(crate) fn test_layout(items: Vec<(&str, Vec<usize>)>) -> Layout {
    Layout::pack(&items)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (ModelConfig, Layout, Layout) {
        let cfg = ModelConfig {
            name: "t".into(),
            family: "opt".into(),
            d_model: 4,
            n_heads: 2,
            n_layers: 2,
            d_ff: 8,
            vocab: 16,
            seq: 8,
            batch: 2,
            train_batch: 2,
            head_dim: 2,
            params: 0,
        };
        let gl = test_layout(vec![
            ("tok_emb", vec![16, 4]),
            ("lnf_g", vec![4]),
            ("lnf_b", vec![4]),
        ]);
        let bl = test_layout(vec![
            ("ln1_g", vec![4]),
            ("wq", vec![4, 4]),
            ("bq", vec![4]),
        ]);
        (cfg, gl, bl)
    }

    #[test]
    fn layout_views_and_init() {
        let (cfg, gl, bl) = tiny();
        let mut ps = ParamStore::new(cfg, gl, bl);
        assert_eq!(ps.theta.len(), 72 + 2 * 24);
        ps.init(1);
        assert!(ps.block_tensor(0, "ln1_g").data.iter().all(|&v| v == 1.0));
        assert!(ps.block_tensor(1, "bq").data.iter().all(|&v| v == 0.0));
        assert_ne!(ps.block_tensor(0, "wq"), ps.block_tensor(1, "wq"));
        let t = Tensor::full(&[4, 4], 7.0);
        let bl2 = ps.block_layout.clone();
        bl2.set(ps.block_mut(1), "wq", &t);
        assert_eq!(ps.block_tensor(1, "wq"), t);
        assert_ne!(ps.block_tensor(0, "wq"), t);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (cfg, gl, bl) = tiny();
        let mut ps = ParamStore::new(cfg, gl, bl);
        ps.init(3);
        let path = "/tmp/aq_test_ck.bin";
        ps.save(path).unwrap();
        let mut ps2 = ps.clone();
        ps2.theta.iter_mut().for_each(|v| *v = 0.0);
        ps2.load_into(path).unwrap();
        assert_eq!(ps.theta, ps2.theta);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_model() {
        let (cfg, gl, bl) = tiny();
        let mut ps = ParamStore::new(cfg, gl.clone(), bl.clone());
        ps.init(3);
        let path = "/tmp/aq_test_ck2.bin";
        ps.save(path).unwrap();
        let mut cfg2 = ps.cfg.clone();
        cfg2.name = "other".into();
        let mut ps2 = ParamStore::new(cfg2, gl, bl);
        assert!(ps2.load_into(path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn init_is_deterministic() {
        let (cfg, gl, bl) = tiny();
        let mut a = ParamStore::new(cfg.clone(), gl.clone(), bl.clone());
        let mut b = ParamStore::new(cfg, gl, bl);
        a.init(9);
        b.init(9);
        assert_eq!(a.theta, b.theta);
    }
}
