//! Host-side model zoo: the size ladder from `python/compile/configs.py`
//! replicated in rust so the packed-weight engine (and tests) can construct
//! models, layouts, and seeded checkpoints without the AOT artifacts.
//!
//! Kept bit-compatible with the manifest the AOT pipeline emits: same
//! ordered (name, shape) lists, same flat offsets — a `ParamStore` built
//! here accepts checkpoints trained through the PJRT path unchanged.

use super::{Layout, ModelConfig, ParamStore};

/// Names of the built-in models, smallest first per family.
pub const NAMES: [&str; 5] = ["opt-s1", "opt-s2", "opt-s3", "ll-s1", "ll-s2"];

/// Built-in config by name (mirrors configs.py MODELS).
pub fn config(name: &str) -> Option<ModelConfig> {
    let (family, d_model, n_heads, n_layers, d_ff) = match name {
        "opt-s1" => ("opt", 128, 4, 2, 512),
        "opt-s2" => ("opt", 256, 8, 3, 1024),
        "opt-s3" => ("opt", 384, 12, 4, 1536),
        "ll-s1" => ("ll", 128, 4, 2, 384),
        "ll-s2" => ("ll", 256, 8, 3, 768),
        _ => return None,
    };
    let mut cfg = ModelConfig {
        name: name.to_string(),
        family: family.to_string(),
        d_model,
        n_heads,
        n_layers,
        d_ff,
        vocab: 256,
        seq: 128,
        batch: 8,
        train_batch: 16,
        head_dim: d_model / n_heads,
        params: 0,
    };
    let (gl, bl) = layouts(&cfg);
    cfg.params = gl.size + cfg.n_layers * bl.size;
    Some(cfg)
}

/// (globals_layout, block_layout) for a config — the ordered (name, shape)
/// lists from configs.py `global_weight_names` / `block_weight_names`.
pub fn layouts(cfg: &ModelConfig) -> (Layout, Layout) {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let (globals, blocks): (Vec<(&str, Vec<usize>)>, Vec<(&str, Vec<usize>)>) =
        if cfg.family == "opt" {
            (
                vec![
                    ("tok_emb", vec![cfg.vocab, d]),
                    ("pos_emb", vec![cfg.seq, d]),
                    ("lnf_g", vec![d]),
                    ("lnf_b", vec![d]),
                ],
                vec![
                    ("ln1_g", vec![d]),
                    ("ln1_b", vec![d]),
                    ("wq", vec![d, d]),
                    ("bq", vec![d]),
                    ("wk", vec![d, d]),
                    ("bk", vec![d]),
                    ("wv", vec![d, d]),
                    ("bv", vec![d]),
                    ("wo", vec![d, d]),
                    ("bo", vec![d]),
                    ("ln2_g", vec![d]),
                    ("ln2_b", vec![d]),
                    ("w1", vec![d, ff]),
                    ("b1", vec![ff]),
                    ("w2", vec![ff, d]),
                    ("b2", vec![d]),
                ],
            )
        } else {
            (
                vec![("tok_emb", vec![cfg.vocab, d]), ("rmsf_g", vec![d])],
                vec![
                    ("rms1_g", vec![d]),
                    ("wq", vec![d, d]),
                    ("wk", vec![d, d]),
                    ("wv", vec![d, d]),
                    ("wo", vec![d, d]),
                    ("rms2_g", vec![d]),
                    ("wg", vec![d, ff]),
                    ("wu", vec![d, ff]),
                    ("wd", vec![ff, d]),
                ],
            )
        };
    (Layout::pack(&globals), Layout::pack(&blocks))
}

/// A fresh `ParamStore` for a built-in model.
pub fn param_store(name: &str) -> Option<ParamStore> {
    let cfg = config(name)?;
    let (gl, bl) = layouts(&cfg);
    Some(ParamStore::new(cfg, gl, bl))
}

/// A seeded, initialized `ParamStore` — the deterministic "checkpoint"
/// the engine tests and the offline `generate` path fall back to when no
/// trained checkpoint exists.
pub fn seeded_store(name: &str, seed: u64) -> Option<ParamStore> {
    let mut ps = param_store(name)?;
    ps.init(seed);
    Some(ps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::numel;

    #[test]
    fn all_builtins_construct() {
        for name in NAMES {
            let cfg = config(name).unwrap();
            assert_eq!(cfg.name, name);
            assert_eq!(cfg.d_model % 128, 0, "{name}: dims must divide g128");
            assert_eq!(cfg.d_ff % 128, 0, "{name}");
            assert_eq!(cfg.head_dim * cfg.n_heads, cfg.d_model);
            let ps = seeded_store(name, 1).unwrap();
            assert_eq!(ps.theta.len(), cfg.params);
            assert!(ps.theta.iter().any(|&v| v != 0.0));
        }
        assert!(config("opt-xl").is_none());
    }

    #[test]
    fn layouts_cover_quantized_weights() {
        for name in NAMES {
            let cfg = config(name).unwrap();
            let (_, bl) = layouts(&cfg);
            for (w, din, dout) in cfg.quantized_weights() {
                assert_eq!(bl.shape(w), &[din, dout], "{name}/{w}");
            }
        }
    }

    #[test]
    fn param_count_matches_layout_sum() {
        let cfg = config("opt-s1").unwrap();
        let (gl, bl) = layouts(&cfg);
        let by_hand: usize = gl.entries.iter().map(|(_, s, _)| numel(s)).sum::<usize>()
            + cfg.n_layers * bl.entries.iter().map(|(_, s, _)| numel(s)).sum::<usize>();
        assert_eq!(cfg.params, by_hand);
    }
}
