//! `affinequant` — the leader binary.
//!
//! Subcommands:
//!   generate  --model NAME [--config w4a16g128] [--prompt "the "] [--n N]
//!             [--max-new N] [--topk K] [--temp=T] [--batch B] [--seed S]
//!             [--prefill-chunk N] [--token-budget N] [--kernel V]
//!             [--ckpt DIR] [--save-packed PATH | --load-packed PATH]
//!             — packed-weight engine decode; pure host, no artifacts.
//!             `--prefill-chunk` (default 16, 0 = whole prompt) pushes that
//!             many prompt tokens per scheduler tick; `--token-budget`
//!             caps total rows per tick (0 = unlimited). `--kernel`
//!             (scalar|avx2|avx512|neon; also the `AQ_KERNEL` env) pins
//!             the GEMM dispatch variant, scalar-falling-back when the
//!             CPU/build lacks it. Greedy output is bit-identical for any
//!             setting, kernel variant included.
//!   serve     --model NAME [--config C] [--addr 127.0.0.1] [--port 8080]
//!             [--batch B] [--queue-cap N] [--client-cap N] [--workers N]
//!             [--deadline-ms D] [--max-new N] [--prefill-chunk N]
//!             [--token-budget N] [--ckpt DIR] [--load-packed PATH]
//!             [--kv-pages N] [--kv-page-tokens N]
//!             [--fault-tick-ms N] [--fault-admit-ms N]
//!             [--fault-drop-after N] [--no-telemetry] [--log-requests]
//!             [--draft-bits B] [--kernel V]
//!             — overload-safe HTTP serving over the packed engine:
//!             POST /v1/completions (OpenAI-style, `"stream": true` for
//!             SSE), GET /healthz, GET /v1/stats, GET /metrics
//!             (Prometheus), GET /v1/trace/<id>, GET /v1/journal,
//!             GET /v1/health/numeric, POST /admin/shutdown.
//!             `--draft-bits` (default 2, 0 = off) double-quantizes a
//!             lower-bit draft variant for the cross-bit-width divergence
//!             sampler behind /v1/health/numeric.
//!             Sheds load with 429 + Retry-After past the queue cap,
//!             evicts expired requests (504/`deadline`), drains
//!             gracefully on SIGTERM. `--kv-pages` bounds the paged KV
//!             pool; requests are admitted only when their worst-case
//!             page count is reservable (429 otherwise). Pure host, no
//!             artifacts.
//!   profile   --model NAME [--config C] [--batch B] [--max-new N]
//!             [--n N] [--prefill-chunk N] [--token-budget N] [--kernel V]
//!             [--ckpt DIR] [--load-packed PATH]
//!             — run a canned mixed-length greedy workload with telemetry
//!             and sampled kernel timing enabled, then print the latency
//!             breakdown (queue wait / TTFT / inter-token / tick phases /
//!             kernels) and save it to results/profile_latency.{md,csv}.
//!             Pure host, no artifacts.
//!   doctor    --model NAME [--config C] [--batch B] [--max-new N]
//!             [--n N] [--draft-bits B] [--kernel V] [--ckpt DIR]
//!             [--load-packed PATH]
//!             — numeric-health exhibit: canned workload with sampled
//!             activation stats, per-layer drift verdicts against the
//!             baked calibration envelopes, the w-serve vs w-draft
//!             divergence summary, and the active GEMM kernel dispatch;
//!             saves results/numeric_health.{md,csv}.
//!             Pure host, no artifacts.
//!   train     --model NAME | --all  [--steps N] [--out DIR]      (pjrt)
//!   quantize  --model NAME --method M --config w3a16g128 [--alpha A]
//!   eval      --model NAME [--method M --config C] [--zeroshot]  (pjrt)
//!   info      print the artifact manifest summary                (pjrt)
//!
//! Everything here drives the library; the table/figure reproductions live
//! under `rust/benches/` and `examples/`.

use anyhow::Result;

use affinequant::cli::Cli;

fn main() -> Result<()> {
    let cli = match Cli::from_env() {
        Ok(c) => c,
        Err(_) => {
            eprintln!(
                "usage: affinequant <generate|serve|profile|doctor|train|quantize|eval|info> \
                 [--options]"
            );
            std::process::exit(2);
        }
    };
    if cli.cmd == "generate" {
        return cmd_generate(&cli);
    }
    if cli.cmd == "serve" {
        return cmd_serve(&cli);
    }
    if cli.cmd == "profile" {
        return cmd_profile(&cli);
    }
    if cli.cmd == "doctor" {
        return cmd_doctor(&cli);
    }
    pjrt_main(cli)
}

/// Build the packed serving engine a pure-host subcommand drives. Uses a
/// trained checkpoint when one exists under `--ckpt` (same `.aqck` files
/// the PJRT trainer writes), otherwise a deterministic seeded init — so
/// `generate` and `serve` run fully offline.
fn build_engine(cli: &Cli, tag: &str) -> Result<affinequant::engine::Engine> {
    use affinequant::cli::parse_config;
    use affinequant::engine::{kernels, Engine, SchedConfig};
    use affinequant::model::zoo;

    // pin the GEMM dispatch variant before any weight is packed/loaded —
    // every PackedLinear resolves its kernel at construction time
    if let Some(k) = cli.get("kernel") {
        kernels::set_requested(k)?;
    }
    let ki = kernels::info();
    eprintln!(
        "[{tag}] kernel dispatch: {} ({}{})",
        ki.selected,
        ki.source,
        if ki.fell_back {
            format!(", fell back from {:?}", ki.requested.as_deref().unwrap_or("?"))
        } else {
            String::new()
        },
    );

    let model = cli.str_or("model", "opt-s1");
    let max_batch = cli.usize_or("batch", 8);
    let mut engine = if let Some(path) = cli.get("load-packed") {
        Engine::load(path, max_batch)?
    } else {
        let (spec, _act_bits) = parse_config(&cli.str_or("config", "w4a16g128"))?;
        let mut ps = zoo::param_store(&model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model:?} (try {:?})", zoo::NAMES))?;
        let ckpt = format!("{}/{model}.aqck", cli.str_or("ckpt", "checkpoints"));
        if std::path::Path::new(&ckpt).exists() {
            ps.load_into(&ckpt)?;
            eprintln!("[{tag}] loaded checkpoint {ckpt}");
        } else {
            ps.init(cli.usize_or("init-seed", 42) as u64);
            eprintln!("[{tag}] no checkpoint at {ckpt}; using seeded init");
        }
        Engine::from_store(&ps, spec, max_batch)
    };
    engine.sched = SchedConfig {
        prefill_chunk: cli.usize_or("prefill-chunk", 16),
        token_budget: cli.usize_or("token-budget", 0),
        queue_cap: 0, // generate: unbounded; serve overwrites from --queue-cap
    };
    Ok(engine)
}

/// Packed-engine decode (see [`build_engine`] for checkpoint fallback).
fn cmd_generate(cli: &Cli) -> Result<()> {
    use affinequant::engine::{Engine, Sampler};
    use affinequant::util::{human_secs, Timer};

    let mut engine = build_engine(cli, "generate")?;
    if let Some(path) = cli.get("save-packed") {
        engine.model.save(path)?;
        eprintln!("[generate] saved packed model to {path}");
    }
    eprintln!("[generate] {}", engine.memory_report());
    let show = |v: usize| if v == 0 { "unlimited".to_string() } else { v.to_string() };
    eprintln!(
        "[generate] prefill chunk {} tokens/tick, token budget {}",
        show(engine.sched.prefill_chunk),
        show(engine.sched.token_budget),
    );

    let prompt = cli.str_or("prompt", "the ");
    let n = cli.usize_or("n", 1);
    let max_new = cli.usize_or("max-new", 48);
    let topk = cli.usize_or("topk", 0);
    let sampler = if topk > 1 {
        Sampler::TopK { k: topk, temperature: cli.f32_or("temp", 1.0) }
    } else {
        Sampler::Greedy
    };
    // distinct per-request suffixes so top-k runs diverge visibly
    let prompts: Vec<String> = (0..n).map(|i| format!("{prompt}{}", "and ".repeat(i % 3))).collect();
    let prefs: Vec<&str> = prompts.iter().map(|s| s.as_str()).collect();
    let reqs = Engine::byte_requests(&prefs, max_new);
    let t = Timer::start();
    // submit errors (empty prompt, zero max-new) report instead of panic
    let (completions, stats) = engine.generate(reqs, sampler, cli.usize_or("seed", 1) as u64)?;
    let secs = t.secs();
    for (p, c) in prefs.iter().zip(&completions) {
        // completions come back sorted by id, i.e. prompt order
        println!("{p}⟨{}⟩ [{}]", Engine::completion_text(c), c.finish.label());
    }
    eprintln!(
        "[generate] {} generated (+{} prefill) in {} — {:.1} tok/s throughput \
         (batch peak {}, {} scheduler steps)",
        stats.tokens_generated,
        stats.tokens_processed - stats.tokens_generated,
        human_secs(secs),
        stats.tokens_processed as f64 / secs.max(1e-9),
        stats.peak_batch,
        stats.scheduler_steps,
    );
    Ok(())
}

/// Overload-safe HTTP serving over the packed engine. Blocks until the
/// server drains (SIGTERM/SIGINT or `POST /admin/shutdown`).
fn cmd_serve(cli: &Cli) -> Result<()> {
    use affinequant::engine::Sampler;
    use affinequant::server::{fault::FaultConfig, install_signal_handlers, Server, ServerConfig};

    let mut engine = build_engine(cli, "serve")?;
    let telemetry = !cli.flag("no-telemetry");
    // the cross-bit-width divergence sampler needs a lower-bit draft
    // variant; double-quantized from the serving weights so it also works
    // for --load-packed (no ParamStore around). 0 disables.
    let draft_bits = cli.usize_or("draft-bits", 2) as u32;
    if telemetry && draft_bits > 0 && draft_bits < engine.model.spec.bits {
        engine.enable_draft(affinequant::quant::QuantSpec::new(
            draft_bits,
            engine.model.spec.group,
        ));
        eprintln!(
            "[serve] divergence sampler on: w{} serve vs w{draft_bits} draft",
            engine.model.spec.bits
        );
    }
    let topk = cli.usize_or("topk", 0);
    let cfg = ServerConfig {
        addr: format!("{}:{}", cli.str_or("addr", "127.0.0.1"), cli.usize_or("port", 8080)),
        workers: cli.usize_or("workers", 4),
        queue_cap: cli.usize_or("queue-cap", 32),
        client_cap: cli.usize_or("client-cap", 8),
        default_max_new: cli.usize_or("max-new", 64),
        default_deadline_ms: cli.usize_or("deadline-ms", 0) as u64,
        retry_after_s: cli.usize_or("retry-after", 1) as u64,
        kv_pages: cli.usize_or("kv-pages", 0),
        kv_page_tokens: cli.usize_or("kv-page-tokens", 0),
        sampler: if topk > 1 {
            Sampler::TopK { k: topk, temperature: cli.f32_or("temp", 1.0) }
        } else {
            Sampler::Greedy
        },
        seed: cli.usize_or("seed", 1) as u64,
        fault: FaultConfig {
            tick_delay_ms: cli.usize_or("fault-tick-ms", 0) as u64,
            admit_delay_ms: cli.usize_or("fault-admit-ms", 0) as u64,
            drop_after_tokens: cli.usize_or("fault-drop-after", 0),
        },
        telemetry,
        log_requests: cli.flag("log-requests"),
    };
    eprintln!("[serve] {}", engine.memory_report());
    if cfg.fault.active() {
        eprintln!("[serve] FAULT INJECTION ACTIVE: {:?}", cfg.fault);
    }
    install_signal_handlers();
    let handle = Server::spawn(engine, cfg)?;
    eprintln!(
        "[serve] listening on http://{} (queue cap {}, SIGTERM drains gracefully)",
        handle.addr,
        cli.usize_or("queue-cap", 32),
    );
    handle.join();
    eprintln!("[serve] drained; bye");
    Ok(())
}

/// Telemetry exhibit: run a canned mixed-length greedy workload with the
/// recorder and sampled kernel timing on, then print where the time went.
fn cmd_profile(cli: &Cli) -> Result<()> {
    use affinequant::benchx::Table;
    use affinequant::engine::{Request, Sampler};
    use affinequant::telemetry::{kernel, Histogram, Recorder};
    use affinequant::util::human_secs;
    use affinequant::util::Timer;

    let mut engine = build_engine(cli, "profile")?;
    engine.recorder = Recorder::new_enabled();
    kernel::enable(true);
    eprintln!("[profile] {}", engine.memory_report());

    // canned workload: n requests with staggered prompt lengths (1/4, 1/2,
    // 3/4 of the context window) so prefill, decode, and mixed ticks all
    // show up in the phase split
    let n = cli.usize_or("n", 6).max(1);
    let max_new = cli.usize_or("max-new", 32);
    let seq = engine.model.cfg.seq;
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let plen = (seq * (1 + i % 3) / 4).saturating_sub(max_new).max(1);
            Request {
                id: i as u64 + 1,
                prompt: (0..plen).map(|j| (j % 251) as i32).collect(),
                max_new,
                eos: None,
            }
        })
        .collect();
    let t = Timer::start();
    let (_completions, stats) = engine.generate(reqs, Sampler::Greedy, 1)?;
    let secs = t.secs();
    eprintln!(
        "[profile] {} tokens generated (+{} prefill) in {} — {:.1} tok/s",
        stats.tokens_generated,
        stats.tokens_processed - stats.tokens_generated,
        human_secs(secs),
        stats.tokens_processed as f64 / secs.max(1e-9),
    );

    let tele = engine.recorder.telemetry().expect("recorder was enabled above");
    let mut table = Table::new(
        "latency breakdown (profile workload)",
        &["stage", "count", "p50 ms", "p90 ms", "p99 ms", "mean ms"],
    );
    let mut push = |stage: &str, h: &Histogram| {
        table.row(vec![
            stage.to_string(),
            h.count().to_string(),
            format!("{:.3}", h.percentile_ms(0.50)),
            format!("{:.3}", h.percentile_ms(0.90)),
            format!("{:.3}", h.percentile_ms(0.99)),
            format!("{:.3}", h.mean_ms()),
        ]);
    };
    push("queue_wait", &tele.queue_wait);
    push("ttft", &tele.ttft);
    push("inter_token", &tele.inter_token);
    push("request", &tele.request);
    push("tick", &tele.tick);
    push("tick_prefill", &tele.tick_prefill);
    push("tick_decode", &tele.tick_decode);
    push("tick_mixed", &tele.tick_mixed);
    let ks = kernel::stats();
    for (i, label) in kernel::BITS_LABELS.iter().enumerate() {
        if ks.gemm[i].count() > 0 {
            push(&format!("gemm_w{label}"), &ks.gemm[i]);
        }
    }
    push("head_logits", &ks.head);
    table.print();
    affinequant::report::save_table(&table, "profile_latency")?;
    Ok(())
}

/// Numeric-health exhibit: run the canned workload with the recorder and
/// the cross-bit-width divergence sampler on, then print (and save to
/// `results/numeric_health.{md,csv}`) the per-layer drift verdicts against
/// the baked calibration envelopes.
fn cmd_doctor(cli: &Cli) -> Result<()> {
    use affinequant::benchx::Table;
    use affinequant::engine::{Request, Sampler};
    use affinequant::quant::QuantSpec;
    use affinequant::telemetry::{kernel, Recorder};
    use affinequant::util::{human_secs, Timer};

    let mut engine = build_engine(cli, "doctor")?;
    engine.recorder = Recorder::new_enabled();
    kernel::enable(true);
    let serve_bits = engine.model.spec.bits;
    let draft_bits = cli.usize_or("draft-bits", 2) as u32;
    if draft_bits > 0 && draft_bits < serve_bits {
        engine.enable_draft(QuantSpec::new(draft_bits, engine.model.spec.group));
        eprintln!("[doctor] divergence sampler: w{serve_bits} serve vs w{draft_bits} draft");
    }
    eprintln!("[doctor] {}", engine.memory_report());
    {
        use affinequant::engine::kernels;
        let ki = kernels::info();
        let avail: Vec<&str> = ki.available.iter().map(|v| v.name()).collect();
        eprintln!(
            "[doctor] kernel: {} (selection {}{}; available: {})",
            engine.model.kernel_name(),
            ki.source,
            if ki.fell_back { ", fell back" } else { "" },
            avail.join(","),
        );
    }

    // same canned mixed-length workload as `profile`; decode tails are long
    // enough that the divergence sampler fires (first probe at decode tick
    // 4) and every layer clears the drift detector's minimum window
    let n = cli.usize_or("n", 6).max(1);
    let max_new = cli.usize_or("max-new", 48);
    let seq = engine.model.cfg.seq;
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let plen = (seq * (1 + i % 3) / 4).saturating_sub(max_new).max(1);
            Request {
                id: i as u64 + 1,
                prompt: (0..plen).map(|j| (j % 251) as i32).collect(),
                max_new,
                eos: None,
            }
        })
        .collect();
    let t = Timer::start();
    let (_completions, stats) = engine.generate(reqs, Sampler::Greedy, 1)?;
    let secs = t.secs();
    eprintln!(
        "[doctor] {} tokens generated in {} — {:.1} tok/s",
        stats.tokens_generated,
        human_secs(secs),
        stats.tokens_processed as f64 / secs.max(1e-9),
    );

    let tele = engine.recorder.telemetry().expect("recorder was enabled above");
    let snap = tele.numeric.snapshot();
    let mut table = Table::new(
        "numeric health (doctor workload)",
        &[
            "layer",
            "verdict",
            "baked absmax",
            "live absmax",
            "sampled rows",
            "outlier %",
            "weight mse",
            "weight max|e|",
        ],
    );
    for l in &snap.layers {
        table.row(vec![
            l.layer.to_string(),
            l.verdict().to_string(),
            format!("{:.4}", l.env.absmax),
            format!("{:.4}", l.absmax),
            l.rows.to_string(),
            format!("{:.1}", 100.0 * l.outlier_frac),
            format!("{:.3e}", l.env.weight_mse),
            format!("{:.4}", l.env.weight_max_abs),
        ]);
    }
    table.print();
    let drift_layers = snap.layers.iter().filter(|l| l.drifting).count();
    let d = &snap.div;
    eprintln!(
        "[doctor] drift layers: {drift_layers}/{}; divergence: {} probes, \
         top-1 agree {:.1}% (w{} vs w{}), max |logit delta| {:.4}",
        snap.layers.len(),
        d.probes,
        d.agree_pct(),
        d.serve_bits,
        d.draft_bits,
        d.max_logit_delta,
    );
    affinequant::report::save_table(&table, "numeric_health")?;
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_main(cli: Cli) -> Result<()> {
    anyhow::bail!(
        "subcommand {:?} needs the PJRT runtime; this binary was built with \
         --no-default-features (only `generate`, `serve`, `profile`, and \
         `doctor` are available)",
        cli.cmd
    )
}

#[cfg(feature = "pjrt")]
fn pjrt_main(cli: Cli) -> Result<()> {
    use anyhow::bail;

    use affinequant::cli::parse_config;
    use affinequant::coordinator::CalibOptions;
    use affinequant::data::CorpusKind;
    use affinequant::model::ParamStore;
    use affinequant::runtime::Runtime;
    use affinequant::train::{ensure_checkpoint, TrainConfig};
    use affinequant::{baselines, eval};

    let artifacts = cli.str_or("artifacts", "artifacts");
    let rt_root = Runtime::load(&artifacts)?;

    match cli.cmd.as_str() {
        "info" => {
            for name in rt_root.model_names() {
                let rt = rt_root.model(&name)?;
                println!(
                    "{name:8} family={:3} d={} h={} L={} ff={} params={}",
                    rt.cfg.family,
                    rt.cfg.d_model,
                    rt.cfg.n_heads,
                    rt.cfg.n_layers,
                    rt.cfg.d_ff,
                    affinequant::util::human_count(rt.cfg.params as f64)
                );
            }
        }
        "train" => {
            let out = cli.str_or("out", "checkpoints");
            let models: Vec<String> = if cli.flag("all") {
                rt_root.model_names()
            } else {
                vec![cli.str_or("model", "opt-s1")]
            };
            for name in models {
                let rt = rt_root.model(&name)?;
                let mut ps = ParamStore::new(
                    rt.cfg.clone(),
                    rt.globals_layout.clone(),
                    rt.block_layout.clone(),
                );
                let tc = TrainConfig {
                    steps: cli.usize_or("steps", TrainConfig::default().steps),
                    ..TrainConfig::default()
                };
                ensure_checkpoint(&rt, &mut ps, &out, &tc)?;
            }
        }
        "quantize" | "eval" => {
            let name = cli.str_or("model", "opt-s1");
            let rt = rt_root.model(&name)?;
            let mut ps = ParamStore::new(
                rt.cfg.clone(),
                rt.globals_layout.clone(),
                rt.block_layout.clone(),
            );
            ensure_checkpoint(
                &rt,
                &mut ps,
                &cli.str_or("ckpt", "checkpoints"),
                &TrainConfig::default(),
            )?;

            let method = cli.str_or("method", "fp16");
            let (qps, act_bits) = if method == "fp16" {
                (ps.clone(), 16)
            } else {
                let (spec, act_bits) = parse_config(&cli.str_or("config", "w4a16"))?;
                let alpha = cli.f32_or("alpha", CalibOptions::affinequant(spec, act_bits).alpha);
                (baselines::quantize_with(&rt, &ps, &method, spec, act_bits, alpha)?, act_bits)
            };
            let qmax = eval::act_qmax(act_bits);
            for kind in CorpusKind::all() {
                let ppl = eval::perplexity(&rt, &qps, kind, 8, qmax)?;
                println!(
                    "{name} {method} {} ppl[{}] = {ppl:.3}",
                    cli.str_or("config", "-"),
                    kind.name()
                );
            }
            if cli.flag("zeroshot") {
                for (task, acc) in eval::zeroshot::suite(&rt, &qps, 64, qmax)? {
                    println!("{name} {method} zeroshot {task}: {acc:.2}%");
                }
            }
        }
        other => bail!("unknown subcommand {other:?}"),
    }
    Ok(())
}
