//! `affinequant` — the leader binary.
//!
//! Subcommands:
//!   train     --model NAME | --all  [--steps N] [--out DIR]
//!   quantize  --model NAME --method M --config w3a16g128 [--alpha A]
//!   eval      --model NAME [--method M --config C] [--zeroshot]
//!   info      print the artifact manifest summary
//!
//! Everything here drives the library; the table/figure reproductions live
//! under `rust/benches/` and `examples/`.

use anyhow::{bail, Result};

use affinequant::cli::{parse_config, Cli};
use affinequant::coordinator::CalibOptions;
use affinequant::data::CorpusKind;
use affinequant::model::ParamStore;
use affinequant::runtime::Runtime;
use affinequant::train::{ensure_checkpoint, TrainConfig};
use affinequant::{baselines, eval};

fn main() -> Result<()> {
    let cli = match Cli::from_env() {
        Ok(c) => c,
        Err(_) => {
            eprintln!("usage: affinequant <train|quantize|eval|info> [--options]");
            std::process::exit(2);
        }
    };
    let artifacts = cli.str_or("artifacts", "artifacts");
    let rt_root = Runtime::load(&artifacts)?;

    match cli.cmd.as_str() {
        "info" => {
            for name in rt_root.model_names() {
                let rt = rt_root.model(&name)?;
                println!(
                    "{name:8} family={:3} d={} h={} L={} ff={} params={}",
                    rt.cfg.family,
                    rt.cfg.d_model,
                    rt.cfg.n_heads,
                    rt.cfg.n_layers,
                    rt.cfg.d_ff,
                    affinequant::util::human_count(rt.cfg.params as f64)
                );
            }
        }
        "train" => {
            let out = cli.str_or("out", "checkpoints");
            let models: Vec<String> = if cli.flag("all") {
                rt_root.model_names()
            } else {
                vec![cli.str_or("model", "opt-s1")]
            };
            for name in models {
                let rt = rt_root.model(&name)?;
                let mut ps = ParamStore::new(
                    rt.cfg.clone(),
                    rt.globals_layout.clone(),
                    rt.block_layout.clone(),
                );
                let tc = TrainConfig {
                    steps: cli.usize_or("steps", TrainConfig::default().steps),
                    ..TrainConfig::default()
                };
                ensure_checkpoint(&rt, &mut ps, &out, &tc)?;
            }
        }
        "quantize" | "eval" => {
            let name = cli.str_or("model", "opt-s1");
            let rt = rt_root.model(&name)?;
            let mut ps = ParamStore::new(
                rt.cfg.clone(),
                rt.globals_layout.clone(),
                rt.block_layout.clone(),
            );
            ensure_checkpoint(
                &rt,
                &mut ps,
                &cli.str_or("ckpt", "checkpoints"),
                &TrainConfig::default(),
            )?;

            let method = cli.str_or("method", "fp16");
            let (qps, act_bits) = if method == "fp16" {
                (ps.clone(), 16)
            } else {
                let (spec, act_bits) = parse_config(&cli.str_or("config", "w4a16"))?;
                let alpha = cli.f32_or("alpha", CalibOptions::affinequant(spec, act_bits).alpha);
                (baselines::quantize_with(&rt, &ps, &method, spec, act_bits, alpha)?, act_bits)
            };
            let qmax = eval::act_qmax(act_bits);
            for kind in CorpusKind::all() {
                let ppl = eval::perplexity(&rt, &qps, kind, 8, qmax)?;
                println!(
                    "{name} {method} {} ppl[{}] = {ppl:.3}",
                    cli.str_or("config", "-"),
                    kind.name()
                );
            }
            if cli.flag("zeroshot") {
                for (task, acc) in eval::zeroshot::suite(&rt, &qps, 64, qmax)? {
                    println!("{name} {method} zeroshot {task}: {acc:.2}%");
                }
            }
        }
        other => bail!("unknown subcommand {other:?}"),
    }
    Ok(())
}
