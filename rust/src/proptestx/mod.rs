//! Mini property-testing harness (proptest is not vendored offline).
//!
//! Deterministic seed sweep + simple input shrinking for numeric cases:
//! when a case fails, the harness retries with scaled-down variants and
//! reports the smallest failing case found.

use crate::rngx::Pcg32;

/// A generated case that knows how to shrink itself.
pub trait Shrink: Clone + std::fmt::Debug {
    /// Candidate smaller versions of self (tried in order).
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for Vec<f32> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() > 1 {
            out.push(self[..self.len() / 2].to_vec());
        }
        // halve magnitudes
        if self.iter().any(|v| v.abs() > 1e-3) {
            out.push(self.iter().map(|v| v / 2.0).collect());
        }
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        if *self > 1 {
            vec![self / 2, self - 1]
        } else {
            Vec::new()
        }
    }
}

impl Shrink for (usize, usize) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.0 > 1 {
            out.push((self.0 / 2, self.1));
        }
        if self.1 > 1 {
            out.push((self.0, self.1 / 2));
        }
        out
    }
}

pub struct Runner {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Runner { cases: 64, seed: 0x5eed, max_shrinks: 200 }
    }
}

impl Runner {
    /// Run `prop` on `cases` generated inputs; panic with the smallest
    /// failing input if any case fails.
    pub fn run<T, G, P>(&self, name: &str, mut gen: G, mut prop: P)
    where
        T: Shrink,
        G: FnMut(&mut Pcg32) -> T,
        P: FnMut(&T) -> Result<(), String>,
    {
        let mut rng = Pcg32::seeded(self.seed);
        for case in 0..self.cases {
            let input = gen(&mut rng);
            if let Err(first_err) = prop(&input) {
                // shrink
                let mut best = input.clone();
                let mut best_err = first_err;
                let mut budget = self.max_shrinks;
                let mut progress = true;
                while progress && budget > 0 {
                    progress = false;
                    for cand in best.shrinks() {
                        budget -= 1;
                        if let Err(e) = prop(&cand) {
                            best = cand;
                            best_err = e;
                            progress = true;
                            break;
                        }
                        if budget == 0 {
                            break;
                        }
                    }
                }
                panic!(
                    "property {name:?} failed (case {case}/{}):\n  input: {best:?}\n  error: {best_err}",
                    self.cases
                );
            }
        }
    }
}

/// Assert helper producing Result for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        Runner::default().run(
            "abs is nonneg",
            |rng| rng.normal_vec(8, 1.0),
            |xs| {
                for x in xs {
                    prop_assert!(x.abs() >= 0.0, "abs < 0");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            Runner { cases: 32, seed: 1, max_shrinks: 100 }.run(
                "all values below 0.5",
                |rng| rng.normal_vec(64, 2.0),
                |xs: &Vec<f32>| {
                    for x in xs {
                        prop_assert!(*x < 0.5, "found {x}");
                    }
                    Ok(())
                },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrinking should reduce to a short vector
        let input_len = msg.split("input: ").nth(1).unwrap().matches(',').count();
        assert!(input_len < 64, "{msg}");
    }

    #[test]
    fn usize_shrinking() {
        assert_eq!(8usize.shrinks(), vec![4, 7]);
        assert!(1usize.shrinks().is_empty());
    }
}
