//! Host-side transformer forward + batched autoregressive decoding.
//!
//! Reimplements the L2 block math (`python/compile/blocks.py`) against the
//! packed-weight GEMM: pre-LN attention (causal, RoPE for the `ll` family)
//! and the family MLP, with per-sequence KV-cached incremental steps.
//!
//! **Parity contract:** [`step`] (incremental, any batch composition,
//! including multi-token chunks of one sequence) and [`forward_full`]
//! (whole-context reference) run the *same* per-row code — same norm, same
//! fused GEMM (whose row results are independent of the batch size), same
//! attention accumulation order — so greedy decode is bit-identical to
//! re-running the full forward after every token, for any prefill chunk
//! size. Within a layer, each row writes its K/V and attends *before* the
//! next row writes (see [`layer_forward`]'s row loop): a chunk therefore
//! sees exactly the cache states token-at-a-time stepping would have
//! produced. The paged cache is append-only — out-of-window pages are
//! released only at step start ([`KvCache::trim`]), never mid-chunk — so
//! the interleave survives any page size, with or without prefix sharing.
//! Every GEMM goes through the model's per-linear dispatch kernel
//! ([`super::kernels`]), fixed at pack/load time and bit-identical across
//! ISA variants, so the contract holds for any `--kernel`/`AQ_KERNEL`
//! selection too. Tests in `rust/tests/engine.rs` assert exact equality.

use crate::rngx::Pcg32;
use crate::telemetry::numeric::{NumericHealth, Welford};
use crate::tensor::Tensor;

use super::kv::KvCache;
use super::packed::{PackedBlock, PackedModel};

pub const LN_EPS: f32 = 1e-5;

// ------------------------------------------------------------ primitives

pub fn layer_norm_row(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mu = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    for ((o, &v), (&gg, &bb)) in out.iter_mut().zip(x).zip(g.iter().zip(b)) {
        *o = (v - mu) * inv * gg + bb;
    }
}

pub fn rms_norm_row(x: &[f32], g: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / n;
    let inv = 1.0 / (ms + LN_EPS).sqrt();
    for ((o, &v), &gg) in out.iter_mut().zip(x).zip(g) {
        *o = v * inv * gg;
    }
}

/// tanh-approximated GELU (matches `jax.nn.gelu`'s default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// In-place stable softmax.
pub fn softmax(xs: &mut [f32]) {
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

/// Rotary embedding at absolute position `pos`, applied per head over a
/// `(d_model,)` row (mirrors `blocks.rope`: first/second half pairing).
pub fn rope_row(row: &mut [f32], n_heads: usize, head_dim: usize, pos: usize) {
    let half = head_dim / 2;
    let p = pos as f32;
    for h in 0..n_heads {
        let base = h * head_dim;
        for i in 0..half {
            let freq = 10000.0f32.powf(-(i as f32) / half as f32);
            let ang = p * freq;
            let (sin, cos) = ang.sin_cos();
            let x1 = row[base + i];
            let x2 = row[base + half + i];
            row[base + i] = x1 * cos - x2 * sin;
            row[base + half + i] = x1 * sin + x2 * cos;
        }
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Causal multi-head attention for one query row against the window of
/// `limit` cached K/V entries ending at the row's own absolute position
/// `pos` (the newest entry of the window is the row itself). Reads go
/// through page-table translation at absolute token positions — pages are
/// append-only, so later rows of the same step can never disturb an
/// earlier row's window.
#[allow(clippy::too_many_arguments)]
pub fn attend(
    n_heads: usize,
    head_dim: usize,
    q: &[f32],
    cache: &KvCache,
    slot: usize,
    layer: usize,
    pos: usize,
    limit: usize,
    out: &mut [f32],
) {
    debug_assert!(limit >= 1 && limit <= cache.window && limit <= pos + 1);
    let base = pos + 1 - limit;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut scores = vec![0.0f32; limit];
    for h in 0..n_heads {
        let hr = h * head_dim..(h + 1) * head_dim;
        let qh = &q[hr.clone()];
        for (t, s) in scores.iter_mut().enumerate() {
            *s = dot(qh, &cache.k_row(slot, layer, base + t)[hr.clone()]) * scale;
        }
        softmax(&mut scores);
        let oh = &mut out[hr.clone()];
        oh.fill(0.0);
        for (t, &p) in scores.iter().enumerate() {
            let vh = &cache.v_row(slot, layer, base + t)[hr.clone()];
            for (o, &vv) in oh.iter_mut().zip(vh) {
                *o += p * vv;
            }
        }
    }
}

// ----------------------------------------------------------- block layer

/// Per-row decode context: which cache slot the row belongs to, its
/// absolute position (also its KV page-table index), and how many cache
/// entries (window ending at the row itself) its attention may see.
#[derive(Clone, Copy, Debug)]
pub struct RowCtx {
    pub slot: usize,
    pub pos: usize,
    pub limit: usize,
}

fn add_bias(x: &mut [f32], bias: &[f32], m: usize) {
    let d = bias.len();
    for i in 0..m {
        for (v, &b) in x[i * d..(i + 1) * d].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// One transformer block over `m` rows (shared by incremental + full paths).
/// `obs` is the numeric-health observation hook: `(handle, sampled row
/// indices)` — the listed rows' residual-stream input (`x` before the
/// pre-attention norm, the quantity the calibration probe enveloped) is
/// folded into the per-layer live stats. Pure observation: `x` is read
/// before any mutation, so the math below is untouched.
fn layer_forward(
    model: &PackedModel,
    block: &PackedBlock,
    layer: usize,
    x: &mut [f32],
    rows: &[RowCtx],
    cache: &mut KvCache,
    obs: Option<(&NumericHealth, &[usize])>,
) {
    let cfg = &model.cfg;
    let m = rows.len();
    let d = cfg.d_model;
    let opt = cfg.family == "opt";
    if let Some((nh, sampled)) = obs {
        nh.record_rows(layer, x, d, sampled);
    }

    // pre-attention norm
    let mut xn = vec![0.0f32; m * d];
    for i in 0..m {
        let xi = &x[i * d..(i + 1) * d];
        let o = &mut xn[i * d..(i + 1) * d];
        if opt {
            layer_norm_row(xi, block.f32("ln1_g"), block.f32("ln1_b"), o);
        } else {
            rms_norm_row(xi, block.f32("rms1_g"), o);
        }
    }

    // qkv projections (fused packed GEMM)
    let mut q = block.linear("wq").matmul(&xn, m);
    let mut k = block.linear("wk").matmul(&xn, m);
    let mut v = block.linear("wv").matmul(&xn, m);
    if opt {
        add_bias(&mut q, block.f32("bq"), m);
        add_bias(&mut k, block.f32("bk"), m);
        add_bias(&mut v, block.f32("bv"), m);
    }

    // rope + cache write + attention, row by row. Write→attend is
    // interleaved *per row* — exactly the order token-at-a-time stepping
    // produces. Pages are append-only, so no later write can disturb an
    // earlier row's window; the interleave is kept because it is the
    // contract chunked prefill's bit-identity is specified against.
    let mut ctx = vec![0.0f32; m * d];
    for (i, rc) in rows.iter().enumerate() {
        let qrow = &mut q[i * d..(i + 1) * d];
        let krow = &mut k[i * d..(i + 1) * d];
        if !opt {
            rope_row(qrow, cfg.n_heads, cfg.head_dim, rc.pos);
            rope_row(krow, cfg.n_heads, cfg.head_dim, rc.pos);
        }
        cache.write_k(rc.slot, layer, rc.pos, krow);
        cache.write_v(rc.slot, layer, rc.pos, &v[i * d..(i + 1) * d]);
        attend(
            cfg.n_heads,
            cfg.head_dim,
            qrow,
            cache,
            rc.slot,
            layer,
            rc.pos,
            rc.limit,
            &mut ctx[i * d..(i + 1) * d],
        );
    }

    // residual: x += ctx @ wo (+ bo)
    let mut proj = block.linear("wo").matmul(&ctx, m);
    if opt {
        add_bias(&mut proj, block.f32("bo"), m);
    }
    for (xv, &pv) in x.iter_mut().zip(&proj) {
        *xv += pv;
    }

    // MLP
    for i in 0..m {
        let xi = &x[i * d..(i + 1) * d];
        let o = &mut xn[i * d..(i + 1) * d];
        if opt {
            layer_norm_row(xi, block.f32("ln2_g"), block.f32("ln2_b"), o);
        } else {
            rms_norm_row(xi, block.f32("rms2_g"), o);
        }
    }
    let mlp = if opt {
        let mut h = block.linear("w1").matmul(&xn, m);
        add_bias(&mut h, block.f32("b1"), m);
        for v in h.iter_mut() {
            *v = gelu(*v);
        }
        let mut y = block.linear("w2").matmul(&h, m);
        add_bias(&mut y, block.f32("b2"), m);
        y
    } else {
        let hg = block.linear("wg").matmul(&xn, m);
        let hu = block.linear("wu").matmul(&xn, m);
        let h: Vec<f32> = hg.iter().zip(&hu).map(|(&g, &u)| silu(g) * u).collect();
        block.linear("wd").matmul(&h, m)
    };
    for (xv, &mv) in x.iter_mut().zip(&mlp) {
        *xv += mv;
    }
}

fn embed_row(model: &PackedModel, token: i32, pos: usize, out: &mut [f32]) {
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let tok = token as usize;
    assert!(tok < cfg.vocab, "token {token} out of vocab {}", cfg.vocab);
    let emb = model.global("tok_emb");
    out.copy_from_slice(&emb.data[tok * d..(tok + 1) * d]);
    if cfg.family == "opt" {
        assert!(
            pos < cfg.seq,
            "position {pos} exceeds the learned positional table ({}) — \
             the scheduler must cap sequence length for the opt family",
            cfg.seq
        );
        let pe = model.global("pos_emb");
        for (o, &p) in out.iter_mut().zip(&pe.data[pos * d..(pos + 1) * d]) {
            *o += p;
        }
    }
}

/// Final norm + tied-embedding head over `m` rows: `(m, vocab)` logits.
/// `select` (same length as rows) skips rows whose logits nobody reads —
/// prefill rows — leaving them zero; a row's logits never depend on the
/// other rows, so skipping cannot change sampled outputs.
fn head_logits(model: &PackedModel, x: &[f32], m: usize, select: Option<&[bool]>) -> Tensor {
    // the vocab projection is the most expensive per-token stage; sampled
    // telemetry times it without touching the math
    let t0 = crate::telemetry::kernel::sample_start();
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let emb = model.global("tok_emb");
    let mut hf = vec![0.0f32; d];
    let mut out = Tensor::zeros(&[m, cfg.vocab]);
    for i in 0..m {
        if select.is_some_and(|s| !s[i]) {
            continue;
        }
        let xi = &x[i * d..(i + 1) * d];
        if cfg.family == "opt" {
            layer_norm_row(xi, &model.global("lnf_g").data, &model.global("lnf_b").data, &mut hf);
        } else {
            rms_norm_row(xi, &model.global("rmsf_g").data, &mut hf);
        }
        let orow = out.row_mut(i);
        for (vcb, o) in orow.iter_mut().enumerate() {
            *o = dot(&hf, &emb.data[vcb * d..(vcb + 1) * d]);
        }
    }
    crate::telemetry::kernel::record_head(t0);
    out
}

// -------------------------------------------------------------- stepping

/// One decode-step input: feed `token` at absolute `pos` for the sequence
/// living in cache `slot`.
#[derive(Clone, Copy, Debug)]
pub struct StepInput {
    pub slot: usize,
    pub token: i32,
    pub pos: usize,
}

/// Advance the listed sequences; returns `(m, vocab)` logits (row i
/// predicts the token after `inputs[i].token`). A slot may contribute a
/// *chunk* of several rows (chunked prefill) as long as its rows are
/// contiguous with consecutive positions; attention is causal within the
/// chunk.
pub fn step(model: &PackedModel, inputs: &[StepInput], cache: &mut KvCache) -> Tensor {
    step_select(model, inputs, cache, None)
}

/// [`step`] with a per-row logits mask: rows with `need_logits[i] == false`
/// (mid-prefill) still advance the KV cache but skip the vocab head — the
/// most expensive per-token stage for small models.
pub fn step_select(
    model: &PackedModel,
    inputs: &[StepInput],
    cache: &mut KvCache,
    need_logits: Option<&[bool]>,
) -> Tensor {
    step_observed(model, inputs, cache, need_logits, None)
}

/// [`step_select`] with the numeric-health observation hook: when `numeric`
/// is live, 1-in-N rows (the handle's sampling ticket) have their per-layer
/// input activations folded into the live drift statistics. Observation
/// only — the computed logits are bit-identical with the hook on or off
/// (asserted by parity tests).
pub fn step_observed(
    model: &PackedModel,
    inputs: &[StepInput],
    cache: &mut KvCache,
    need_logits: Option<&[bool]>,
    numeric: Option<&NumericHealth>,
) -> Tensor {
    let m = inputs.len();
    assert!(m > 0, "empty step");
    // a slot's rows must form one contiguous run with consecutive
    // positions (a prefill chunk); distinct slots may appear in any order
    debug_assert!(
        (0..m).all(|i| {
            (i + 1..m).all(|j| {
                inputs[i].slot != inputs[j].slot
                    || ((i..j).all(|t| inputs[t].slot == inputs[i].slot)
                        && inputs[j].pos == inputs[i].pos + (j - i))
            })
        }),
        "slot rows must be one contiguous, position-consecutive chunk"
    );
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let mut x = vec![0.0f32; m * d];
    for (i, inp) in inputs.iter().enumerate() {
        embed_row(model, inp.token, inp.pos, &mut x[i * d..(i + 1) * d]);
    }
    // release out-of-window pages at step start only: every row of this
    // step still reads its own trailing window, and freeing mid-chunk
    // could hand a page a not-yet-attended row needs to a later advance
    let mut trimmed: Vec<usize> = Vec::new();
    for inp in inputs {
        if !trimmed.contains(&inp.slot) {
            trimmed.push(inp.slot);
            cache.trim(inp.slot);
        }
    }
    let rows: Vec<RowCtx> = inputs
        .iter()
        .map(|inp| {
            let pos = cache.advance(inp.slot);
            debug_assert_eq!(pos, inp.pos, "scheduler position desynced from the kv page table");
            RowCtx { slot: inp.slot, pos, limit: cache.attn_len(inp.slot) }
        })
        .collect();
    // decide the sampled rows once per step so every layer observes the
    // same rows (keeps per-layer stats aligned); one ticket pull per row
    let sampled: Vec<usize> = match numeric {
        Some(nh) => (0..m).filter(|_| nh.sample()).collect(),
        None => Vec::new(),
    };
    let obs = numeric.filter(|_| !sampled.is_empty()).map(|nh| (nh, sampled.as_slice()));
    for (layer, block) in model.blocks.iter().enumerate() {
        layer_forward(model, block, layer, &mut x, &rows, cache, obs);
    }
    head_logits(model, &x, m, need_logits)
}

/// Hidden states (pre-final-norm) of a whole-context forward — the
/// quantity `runtime::block_fp` chains produce; used by the PJRT parity
/// exhibit. Allocates its own KV arena sized to the sequence.
pub fn hidden_full(model: &PackedModel, tokens: &[i32]) -> Tensor {
    let s_len = tokens.len();
    assert!(s_len > 0, "empty sequence");
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let mut cache = KvCache::new(1, cfg.n_layers, s_len, d);
    let mut x = vec![0.0f32; s_len * d];
    let rows: Vec<RowCtx> = (0..s_len)
        .map(|i| {
            embed_row(model, tokens[i], i, &mut x[i * d..(i + 1) * d]);
            let pos = cache.advance(0);
            RowCtx { slot: 0, pos, limit: i + 1 }
        })
        .collect();
    for (layer, block) in model.blocks.iter().enumerate() {
        layer_forward(model, block, layer, &mut x, &rows, &mut cache, None);
    }
    Tensor::new(vec![s_len, d], x)
}

/// Per-layer streaming stats of the residual-stream *input* of every block
/// over a whole-context forward of `tokens` — the pack-time calibration
/// pass (`PackedModel::bake_calibration`). Same quantity the serving-time
/// observation hook samples, so envelope and live stats are comparable.
pub fn layer_input_stats(model: &PackedModel, tokens: &[i32]) -> Vec<Welford> {
    let s_len = tokens.len();
    assert!(s_len > 0, "empty calibration probe");
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let mut cache = KvCache::new(1, cfg.n_layers, s_len, d);
    let mut x = vec![0.0f32; s_len * d];
    let rows: Vec<RowCtx> = (0..s_len)
        .map(|i| {
            embed_row(model, tokens[i], i, &mut x[i * d..(i + 1) * d]);
            let pos = cache.advance(0);
            RowCtx { slot: 0, pos, limit: i + 1 }
        })
        .collect();
    let mut stats = vec![Welford::default(); model.blocks.len()];
    for (layer, block) in model.blocks.iter().enumerate() {
        // x holds the input to `layer` right here (layer_forward mutates it
        // into the layer's output in place)
        for &v in x.iter() {
            stats[layer].push(v);
        }
        layer_forward(model, block, layer, &mut x, &rows, &mut cache, None);
    }
    stats
}

/// Whole-context reference forward for one sequence: `(S, vocab)` logits
/// with causal attention, computed through the exact per-row code `step`
/// uses.
pub fn forward_full(model: &PackedModel, tokens: &[i32]) -> Tensor {
    let h = hidden_full(model, tokens);
    head_logits(model, &h.data, tokens.len(), None)
}

/// Sliding-window reference forward: like [`forward_full`] but row `i`
/// attends only to the last `min(i + 1, window)` tokens at every layer —
/// the semantics a window-`window` KV cache converges to past capacity.
/// Retains the whole sequence (its own cache window is `s_len`, so nothing
/// is ever trimmed) and limits attention per row instead, making it an
/// *independent* implementation of the eviction behaviour the paged cache
/// produces; `rust/tests/engine.rs` pits the two against each other.
pub fn forward_window(model: &PackedModel, tokens: &[i32], window: usize) -> Tensor {
    let s_len = tokens.len();
    assert!(s_len > 0, "empty sequence");
    assert!(window >= 1, "zero attention window");
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let mut cache = KvCache::new(1, cfg.n_layers, s_len, d);
    let mut x = vec![0.0f32; s_len * d];
    let rows: Vec<RowCtx> = (0..s_len)
        .map(|i| {
            embed_row(model, tokens[i], i, &mut x[i * d..(i + 1) * d]);
            let pos = cache.advance(0);
            RowCtx { slot: 0, pos, limit: (i + 1).min(window) }
        })
        .collect();
    for (layer, block) in model.blocks.iter().enumerate() {
        layer_forward(model, block, layer, &mut x, &rows, &mut cache, None);
    }
    head_logits(model, &x, s_len, None)
}

// ----------------------------------------------------- divergence probing

/// Result of one cross-bit-width divergence probe: how far a lower-bit
/// draft variant diverges from the serving model on the same token window.
#[derive(Clone, Debug)]
pub struct DivergenceProbe {
    /// Greedy top-1 tokens of each variant for the window's last position.
    pub top1_serve: i32,
    pub top1_draft: i32,
    /// `top1_serve == top1_draft` — the speculative-decoding acceptance
    /// proxy for this probe.
    pub agree: bool,
    /// Max |logit delta| over the vocab at the last position.
    pub max_logit_delta: f32,
    /// Max hidden-state |delta| of the last position's per-layer outputs,
    /// folded into `groups` consecutive layer groups.
    pub group_delta: Vec<f32>,
}

/// Run `tokens` through both models with self-contained scratch KV caches
/// and compare the last position: per-layer hidden deltas (grouped) and
/// final logits. Pure observation for the serving stack — touches no
/// serving cache, consumes no RNG; both models must share a config.
pub fn probe_divergence(
    serve: &PackedModel,
    draft: &PackedModel,
    tokens: &[i32],
    groups: usize,
) -> DivergenceProbe {
    assert_eq!(serve.cfg.n_layers, draft.cfg.n_layers, "probe needs same-depth variants");
    assert_eq!(serve.cfg.d_model, draft.cfg.d_model, "probe needs same-width variants");
    let (h_s, logit_s) = trace_last(serve, tokens);
    let (h_d, logit_d) = trace_last(draft, tokens);
    let n_layers = serve.cfg.n_layers;
    let g = groups.clamp(1, n_layers);
    let mut group_delta = vec![0f32; g];
    for l in 0..n_layers {
        let gi = l * g / n_layers;
        let delta = h_s[l]
            .iter()
            .zip(&h_d[l])
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        group_delta[gi] = group_delta[gi].max(delta);
    }
    let top1_serve = argmax(&logit_s);
    let top1_draft = argmax(&logit_d);
    let max_logit_delta =
        logit_s.iter().zip(&logit_d).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    DivergenceProbe {
        top1_serve,
        top1_draft,
        agree: top1_serve == top1_draft,
        max_logit_delta,
        group_delta,
    }
}

/// Whole-window forward capturing the last row's hidden state after every
/// layer, plus its final logits (vocab head on that row only).
fn trace_last(model: &PackedModel, tokens: &[i32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    let s_len = tokens.len();
    assert!(s_len > 0, "empty probe window");
    let cfg = &model.cfg;
    let d = cfg.d_model;
    let mut cache = KvCache::new(1, cfg.n_layers, s_len, d);
    let mut x = vec![0.0f32; s_len * d];
    let rows: Vec<RowCtx> = (0..s_len)
        .map(|i| {
            embed_row(model, tokens[i], i, &mut x[i * d..(i + 1) * d]);
            let pos = cache.advance(0);
            RowCtx { slot: 0, pos, limit: i + 1 }
        })
        .collect();
    let mut trace = Vec::with_capacity(model.blocks.len());
    for (layer, block) in model.blocks.iter().enumerate() {
        layer_forward(model, block, layer, &mut x, &rows, &mut cache, None);
        trace.push(x[(s_len - 1) * d..s_len * d].to_vec());
    }
    let mut select = vec![false; s_len];
    select[s_len - 1] = true;
    let logits = head_logits(model, &x, s_len, Some(&select));
    (trace, logits.row(s_len - 1).to_vec())
}

// -------------------------------------------------------------- sampling

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampler {
    /// Argmax, lowest index on ties — fully deterministic.
    Greedy,
    /// Sample among the `k` highest logits at `temperature`.
    TopK { k: usize, temperature: f32 },
}

pub fn sample_row(logits: &[f32], sampler: Sampler, rng: &mut Pcg32) -> i32 {
    match sampler {
        Sampler::Greedy => argmax(logits),
        Sampler::TopK { k, temperature } => {
            if k <= 1 || temperature <= 0.0 {
                return argmax(logits);
            }
            let k = k.min(logits.len());
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            idx.truncate(k);
            let mx = logits[idx[0]];
            let weights: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / temperature) as f64).exp())
                .collect();
            idx[rng.weighted(&weights)] as i32
        }
    }
}

pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0f32, 2.0, 3.0];
        softmax(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn norms_match_semantics() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        let mut o = vec![0.0f32; 4];
        layer_norm_row(&x, &g, &b, &mut o);
        assert!(o.iter().sum::<f32>().abs() < 1e-5, "{o:?}");
        let mut r = vec![0.0f32; 4];
        rms_norm_row(&x, &g, &mut r);
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((r[0] - 1.0 / (ms + LN_EPS).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm_and_pos0_is_identity() {
        let mut row: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let orig = row.clone();
        rope_row(&mut row, 2, 16, 0);
        assert_eq!(row, orig, "pos 0 must be identity");
        rope_row(&mut row, 2, 16, 17);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = row.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4, "rotation must preserve norm");
        assert_ne!(row, orig);
    }

    #[test]
    fn sampler_greedy_and_topk() {
        let logits = vec![0.1f32, 3.0, 2.9, -1.0];
        let mut rng = Pcg32::seeded(4);
        assert_eq!(sample_row(&logits, Sampler::Greedy, &mut rng), 1);
        // top-2 sampling only ever returns the top-2 indices
        for _ in 0..100 {
            let t = sample_row(&logits, Sampler::TopK { k: 2, temperature: 0.8 }, &mut rng);
            assert!(t == 1 || t == 2, "{t}");
        }
        // temperature 0 falls back to greedy
        assert_eq!(
            sample_row(&logits, Sampler::TopK { k: 3, temperature: 0.0 }, &mut rng),
            1
        );
    }
}
