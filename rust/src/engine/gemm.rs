//! Fused unpack→dequant→matmul microkernels over bit-packed weight codes.
//!
//! The deployment format stores a row-major `(din, dout)` weight as b-bit
//! little-endian codes (`quant::pack_bits` layout) plus per-(group, col)
//! f16 scale/zero-point. The GEMM never materializes the f32 weight matrix:
//! it streams one code row at a time through a small per-stripe buffer
//! (unpack → dequant → FMA into all `m` output rows), so the working set is
//! `O(stripe_width)` and the dequant cost is amortized over the batch.
//!
//! Threading: output columns are split into stripes, one scoped
//! `std::thread` worker per stripe; each worker owns a private partial
//! buffer that is copied into `y` after join. Every `y[i][j]` is accumulated
//! serially over `k` in ascending order inside exactly one worker, so
//! results are **bit-identical for any m, any thread count, and any stripe
//! partition** — the property the engine's "incremental decode == full
//! forward" guarantee rests on.

use crate::tensor::num_threads;

/// Unpack `out.len()` consecutive b-bit codes starting at element index
/// `start` of a `pack_bits`-packed stream. Mirrors `quant::unpack_bits` but
/// allows an arbitrary element offset so column stripes can decode only
/// their slice of each code row.
#[inline]
pub fn unpack_seg(packed: &[u8], bits: u32, start: usize, out: &mut [u8]) {
    debug_assert!(bits >= 1 && bits <= 8);
    let mask = ((1u16 << bits) - 1) as u8;
    let mut bitpos = start * bits as usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        *o = v & mask;
        bitpos += bits as usize;
    }
}

/// Arguments shared by the packed kernels: one quantized `(din, dout)`
/// weight in deployment form. `scales`/`zps` are the f16-decoded per-(group,
/// col) parameters, row-major `(din/group_len, dout)`.
#[derive(Clone, Copy)]
pub struct PackedWeight<'a> {
    pub packed: &'a [u8],
    pub bits: u32,
    pub din: usize,
    pub dout: usize,
    pub group_len: usize,
    pub scales: &'a [f32],
    pub zps: &'a [f32],
}

impl<'a> PackedWeight<'a> {
    fn check(&self) {
        debug_assert_eq!(self.din % self.group_len, 0);
        debug_assert_eq!(self.scales.len(), (self.din / self.group_len) * self.dout);
        debug_assert_eq!(self.zps.len(), self.scales.len());
        debug_assert!(self.packed.len() * 8 >= self.din * self.dout * self.bits as usize);
    }
}

/// `y (m, dout) += x (m, din) @ dequant(W)`. `y` must be pre-zeroed by the
/// caller if `+=` semantics are not wanted.
pub fn packed_gemm(w: &PackedWeight, x: &[f32], y: &mut [f32], m: usize) {
    w.check();
    assert_eq!(x.len(), m * w.din, "x len vs (m={m}, din={})", w.din);
    assert_eq!(y.len(), m * w.dout, "y len vs (m={m}, dout={})", w.dout);
    let stripes = plan_stripes(m, w.din, w.dout);
    if stripes.len() <= 1 {
        let mut part = vec![0.0f32; m * w.dout];
        gemm_stripe(w, x, m, 0, w.dout, &mut part);
        for (yv, pv) in y.iter_mut().zip(&part) {
            *yv += pv;
        }
        return;
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = stripes
            .iter()
            .map(|&(j0, j1)| {
                scope.spawn(move || {
                    let mut part = vec![0.0f32; m * (j1 - j0)];
                    gemm_stripe(w, x, m, j0, j1, &mut part);
                    part
                })
            })
            .collect();
        for (h, &(j0, j1)) in handles.into_iter().zip(&stripes) {
            let part = h.join().expect("gemm worker panicked");
            let bw = j1 - j0;
            for i in 0..m {
                let dst = &mut y[i * w.dout + j0..i * w.dout + j1];
                let src = &part[i * bw..(i + 1) * bw];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
    });
}

/// Column-stripe partition: one stripe per worker, stripes at least 32
/// columns wide, single stripe for small problems (threading overhead).
fn plan_stripes(m: usize, din: usize, dout: usize) -> Vec<(usize, usize)> {
    let work = m * din * dout;
    let threads = if work < 32 * 128 * 128 { 1 } else { num_threads() };
    let n = threads.clamp(1, dout.div_ceil(32));
    let chunk = dout.div_ceil(n);
    let mut out = Vec::with_capacity(n);
    let mut j = 0;
    while j < dout {
        let hi = (j + chunk).min(dout);
        out.push((j, hi));
        j = hi;
    }
    out
}

/// Serial kernel over columns `[j0, j1)`: stream code rows, dequant into a
/// stripe-wide buffer, FMA into each of the `m` partial rows.
fn gemm_stripe(w: &PackedWeight, x: &[f32], m: usize, j0: usize, j1: usize, part: &mut [f32]) {
    let bw = j1 - j0;
    let mut crow = vec![0u8; bw];
    let mut wrow = vec![0.0f32; bw];
    for k in 0..w.din {
        let gi = k / w.group_len;
        unpack_seg(w.packed, w.bits, k * w.dout + j0, &mut crow);
        let sc = &w.scales[gi * w.dout + j0..gi * w.dout + j1];
        let zp = &w.zps[gi * w.dout + j0..gi * w.dout + j1];
        for j in 0..bw {
            wrow[j] = (crow[j] as f32 - zp[j]) * sc[j];
        }
        for i in 0..m {
            let a = x[i * w.din + k];
            if a != 0.0 {
                let prow = &mut part[i * bw..(i + 1) * bw];
                for (p, &wv) in prow.iter_mut().zip(&wrow) {
                    *p += a * wv;
                }
            }
        }
    }
}

/// Group-factored fused matvec: `y (dout) += x (din) @ dequant(W)` computed
/// as `Σ_g s_gj ((Σ_r x_r c_rj) - z_gj Σ_r x_r)` — one FMA per code instead
/// of dequant+FMA. Fastest single-row kernel (batch-1 decode microbench),
/// but a *different accumulation order* than [`packed_gemm`], so the engine
/// forward does not use it by default (bit-stability across batch sizes
/// wins); it is exercised by `perf_engine` and available for opt-in.
pub fn packed_matvec_grouped(w: &PackedWeight, x: &[f32], y: &mut [f32]) {
    w.check();
    assert_eq!(x.len(), w.din);
    assert_eq!(y.len(), w.dout);
    let stripes = plan_stripes(1, w.din, w.dout);
    let run = |j0: usize, j1: usize, part: &mut [f32]| {
        debug_assert_eq!(part.len(), j1 - j0);
        let bw = j1 - j0;
        let mut crow = vec![0u8; bw];
        let mut acc = vec![0.0f32; bw];
        let ngroups = w.din / w.group_len;
        for gi in 0..ngroups {
            acc.iter_mut().for_each(|a| *a = 0.0);
            let mut sx = 0.0f32;
            for r in 0..w.group_len {
                let k = gi * w.group_len + r;
                let a = x[k];
                sx += a;
                if a != 0.0 {
                    unpack_seg(w.packed, w.bits, k * w.dout + j0, &mut crow);
                    for (av, &c) in acc.iter_mut().zip(crow.iter()) {
                        *av += a * c as f32;
                    }
                }
            }
            let sc = &w.scales[gi * w.dout + j0..gi * w.dout + j1];
            let zp = &w.zps[gi * w.dout + j0..gi * w.dout + j1];
            for j in 0..bw {
                part[j] += sc[j] * (acc[j] - zp[j] * sx);
            }
        }
    };
    if stripes.len() <= 1 {
        let mut part = vec![0.0f32; w.dout];
        run(0, w.dout, &mut part);
        for (yv, pv) in y.iter_mut().zip(&part) {
            *yv += pv;
        }
        return;
    }
    std::thread::scope(|scope| {
        let run_ref = &run;
        let handles: Vec<_> = stripes
            .iter()
            .map(|&(j0, j1)| {
                scope.spawn(move || {
                    let mut part = vec![0.0f32; j1 - j0];
                    run_ref(j0, j1, &mut part);
                    part
                })
            })
            .collect();
        for (h, &(j0, j1)) in handles.into_iter().zip(&stripes) {
            let part = h.join().expect("matvec worker panicked");
            for (yv, pv) in y[j0..j1].iter_mut().zip(&part) {
                *yv += pv;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{pack_bits, unpack_bits};
    use crate::rngx::Pcg32;

    #[test]
    fn unpack_seg_matches_full_unpack() {
        let mut rng = Pcg32::seeded(1);
        for bits in [2u32, 3, 4, 8] {
            let n = 257;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            let full = unpack_bits(&packed, bits, n);
            assert_eq!(full, codes);
            for &(s, l) in &[(0usize, 7usize), (1, 16), (13, 64), (255, 2), (256, 1), (100, 0)] {
                let mut out = vec![0u8; l];
                unpack_seg(&packed, bits, s, &mut out);
                assert_eq!(&out[..], &codes[s..s + l], "bits={bits} start={s}");
            }
        }
    }

    fn toy_weight(
        din: usize,
        dout: usize,
        bits: u32,
        group_len: usize,
        rng: &mut Pcg32,
    ) -> (Vec<u8>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let codes: Vec<u8> = (0..din * dout).map(|_| rng.below(1 << bits) as u8).collect();
        let ngroups = din / group_len;
        let scales: Vec<f32> =
            (0..ngroups * dout).map(|_| 0.01 + rng.uniform() as f32).collect();
        let zps: Vec<f32> =
            (0..ngroups * dout).map(|_| rng.below(1 << bits) as f32).collect();
        // dense reference weight
        let mut dense = vec![0.0f32; din * dout];
        for k in 0..din {
            for j in 0..dout {
                let gi = k / group_len;
                dense[k * dout + j] =
                    (codes[k * dout + j] as f32 - zps[gi * dout + j]) * scales[gi * dout + j];
            }
        }
        (pack_bits(&codes, bits), scales, zps, dense)
    }

    #[test]
    fn gemm_matches_dense_reference() {
        let mut rng = Pcg32::seeded(2);
        for (din, dout, bits, g, m) in
            [(64, 48, 4u32, 16usize, 3usize), (96, 33, 3, 32, 1), (128, 64, 2, 64, 5)]
        {
            let (packed, scales, zps, dense) = toy_weight(din, dout, bits, g, &mut rng);
            let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
            let w = PackedWeight {
                packed: &packed,
                bits,
                din,
                dout,
                group_len: g,
                scales: &scales,
                zps: &zps,
            };
            let mut y = vec![0.0f32; m * dout];
            packed_gemm(&w, &x, &mut y, m);
            for i in 0..m {
                for j in 0..dout {
                    let mut want = 0.0f32;
                    for k in 0..din {
                        want += x[i * din + k] * dense[k * dout + j];
                    }
                    let got = y[i * dout + j];
                    assert!(
                        (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                        "({din},{dout},b{bits},g{g}) y[{i}][{j}] {got} vs {want}"
                    );
                }
            }
            // matvec kernel agrees row-by-row (to fp tolerance)
            for i in 0..m {
                let mut yv = vec![0.0f32; dout];
                packed_matvec_grouped(&w, &x[i * din..(i + 1) * din], &mut yv);
                for j in 0..dout {
                    let want = y[i * dout + j];
                    assert!(
                        (yv[j] - want).abs() < 1e-2 * (1.0 + want.abs()),
                        "matvec row {i} col {j}: {} vs {want}",
                        yv[j]
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_rows_independent_of_batch() {
        // the bit-stability contract: a row's output is identical whether it
        // is computed alone (m=1) or inside a batch (m=16)
        let mut rng = Pcg32::seeded(3);
        let (din, dout, bits, g) = (256, 96, 4u32, 64usize);
        let (packed, scales, zps, _) = toy_weight(din, dout, bits, g, &mut rng);
        let w = PackedWeight {
            packed: &packed,
            bits,
            din,
            dout,
            group_len: g,
            scales: &scales,
            zps: &zps,
        };
        let m = 16;
        let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; m * dout];
        packed_gemm(&w, &x, &mut y, m);
        for i in 0..m {
            let mut yi = vec![0.0f32; dout];
            packed_gemm(&w, &x[i * din..(i + 1) * din], &mut yi, 1);
            assert_eq!(&y[i * dout..(i + 1) * dout], &yi[..], "row {i} differs from batch");
        }
    }
}
