//! Fused unpack→dequant→matmul microkernels over bit-packed weight codes.
//!
//! The deployment format stores a row-major `(din, dout)` weight as b-bit
//! little-endian codes (`quant::pack_bits` layout) plus per-(group, col)
//! f16 scale/zero-point. The GEMM never materializes the f32 weight matrix:
//! it streams one code row at a time through a small per-stripe buffer
//! (unpack → dequant → FMA into all `m` output rows), so the working set is
//! `O(stripe_width)` and the dequant cost is amortized over the batch.
//!
//! Threading: output columns are split into SIMD-width-aligned stripes
//! (widths a multiple of [`STRIPE_ALIGN`], i.e. `2 ×` [`SIMD_LANES`] f32
//! lanes, except the merged ragged tail — `plan_stripes` debug-asserts
//! those invariants for every `dout`), at least one stripe per core when
//! the column count permits. A pool of scoped `std::thread` workers drains
//! the stripes in a static round-robin; each stripe's partial buffer is
//! computed privately and copied into `y` after join. Every `y[i][j]` is
//! accumulated serially over `k` in ascending order inside exactly one
//! stripe, and the inner FMA is unrolled [`SIMD_LANES`] wide over *columns*
//! only (each column keeps its own accumulation chain), so results are
//! **bit-identical for any m, any thread count, and any stripe partition**
//! — the property the engine's "incremental decode == full forward"
//! guarantee rests on.
//!
//! The stripe inner loop itself lives in [`super::kernels`]: it is
//! monomorphized per `(bits, group)` and stamped into per-ISA
//! `#[target_feature]` entry points selected once per model load by CPU
//! feature detection. [`packed_gemm_with`] runs an explicit kernel (what
//! `PackedLinear` resolved at pack/load time); bare [`packed_gemm`]
//! resolves the process-wide selection per call. Every kernel variant
//! executes the same arithmetic in the same order, so the bit-identity
//! contract above holds across variants too.

use super::kernels::{self, Kernel};
use crate::tensor::num_threads;

/// f32 lanes the inner FMA/dequant loops are unrolled for — one 256-bit
/// vector register (AVX2/NEON-pair safe default for LLVM auto-vectorization).
pub const SIMD_LANES: usize = 8;

/// Stripe-width granularity: two f32 vectors, so a stripe's hot loop always
/// has a pair of independent lanes in flight. Stripe widths are multiples
/// of this (the last stripe absorbs the ragged tail).
pub const STRIPE_ALIGN: usize = 2 * SIMD_LANES;

/// Preferred stripe width in columns: big enough to amortize the per-row
/// unpack, small enough that `stripe × m` partials stay cache-resident and
/// there are several stripes per core to balance.
const STRIPE_WIDTH: usize = 64;

/// Unpack `out.len()` consecutive b-bit codes starting at element index
/// `start` of a `pack_bits`-packed stream. Mirrors `quant::unpack_bits` but
/// allows an arbitrary element offset so column stripes can decode only
/// their slice of each code row.
#[inline]
pub fn unpack_seg(packed: &[u8], bits: u32, start: usize, out: &mut [u8]) {
    debug_assert!(bits >= 1 && bits <= 8);
    let mask = ((1u16 << bits) - 1) as u8;
    let mut bitpos = start * bits as usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + bits as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        *o = v & mask;
        bitpos += bits as usize;
    }
}

/// Arguments shared by the packed kernels: one quantized `(din, dout)`
/// weight in deployment form. `scales`/`zps` are the f16-decoded per-(group,
/// col) parameters, row-major `(din/group_len, dout)`.
#[derive(Clone, Copy)]
pub struct PackedWeight<'a> {
    pub packed: &'a [u8],
    pub bits: u32,
    pub din: usize,
    pub dout: usize,
    pub group_len: usize,
    pub scales: &'a [f32],
    pub zps: &'a [f32],
}

impl PackedWeight<'_> {
    fn check(&self) {
        debug_assert_eq!(self.din % self.group_len, 0);
        debug_assert_eq!(self.scales.len(), (self.din / self.group_len) * self.dout);
        debug_assert_eq!(self.zps.len(), self.scales.len());
        debug_assert!(self.packed.len() * 8 >= self.din * self.dout * self.bits as usize);
    }
}

/// `y (m, dout) += x (m, din) @ dequant(W)`. `y` must be pre-zeroed by the
/// caller if `+=` semantics are not wanted. Resolves the process-wide
/// kernel selection per call; hot paths holding a `PackedLinear` go through
/// [`packed_gemm_with`] with the kernel resolved once at pack/load.
pub fn packed_gemm(w: &PackedWeight, x: &[f32], y: &mut [f32], m: usize) {
    packed_gemm_with(kernels::select(w.bits, w.group_len), w, x, y, m)
}

/// [`packed_gemm`] through an explicit dispatch kernel (see
/// [`super::kernels`]). The kernel only changes which ISA executes the
/// stripe loop — outputs are bit-identical across every variant.
pub fn packed_gemm_with(kernel: Kernel, w: &PackedWeight, x: &[f32], y: &mut [f32], m: usize) {
    w.check();
    assert_eq!(x.len(), m * w.din, "x len vs (m={m}, din={})", w.din);
    assert_eq!(y.len(), m * w.dout, "y len vs (m={m}, dout={})", w.dout);
    // sampled kernel telemetry: observes wall time only, never the math
    let t0 = crate::telemetry::kernel::sample_start();
    let stripes = plan_stripes(m, w.din, w.dout);
    run_stripes(
        &stripes,
        m,
        |j0, j1, part| kernel.run(w, x, m, j0, j1, part),
        |j0, j1, part| {
            let bw = j1 - j0;
            for i in 0..m {
                let dst = &mut y[i * w.dout + j0..i * w.dout + j1];
                let src = &part[i * bw..(i + 1) * bw];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        },
    );
    crate::telemetry::kernel::record_gemm(w.bits, t0);
}

/// Workers for a stripe plan: one per stripe up to the core count; serial
/// when the plan is a single stripe (threading overhead dominates).
fn worker_count(stripes: &[(usize, usize)]) -> usize {
    if stripes.len() <= 1 {
        1
    } else {
        num_threads().min(stripes.len())
    }
}

/// Shared stripe driver: run `kernel(j0, j1, part)` for every stripe —
/// serially for single-stripe plans, otherwise on a pool of scoped workers
/// draining stripes in a static round-robin (worker `wid` owns stripes
/// `wid, wid + workers, …` — deterministic, but irrelevant to the result:
/// each stripe is self-contained) — then hand each finished partial to
/// `fold(j0, j1, part)` on the calling thread. `rows` scales the partial
/// buffer (`rows × stripe_width`).
fn run_stripes<K, F>(stripes: &[(usize, usize)], rows: usize, kernel: K, mut fold: F)
where
    K: Fn(usize, usize, &mut [f32]) + Sync,
    F: FnMut(usize, usize, &[f32]),
{
    let workers = worker_count(stripes);
    if workers <= 1 {
        let mut part = Vec::new();
        for &(j0, j1) in stripes {
            part.clear();
            part.resize(rows * (j1 - j0), 0.0);
            kernel(j0, j1, &mut part);
            fold(j0, j1, &part);
        }
        return;
    }
    std::thread::scope(|scope| {
        let kernel = &kernel;
        let handles: Vec<_> = (0..workers)
            .map(|wid| {
                scope.spawn(move || {
                    let mut parts = Vec::new();
                    let mut si = wid;
                    while si < stripes.len() {
                        let (j0, j1) = stripes[si];
                        let mut part = vec![0.0f32; rows * (j1 - j0)];
                        kernel(j0, j1, &mut part);
                        parts.push((si, part));
                        si += workers;
                    }
                    parts
                })
            })
            .collect();
        for h in handles {
            for (si, part) in h.join().expect("stripe worker panicked") {
                let (j0, j1) = stripes[si];
                fold(j0, j1, &part);
            }
        }
    });
}

/// Column-stripe partition. Stripe widths default to [`STRIPE_WIDTH`]
/// columns, shrinking in [`STRIPE_ALIGN`] multiples when that would leave
/// cores idle (stripe count < core count for mid-size `dout`); every
/// boundary sits on a [`STRIPE_ALIGN`] lane edge and the last stripe
/// absorbs the ragged tail. Stripe count stays decoupled from the worker
/// count — workers drain the stripe queue round-robin — and because every
/// stripe is self-contained the partition can never change the results,
/// only the load balance.
fn plan_stripes(m: usize, din: usize, dout: usize) -> Vec<(usize, usize)> {
    let work = m * din * dout;
    if work < 32 * 128 * 128 || dout < 2 * STRIPE_ALIGN {
        let plan = vec![(0, dout)];
        debug_check_plan(&plan, dout);
        return plan;
    }
    let threads = num_threads();
    let mut width = STRIPE_WIDTH;
    while width > STRIPE_ALIGN && dout / width < threads {
        width -= STRIPE_ALIGN;
    }
    let mut out = Vec::with_capacity(dout.div_ceil(width));
    let mut j = 0;
    while j < dout {
        let mut hi = (j + width).min(dout);
        // leave no tail narrower than one lane group: merge it into the
        // final stripe instead
        if dout - hi < STRIPE_ALIGN {
            hi = dout;
        }
        out.push((j, hi));
        j = hi;
    }
    debug_check_plan(&out, dout);
    out
}

/// Debug-only plan invariants: gap-free coverage of `[0, dout)`, every
/// stripe start on the [`STRIPE_ALIGN`] lane grid, and every stripe width a
/// [`STRIPE_ALIGN`] multiple except the final one (which absorbs the merged
/// ragged tail). Holds for every `dout`, including the single-stripe fast
/// path.
fn debug_check_plan(plan: &[(usize, usize)], dout: usize) {
    if !cfg!(debug_assertions) || dout == 0 {
        return;
    }
    debug_assert_eq!(plan.first().map(|s| s.0), Some(0), "plan must start at 0: {plan:?}");
    debug_assert_eq!(plan.last().map(|s| s.1), Some(dout), "plan must cover dout: {plan:?}");
    for w in plan.windows(2) {
        debug_assert_eq!(w[0].1, w[1].0, "stripes must tile without gaps: {plan:?}");
    }
    for (i, &(j0, j1)) in plan.iter().enumerate() {
        debug_assert!(j1 > j0, "empty stripe {i}: {plan:?}");
        debug_assert_eq!(j0 % STRIPE_ALIGN, 0, "stripe {i} start off the lane grid: {plan:?}");
        if i + 1 < plan.len() {
            debug_assert_eq!(
                (j1 - j0) % STRIPE_ALIGN,
                0,
                "interior stripe {i} width off the lane grid: {plan:?}"
            );
        }
    }
}

/// Serial scalar-reference kernel over columns `[j0, j1)`: stream code
/// rows, dequant into a stripe-wide buffer, FMA into each of the `m`
/// partial rows. The loop body now lives in [`super::kernels`] (where it is
/// also monomorphized per `(bits, group)` and stamped into per-ISA entry
/// points); this wrapper is the always-safe runtime-generic form the
/// partition-invariance test compares against.
#[cfg(test)]
fn gemm_stripe(w: &PackedWeight, x: &[f32], m: usize, j0: usize, j1: usize, part: &mut [f32]) {
    kernels::reference(w, x, m, j0, j1, part)
}

/// `out[j] = (codes[j] - zp[j]) * sc[j]`, processed in [`SIMD_LANES`]-wide
/// blocks whose exact trip count lets LLVM drop bounds checks and emit
/// vector code. `#[inline(always)]` so the [`super::kernels`] entry points
/// absorb it under their `#[target_feature]` sets.
#[inline(always)]
pub(crate) fn dequant_row(codes: &[u8], sc: &[f32], zp: &[f32], out: &mut [f32]) {
    let mut o = out.chunks_exact_mut(SIMD_LANES);
    let mut c = codes.chunks_exact(SIMD_LANES);
    let mut s = sc.chunks_exact(SIMD_LANES);
    let mut z = zp.chunks_exact(SIMD_LANES);
    for (((ob, cb), sb), zb) in (&mut o).zip(&mut c).zip(&mut s).zip(&mut z) {
        for (((ov, &cv), &sv), &zv) in ob.iter_mut().zip(cb).zip(sb).zip(zb) {
            *ov = (cv as f32 - zv) * sv;
        }
    }
    let (ob, cb, sb, zb) = (o.into_remainder(), c.remainder(), s.remainder(), z.remainder());
    for (((ov, &cv), &sv), &zv) in ob.iter_mut().zip(cb).zip(sb).zip(zb) {
        *ov = (cv as f32 - zv) * sv;
    }
}

/// `dst[j] += a * src[j]` in [`SIMD_LANES`]-wide blocks. Column-only
/// blocking: each `dst[j]` keeps its private accumulation chain over `k`,
/// so this is bit-identical to the scalar loop. `#[inline(always)]` so the
/// [`super::kernels`] entry points absorb it under their
/// `#[target_feature]` sets.
#[inline(always)]
pub(crate) fn axpy(a: f32, src: &[f32], dst: &mut [f32]) {
    let mut d = dst.chunks_exact_mut(SIMD_LANES);
    let mut s = src.chunks_exact(SIMD_LANES);
    for (db, sb) in (&mut d).zip(&mut s) {
        for (dv, &sv) in db.iter_mut().zip(sb) {
            *dv += a * sv;
        }
    }
    for (dv, &sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dv += a * sv;
    }
}

/// Group-factored fused matvec: `y (dout) += x (din) @ dequant(W)` computed
/// as `Σ_g s_gj ((Σ_r x_r c_rj) - z_gj Σ_r x_r)` — one FMA per code instead
/// of dequant+FMA. Fastest single-row kernel (batch-1 decode microbench),
/// but a *different accumulation order* than [`packed_gemm`], so the engine
/// forward does not use it by default (bit-stability across batch sizes
/// wins); it is exercised by `perf_engine` and available for opt-in.
pub fn packed_matvec_grouped(w: &PackedWeight, x: &[f32], y: &mut [f32]) {
    w.check();
    assert_eq!(x.len(), w.din);
    assert_eq!(y.len(), w.dout);
    let t0 = crate::telemetry::kernel::sample_start();
    let stripes = plan_stripes(1, w.din, w.dout);
    let run = |j0: usize, j1: usize, part: &mut [f32]| {
        debug_assert_eq!(part.len(), j1 - j0);
        let bw = j1 - j0;
        let mut crow = vec![0u8; bw];
        let mut acc = vec![0.0f32; bw];
        let ngroups = w.din / w.group_len;
        for gi in 0..ngroups {
            acc.iter_mut().for_each(|a| *a = 0.0);
            let mut sx = 0.0f32;
            for r in 0..w.group_len {
                let k = gi * w.group_len + r;
                let a = x[k];
                sx += a;
                if a != 0.0 {
                    unpack_seg(w.packed, w.bits, k * w.dout + j0, &mut crow);
                    for (av, &c) in acc.iter_mut().zip(crow.iter()) {
                        *av += a * c as f32;
                    }
                }
            }
            let sc = &w.scales[gi * w.dout + j0..gi * w.dout + j1];
            let zp = &w.zps[gi * w.dout + j0..gi * w.dout + j1];
            for j in 0..bw {
                part[j] += sc[j] * (acc[j] - zp[j] * sx);
            }
        }
    };
    run_stripes(&stripes, 1, run, |j0, j1, part| {
        for (yv, pv) in y[j0..j1].iter_mut().zip(part) {
            *yv += pv;
        }
    });
    crate::telemetry::kernel::record_gemm(w.bits, t0);
}

/// Streaming quantization error of a packed weight against its pre-quant
/// f32 reference: `(sum of squared error, max absolute error)` over all
/// `din × dout` elements, computed row-at-a-time without materializing the
/// dense dequant. Pack time only (calibration baking) — never on the serve
/// path.
pub fn weight_error(w: &PackedWeight, reference: &[f32]) -> (f64, f32) {
    w.check();
    assert_eq!(reference.len(), w.din * w.dout);
    let mut crow = vec![0u8; w.dout];
    let mut sum_sq = 0f64;
    let mut max_abs = 0f32;
    for k in 0..w.din {
        unpack_seg(w.packed, w.bits, k * w.dout, &mut crow);
        let gi = k / w.group_len;
        let sc = &w.scales[gi * w.dout..(gi + 1) * w.dout];
        let zp = &w.zps[gi * w.dout..(gi + 1) * w.dout];
        let rr = &reference[k * w.dout..(k + 1) * w.dout];
        for j in 0..w.dout {
            let dq = (crow[j] as f32 - zp[j]) * sc[j];
            let e = (dq - rr[j]).abs();
            sum_sq += (e as f64) * (e as f64);
            if e > max_abs {
                max_abs = e;
            }
        }
    }
    (sum_sq, max_abs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{pack_bits, unpack_bits};
    use crate::rngx::Pcg32;

    #[test]
    fn unpack_seg_matches_full_unpack() {
        let mut rng = Pcg32::seeded(1);
        for bits in [2u32, 3, 4, 8] {
            let n = 257;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            let full = unpack_bits(&packed, bits, n);
            assert_eq!(full, codes);
            for &(s, l) in &[(0usize, 7usize), (1, 16), (13, 64), (255, 2), (256, 1), (100, 0)] {
                let mut out = vec![0u8; l];
                unpack_seg(&packed, bits, s, &mut out);
                assert_eq!(&out[..], &codes[s..s + l], "bits={bits} start={s}");
            }
        }
    }

    fn toy_weight(
        din: usize,
        dout: usize,
        bits: u32,
        group_len: usize,
        rng: &mut Pcg32,
    ) -> (Vec<u8>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let codes: Vec<u8> = (0..din * dout).map(|_| rng.below(1 << bits) as u8).collect();
        let ngroups = din / group_len;
        let scales: Vec<f32> =
            (0..ngroups * dout).map(|_| 0.01 + rng.uniform() as f32).collect();
        let zps: Vec<f32> =
            (0..ngroups * dout).map(|_| rng.below(1 << bits) as f32).collect();
        // dense reference weight
        let mut dense = vec![0.0f32; din * dout];
        for k in 0..din {
            for j in 0..dout {
                let gi = k / group_len;
                dense[k * dout + j] =
                    (codes[k * dout + j] as f32 - zps[gi * dout + j]) * scales[gi * dout + j];
            }
        }
        (pack_bits(&codes, bits), scales, zps, dense)
    }

    #[test]
    fn gemm_matches_dense_reference() {
        let mut rng = Pcg32::seeded(2);
        for (din, dout, bits, g, m) in
            [(64, 48, 4u32, 16usize, 3usize), (96, 33, 3, 32, 1), (128, 64, 2, 64, 5)]
        {
            let (packed, scales, zps, dense) = toy_weight(din, dout, bits, g, &mut rng);
            let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
            let w = PackedWeight {
                packed: &packed,
                bits,
                din,
                dout,
                group_len: g,
                scales: &scales,
                zps: &zps,
            };
            let mut y = vec![0.0f32; m * dout];
            packed_gemm(&w, &x, &mut y, m);
            for i in 0..m {
                for j in 0..dout {
                    let mut want = 0.0f32;
                    for k in 0..din {
                        want += x[i * din + k] * dense[k * dout + j];
                    }
                    let got = y[i * dout + j];
                    assert!(
                        (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                        "({din},{dout},b{bits},g{g}) y[{i}][{j}] {got} vs {want}"
                    );
                }
            }
            // matvec kernel agrees row-by-row (to fp tolerance)
            for i in 0..m {
                let mut yv = vec![0.0f32; dout];
                packed_matvec_grouped(&w, &x[i * din..(i + 1) * din], &mut yv);
                for j in 0..dout {
                    let want = y[i * dout + j];
                    assert!(
                        (yv[j] - want).abs() < 1e-2 * (1.0 + want.abs()),
                        "matvec row {i} col {j}: {} vs {want}",
                        yv[j]
                    );
                }
            }
        }
    }

    #[test]
    fn stripe_plan_is_lane_aligned_and_covers_dout() {
        for dout in [16usize, 33, 64, 96, 100, 256, 1000, 1024, 4097] {
            // large m*din so the work threshold is passed and striping kicks in
            let plan = plan_stripes(16, 1024, dout);
            assert_eq!(plan.first().unwrap().0, 0);
            assert_eq!(plan.last().unwrap().1, dout);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "stripes must tile without gaps: {plan:?}");
            }
            for (i, &(j0, j1)) in plan.iter().enumerate() {
                assert!(j1 > j0, "empty stripe in {plan:?}");
                assert_eq!(j0 % STRIPE_ALIGN, 0, "stripe {i} start off lane grid: {plan:?}");
                if i + 1 < plan.len() {
                    assert_eq!(
                        (j1 - j0) % STRIPE_ALIGN,
                        0,
                        "interior stripe {i} width off lane grid: {plan:?}"
                    );
                }
            }
            // the partition is machine-independent: same input, same plan
            assert_eq!(plan, plan_stripes(16, 1024, dout));
        }
        // small problems stay serial (single stripe)
        assert_eq!(plan_stripes(1, 64, 48), vec![(0, 48)]);
    }

    #[test]
    fn gemm_bit_identical_across_stripe_partitions() {
        // the threaded multi-stripe path must agree bit-for-bit with one
        // serial full-width stripe — the partition-invariance contract
        let mut rng = Pcg32::seeded(9);
        let (din, dout, bits, g) = (256, 1000, 4u32, 64usize);
        let (packed, scales, zps, _) = toy_weight(din, dout, bits, g, &mut rng);
        let w = PackedWeight {
            packed: &packed,
            bits,
            din,
            dout,
            group_len: g,
            scales: &scales,
            zps: &zps,
        };
        for m in [1usize, 5, 16] {
            let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
            let mut y = vec![0.0f32; m * dout];
            packed_gemm(&w, &x, &mut y, m);
            let mut whole = vec![0.0f32; m * dout];
            gemm_stripe(&w, &x, m, 0, dout, &mut whole);
            assert_eq!(y, whole, "m={m}: striped result differs from one whole-width stripe");
        }
    }

    #[test]
    fn gemm_rows_independent_of_batch() {
        // the bit-stability contract: a row's output is identical whether it
        // is computed alone (m=1) or inside a batch (m=16)
        let mut rng = Pcg32::seeded(3);
        let (din, dout, bits, g) = (256, 96, 4u32, 64usize);
        let (packed, scales, zps, _) = toy_weight(din, dout, bits, g, &mut rng);
        let w = PackedWeight {
            packed: &packed,
            bits,
            din,
            dout,
            group_len: g,
            scales: &scales,
            zps: &zps,
        };
        let m = 16;
        let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; m * dout];
        packed_gemm(&w, &x, &mut y, m);
        for i in 0..m {
            let mut yi = vec![0.0f32; dout];
            packed_gemm(&w, &x[i * din..(i + 1) * din], &mut yi, 1);
            assert_eq!(&y[i * dout..(i + 1) * dout], &yi[..], "row {i} differs from batch");
        }
    }
}
