//! Runtime-specialized GEMM stripe kernels.
//!
//! The fused unpack→dequant→FMA stripe loop in [`super::gemm`] is the hot
//! path under every decode tick. This module monomorphizes that inner loop
//! per `(bit_width, group_size)` via const generics — the unpacker collapses
//! to the fixed codes-per-byte layout of w2/w4/w8 (w3 const-folds the
//! generic shifter), and `k / group_len` becomes a shift for the common
//! group sizes — and stamps each specialization into per-ISA entry points:
//!
//! | variant  | target features    | compiled when                         |
//! |----------|--------------------|---------------------------------------|
//! | `scalar` | none (baseline)    | always                                |
//! | `avx2`   | avx2               | `x86_64`                              |
//! | `avx512` | avx512f + avx512bw | `x86_64` + `avx512` cargo feature     |
//! | `neon`   | neon               | `aarch64`                             |
//!
//! Selection happens once per `PackedLinear` at pack/load time:
//! `--kernel` CLI override > `AQ_KERNEL` env > auto (best variant whose CPU
//! features runtime detection confirms, preferring avx512 > avx2 > neon >
//! scalar). An explicit request for an unavailable variant falls back to
//! auto and the fallback is surfaced in [`KernelInfo`] (`/v1/stats`, the
//! `aq_kernel_info` metric, and the `doctor` exhibit all report it).
//!
//! **Bit-stability.** Every entry point runs the *same* Rust loop body —
//! `#[target_feature]` only widens the instruction selection LLVM may use
//! to vectorize it. rustc never contracts separate mul+add into FMA, the
//! unpackers produce identical code bytes, and the dequant/FMA helpers
//! block over *columns* only (each output column keeps its own f32
//! accumulation chain over ascending `k`), so every variant is
//! **bit-identical** to the scalar reference — the engine's greedy outputs
//! do not depend on the selected kernel, the thread count, or the stripe
//! partition. A property test in `rust/tests/engine.rs` asserts this across
//! all compiled variants × bit-widths × group sizes × ragged tails.
//!
//! Safety model: specialized entries are `unsafe fn` (calling one on a CPU
//! without the ISA is undefined behavior). They are reachable only through
//! [`Kernel::run`], and [`select_for`] hands out an entry only after
//! `is_x86_feature_detected!`/`is_aarch64_feature_detected!` confirms the
//! features (scalar otherwise), which makes the call sound. Generic
//! functions cannot carry `#[target_feature]`, so the const-generic body is
//! `#[inline(always)]` and the macro stamps concrete wrappers around it —
//! the body inlines into the wrapper and inherits its feature set.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::gemm::{axpy, dequant_row, unpack_seg, PackedWeight};

/// Signature shared by every stripe entry point: accumulate
/// `part (m, j1-j0) += x (m, din) @ dequant(W[:, j0..j1])`.
///
/// `unsafe`: specialized entries require their ISA to be present; call
/// through [`Kernel::run`], never directly.
pub type StripeFn = for<'w, 'p, 'x, 'o> unsafe fn(
    &'w PackedWeight<'p>,
    &'x [f32],
    usize,
    usize,
    usize,
    &'o mut [f32],
);

// ------------------------------------------------------------------ body

/// The one stripe loop, monomorphized by the const parameters. `BITS == 0`
/// / `GROUP == 0` mean "read the runtime value from the weight" (the
/// generic fallback entries); nonzero consts must match the weight and
/// let the compiler specialize the unpacker and the group division.
#[inline(always)]
fn stripe_body<const BITS: u32, const GROUP: usize>(
    w: &PackedWeight<'_>,
    x: &[f32],
    m: usize,
    j0: usize,
    j1: usize,
    part: &mut [f32],
) {
    let bits = if BITS == 0 { w.bits } else { BITS };
    let group_len = if GROUP == 0 { w.group_len } else { GROUP };
    debug_assert_eq!(bits, w.bits, "kernel monomorphized for other bits");
    debug_assert_eq!(group_len, w.group_len, "kernel monomorphized for other group");
    let bw = j1 - j0;
    let mut crow = vec![0u8; bw];
    let mut wrow = vec![0.0f32; bw];
    for k in 0..w.din {
        let gi = k / group_len;
        unpack_row::<BITS>(w.packed, bits, k * w.dout + j0, &mut crow);
        let sc = &w.scales[gi * w.dout + j0..gi * w.dout + j1];
        let zp = &w.zps[gi * w.dout + j0..gi * w.dout + j1];
        dequant_row(&crow, sc, zp, &mut wrow);
        for i in 0..m {
            let a = x[i * w.din + k];
            if a != 0.0 {
                axpy(a, &wrow, &mut part[i * bw..(i + 1) * bw]);
            }
        }
    }
}

/// Compile-time unpack dispatch: the const `BITS` selects a fixed-layout
/// decoder where one exists; other widths (and the runtime-`BITS` fallback)
/// go through the generic shifter, with the shift counts const-folded when
/// `BITS` is known.
#[inline(always)]
fn unpack_row<const BITS: u32>(packed: &[u8], bits: u32, start: usize, out: &mut [u8]) {
    match BITS {
        2 => unpack_w2(packed, start, out),
        4 => unpack_w4(packed, start, out),
        8 => unpack_w8(packed, start, out),
        0 => unpack_seg(packed, bits, start, out),
        _ => unpack_seg(packed, BITS, start, out),
    }
}

/// 2-bit codes: 4 per byte, little-endian within the byte (`pack_bits`
/// layout). Handles an arbitrary element offset — a stripe's `start` =
/// `k * dout + j0` can land mid-byte.
#[inline(always)]
fn unpack_w2(packed: &[u8], start: usize, out: &mut [u8]) {
    if out.is_empty() {
        return;
    }
    let mut i = 0;
    let mut byte = start / 4;
    let lead = start % 4;
    if lead != 0 {
        let b = packed[byte];
        let mut off = lead;
        while off < 4 && i < out.len() {
            out[i] = (b >> (2 * off)) & 3;
            i += 1;
            off += 1;
        }
        byte += 1;
    }
    while out.len() - i >= 4 {
        let b = packed[byte];
        out[i] = b & 3;
        out[i + 1] = (b >> 2) & 3;
        out[i + 2] = (b >> 4) & 3;
        out[i + 3] = b >> 6;
        i += 4;
        byte += 1;
    }
    if i < out.len() {
        let b = packed[byte];
        let mut off = 0;
        while i < out.len() {
            out[i] = (b >> (2 * off)) & 3;
            i += 1;
            off += 1;
        }
    }
}

/// 4-bit codes: 2 per byte, low nibble first (`pack_bits` layout), with an
/// odd `start` beginning on a high nibble.
#[inline(always)]
fn unpack_w4(packed: &[u8], start: usize, out: &mut [u8]) {
    if out.is_empty() {
        return;
    }
    let mut i = 0;
    let mut byte = start / 2;
    if start % 2 == 1 {
        out[i] = packed[byte] >> 4;
        i += 1;
        byte += 1;
    }
    while out.len() - i >= 2 {
        let b = packed[byte];
        out[i] = b & 0x0f;
        out[i + 1] = b >> 4;
        i += 2;
        byte += 1;
    }
    if i < out.len() {
        out[i] = packed[byte] & 0x0f;
    }
}

/// 8-bit codes are bytes: a straight copy.
#[inline(always)]
fn unpack_w8(packed: &[u8], start: usize, out: &mut [u8]) {
    let n = out.len();
    out.copy_from_slice(&packed[start..start + n]);
}

// --------------------------------------------------------------- stamping

/// Stamp one concrete entry point around [`stripe_body`]. Entries are
/// `unsafe fn` (uniform signature with the `#[target_feature]` variants) so
/// they all coerce to [`StripeFn`].
macro_rules! stamp_entry {
    ($(#[$attr:meta])* $name:ident, $bits:literal, $group:literal) => {
        $(#[$attr])*
        pub(super) unsafe fn $name(
            w: &PackedWeight<'_>,
            x: &[f32],
            m: usize,
            j0: usize,
            j1: usize,
            part: &mut [f32],
        ) {
            stripe_body::<$bits, $group>(w, x, m, j0, j1, part)
        }
    };
}

/// Stamp a full ISA module: every (bits ∈ {2,3,4,8}, group ∈ {32,64,128,
/// runtime}) specialization plus the fully-generic fallback, and a
/// `lookup` that maps a weight shape to the matching entry + its name.
macro_rules! stamp_isa {
    ($mod_name:ident $(, $feat:literal)*) => {
        mod $mod_name {
            use super::*;

            stamp_entry!($(#[target_feature(enable = $feat)])* w2_g32, 2, 32);
            stamp_entry!($(#[target_feature(enable = $feat)])* w2_g64, 2, 64);
            stamp_entry!($(#[target_feature(enable = $feat)])* w2_g128, 2, 128);
            stamp_entry!($(#[target_feature(enable = $feat)])* w2_gx, 2, 0);
            stamp_entry!($(#[target_feature(enable = $feat)])* w3_g32, 3, 32);
            stamp_entry!($(#[target_feature(enable = $feat)])* w3_g64, 3, 64);
            stamp_entry!($(#[target_feature(enable = $feat)])* w3_g128, 3, 128);
            stamp_entry!($(#[target_feature(enable = $feat)])* w3_gx, 3, 0);
            stamp_entry!($(#[target_feature(enable = $feat)])* w4_g32, 4, 32);
            stamp_entry!($(#[target_feature(enable = $feat)])* w4_g64, 4, 64);
            stamp_entry!($(#[target_feature(enable = $feat)])* w4_g128, 4, 128);
            stamp_entry!($(#[target_feature(enable = $feat)])* w4_gx, 4, 0);
            stamp_entry!($(#[target_feature(enable = $feat)])* w8_g32, 8, 32);
            stamp_entry!($(#[target_feature(enable = $feat)])* w8_g64, 8, 64);
            stamp_entry!($(#[target_feature(enable = $feat)])* w8_g128, 8, 128);
            stamp_entry!($(#[target_feature(enable = $feat)])* w8_gx, 8, 0);
            stamp_entry!($(#[target_feature(enable = $feat)])* generic, 0, 0);

            /// Entry + display name for a `(bits, group_len)` weight shape.
            /// (Spelled out arm-by-arm: a helper macro here would need the
            /// unstable `$$` escape to survive the outer expansion.)
            pub(super) fn lookup(bits: u32, group_len: usize) -> (&'static str, StripeFn) {
                match (bits, group_len) {
                    (2, 32) => (concat!(stringify!($mod_name), "/w2g32"), w2_g32 as StripeFn),
                    (2, 64) => (concat!(stringify!($mod_name), "/w2g64"), w2_g64 as StripeFn),
                    (2, 128) => (concat!(stringify!($mod_name), "/w2g128"), w2_g128 as StripeFn),
                    (2, _) => (concat!(stringify!($mod_name), "/w2gx"), w2_gx as StripeFn),
                    (3, 32) => (concat!(stringify!($mod_name), "/w3g32"), w3_g32 as StripeFn),
                    (3, 64) => (concat!(stringify!($mod_name), "/w3g64"), w3_g64 as StripeFn),
                    (3, 128) => (concat!(stringify!($mod_name), "/w3g128"), w3_g128 as StripeFn),
                    (3, _) => (concat!(stringify!($mod_name), "/w3gx"), w3_gx as StripeFn),
                    (4, 32) => (concat!(stringify!($mod_name), "/w4g32"), w4_g32 as StripeFn),
                    (4, 64) => (concat!(stringify!($mod_name), "/w4g64"), w4_g64 as StripeFn),
                    (4, 128) => (concat!(stringify!($mod_name), "/w4g128"), w4_g128 as StripeFn),
                    (4, _) => (concat!(stringify!($mod_name), "/w4gx"), w4_gx as StripeFn),
                    (8, 32) => (concat!(stringify!($mod_name), "/w8g32"), w8_g32 as StripeFn),
                    (8, 64) => (concat!(stringify!($mod_name), "/w8g64"), w8_g64 as StripeFn),
                    (8, 128) => (concat!(stringify!($mod_name), "/w8g128"), w8_g128 as StripeFn),
                    (8, _) => (concat!(stringify!($mod_name), "/w8gx"), w8_gx as StripeFn),
                    _ => (concat!(stringify!($mod_name), "/generic"), generic as StripeFn),
                }
            }
        }
    };
}

stamp_isa!(scalar);
#[cfg(target_arch = "x86_64")]
stamp_isa!(avx2, "avx2");
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
stamp_isa!(avx512, "avx512f", "avx512bw");
#[cfg(target_arch = "aarch64")]
stamp_isa!(neon, "neon");

/// The always-available scalar loop with runtime bits/group — exactly the
/// pre-dispatch `gemm_stripe` body, callable safely. Every specialized
/// variant must match it bit-for-bit.
pub fn reference(
    w: &PackedWeight<'_>,
    x: &[f32],
    m: usize,
    j0: usize,
    j1: usize,
    part: &mut [f32],
) {
    stripe_body::<0, 0>(w, x, m, j0, j1, part)
}

// -------------------------------------------------------------- selection

/// ISA variant of a kernel entry. All four names are always accepted by
/// the `--kernel` flag / `AQ_KERNEL` env; variants the binary was not
/// compiled for (wrong arch, or `avx512` without the cargo feature) simply
/// never report as compiled/available.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

/// Every variant, in `auto()` preference order (widest vectors first,
/// scalar last).
pub const ALL: [Variant; 4] = [Variant::Avx512, Variant::Avx2, Variant::Neon, Variant::Scalar];

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Scalar => "scalar",
            Variant::Avx2 => "avx2",
            Variant::Avx512 => "avx512",
            Variant::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Variant::Scalar),
            "avx2" => Some(Variant::Avx2),
            "avx512" | "avx512f" => Some(Variant::Avx512),
            "neon" => Some(Variant::Neon),
            _ => None,
        }
    }

    /// Entry points for this variant exist in the binary.
    pub fn compiled(self) -> bool {
        match self {
            Variant::Scalar => true,
            Variant::Avx2 => cfg!(target_arch = "x86_64"),
            Variant::Avx512 => cfg!(all(target_arch = "x86_64", feature = "avx512")),
            Variant::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Compiled *and* the CPU the process is running on has the features —
    /// the soundness gate for handing out this variant's entries.
    pub fn detected(self) -> bool {
        if !self.compiled() {
            return false;
        }
        match self {
            Variant::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Variant::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            Variant::Avx512 => {
                std::is_x86_feature_detected!("avx512f")
                    && std::is_x86_feature_detected!("avx512bw")
            }
            #[cfg(target_arch = "aarch64")]
            Variant::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Variants whose entry points exist in this binary.
pub fn compiled() -> Vec<Variant> {
    ALL.iter().copied().filter(|v| v.compiled()).collect()
}

/// Variants this process may actually run (compiled + CPU-detected).
pub fn available() -> Vec<Variant> {
    ALL.iter().copied().filter(|v| v.detected()).collect()
}

/// Best available variant: avx512 > avx2 > neon > scalar.
pub fn auto() -> Variant {
    ALL.iter().copied().find(|v| v.detected()).unwrap_or(Variant::Scalar)
}

static CLI_REQUEST: OnceLock<Variant> = OnceLock::new();

/// Install the `--kernel` CLI override (wins over `AQ_KERNEL`). Must name a
/// known variant; an unavailable-but-known one is accepted and falls back
/// at selection time (observable via [`info`]). First call wins; call
/// before the model is packed/loaded.
pub fn set_requested(name: &str) -> Result<()> {
    match Variant::parse(name) {
        Some(v) => {
            let _ = CLI_REQUEST.set(v);
            Ok(())
        }
        None => bail!("unknown kernel variant {name:?} (expected scalar|avx2|avx512|neon)"),
    }
}

/// How the process-wide variant was chosen, for observability surfaces.
#[derive(Clone, Debug)]
pub struct KernelInfo {
    /// What [`select`] hands out.
    pub selected: Variant,
    /// The raw explicit request (`--kernel`/`AQ_KERNEL`), when one was made
    /// — may name an unavailable or unknown variant.
    pub requested: Option<String>,
    /// `"flag"`, `"env"`, or `"auto"`.
    pub source: &'static str,
    /// True when an explicit request could not be honored on this
    /// CPU/build and selection fell back to auto.
    pub fell_back: bool,
    pub compiled: Vec<Variant>,
    pub available: Vec<Variant>,
}

/// Snapshot of the current selection state (`/v1/stats`, `aq_kernel_info`,
/// `doctor`).
pub fn info() -> KernelInfo {
    let (selected, requested, source, fell_back) = resolve();
    KernelInfo {
        selected,
        requested,
        source,
        fell_back,
        compiled: compiled(),
        available: available(),
    }
}

/// The variant [`select`] currently resolves to.
pub fn selected() -> Variant {
    resolve().0
}

fn resolve() -> (Variant, Option<String>, &'static str, bool) {
    if let Some(&v) = CLI_REQUEST.get() {
        return honor(v.name().to_string(), Some(v), "flag");
    }
    match std::env::var("AQ_KERNEL") {
        Ok(s) if !s.trim().is_empty() => {
            let v = Variant::parse(&s);
            honor(s, v, "env")
        }
        _ => (auto(), None, "auto", false),
    }
}

fn honor(
    raw: String,
    v: Option<Variant>,
    source: &'static str,
) -> (Variant, Option<String>, &'static str, bool) {
    match v {
        Some(v) if v.detected() => (v, Some(raw), source, false),
        _ => (auto(), Some(raw), source, true),
    }
}

// --------------------------------------------------------------- kernels

/// A resolved dispatch entry: ISA variant + the `(bits, group)`
/// monomorphization for one weight shape. `Copy` — each `PackedLinear`
/// stores its kernel at pack/load time, so the hot path never re-resolves.
#[derive(Clone, Copy)]
pub struct Kernel {
    pub variant: Variant,
    /// `<variant>/<specialization>`, e.g. `"avx2/w4g128"`.
    pub name: &'static str,
    f: StripeFn,
}

impl Kernel {
    /// Run the stripe kernel: `part (m, j1-j0) += x @ dequant(W[:, j0..j1])`.
    #[inline]
    pub fn run(
        &self,
        w: &PackedWeight<'_>,
        x: &[f32],
        m: usize,
        j0: usize,
        j1: usize,
        part: &mut [f32],
    ) {
        // SAFETY: `select_for` hands out a specialized entry only when
        // runtime feature detection confirmed its ISA on this CPU (scalar
        // needs no features), so the target-feature contract is met.
        unsafe { (self.f)(w, x, m, j0, j1, part) }
    }
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernel").field("name", &self.name).finish()
    }
}

/// Resolve the dispatch kernel for a `(bits, group_len)` weight shape under
/// the process-wide selection (override > env > auto).
pub fn select(bits: u32, group_len: usize) -> Kernel {
    select_for(selected(), bits, group_len)
}

/// Resolve for an explicit variant (tests, benches, `PackedModel::
/// force_kernel`). Falls back to scalar when the variant is not runnable
/// here — the returned kernel is always sound to call.
pub fn select_for(variant: Variant, bits: u32, group_len: usize) -> Kernel {
    let v = if variant.detected() { variant } else { Variant::Scalar };
    let (name, f) = match v {
        Variant::Scalar => scalar::lookup(bits, group_len),
        #[cfg(target_arch = "x86_64")]
        Variant::Avx2 => avx2::lookup(bits, group_len),
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        Variant::Avx512 => avx512::lookup(bits, group_len),
        #[cfg(target_arch = "aarch64")]
        Variant::Neon => neon::lookup(bits, group_len),
        _ => scalar::lookup(bits, group_len),
    };
    Kernel { variant: v, name, f }
}

/// The runtime-generic scalar entry wrapped as a [`Kernel`] — exactly the
/// pre-dispatch stripe loop. Benches and tests use it as the baseline every
/// specialized variant must match bit-for-bit (and beat on throughput).
pub fn reference_kernel() -> Kernel {
    let (name, f) = scalar::lookup(0, 0);
    Kernel { variant: Variant::Scalar, name, f }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack_bits;
    use crate::rngx::Pcg32;

    #[test]
    fn specialized_unpackers_match_generic() {
        let mut rng = Pcg32::seeded(21);
        for bits in [2u32, 4, 8] {
            let n = 513;
            let codes: Vec<u8> = (0..n).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            for &(s, l) in
                &[(0usize, 8usize), (1, 7), (2, 5), (3, 64), (5, 1), (7, 2), (130, 96), (509, 4)]
            {
                let mut want = vec![0u8; l];
                unpack_seg(&packed, bits, s, &mut want);
                let mut got = vec![0u8; l];
                match bits {
                    2 => unpack_w2(&packed, s, &mut got),
                    4 => unpack_w4(&packed, s, &mut got),
                    8 => unpack_w8(&packed, s, &mut got),
                    _ => unreachable!(),
                }
                assert_eq!(got, want, "bits={bits} start={s} len={l}");
            }
        }
    }

    #[test]
    fn scalar_always_available_and_auto_never_empty() {
        assert!(Variant::Scalar.compiled());
        assert!(Variant::Scalar.detected());
        assert!(compiled().contains(&Variant::Scalar));
        assert!(available().contains(&Variant::Scalar));
        assert!(auto().detected());
    }

    #[test]
    fn select_for_falls_back_to_scalar_when_unavailable() {
        for v in ALL {
            let k = select_for(v, 4, 128);
            assert!(k.variant.detected(), "{v} selection must be runnable");
            if !v.detected() {
                assert_eq!(k.variant, Variant::Scalar);
            }
        }
    }

    #[test]
    fn lookup_names_follow_variant_and_shape() {
        assert_eq!(select_for(Variant::Scalar, 4, 128).name, "scalar/w4g128");
        assert_eq!(select_for(Variant::Scalar, 3, 64).name, "scalar/w3g64");
        assert_eq!(select_for(Variant::Scalar, 2, 48).name, "scalar/w2gx");
        assert_eq!(select_for(Variant::Scalar, 5, 64).name, "scalar/generic");
        let k = select_for(auto(), 4, 128);
        assert!(k.name.starts_with(k.variant.name()), "{} vs {}", k.name, k.variant);
    }

    #[test]
    fn variants_bit_identical_on_one_stripe() {
        let mut rng = Pcg32::seeded(22);
        let (din, dout, m) = (128, 75, 3);
        for (bits, group_len) in [(2u32, 32usize), (3, 64), (4, 64), (8, 128), (4, 25)] {
            let group_len = if din % group_len == 0 { group_len } else { din };
            let codes: Vec<u8> = (0..din * dout).map(|_| rng.below(1 << bits) as u8).collect();
            let packed = pack_bits(&codes, bits);
            let ng = din / group_len;
            let scales: Vec<f32> = (0..ng * dout).map(|_| 0.01 + rng.uniform() as f32).collect();
            let zps: Vec<f32> = (0..ng * dout).map(|_| rng.below(1 << bits) as f32).collect();
            let w = PackedWeight {
                packed: &packed,
                bits,
                din,
                dout,
                group_len,
                scales: &scales,
                zps: &zps,
            };
            let x: Vec<f32> = (0..m * din).map(|_| rng.normal() as f32).collect();
            // ragged sub-stripe on purpose: j0=8, j1=dout
            let (j0, j1) = (8, dout);
            let mut want = vec![0.0f32; m * (j1 - j0)];
            reference(&w, &x, m, j0, j1, &mut want);
            for v in available() {
                let k = select_for(v, bits, group_len);
                let mut got = vec![0.0f32; m * (j1 - j0)];
                k.run(&w, &x, m, j0, j1, &mut got);
                assert_eq!(got, want, "kernel {} diverges from scalar reference", k.name);
            }
        }
    }
}
