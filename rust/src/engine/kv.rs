//! Paged KV cache with copy-on-write prefix sharing.
//!
//! One global pool of fixed-size *pages* (default 16 tokens × `n_layers` ×
//! `d` for K and V) backs every sequence. A slot holds a page table mapping
//! the logical token position to `(page, offset)`; pages are refcounted, so
//! sequences admitted with an identical prompt prefix attach the donor's
//! pages read-only and share them until they diverge. Divergence inside a
//! partially-filled shared page triggers exactly one copy-on-write: the
//! attaching slot copies the rows below its divergence point into a fresh
//! page it owns and appends there. Pages are append-only — a row, once
//! written, is never overwritten — which is what makes sharing safe and
//! keeps greedy decode bit-identical to the old ring for any page size.
//!
//! Sliding-window semantics survive the refactor: attention over a slot
//! reads the last `min(len, window)` tokens, and [`KvCache::trim`] (called
//! at *step start*, never mid-chunk) releases whole pages that fell out of
//! the window. Released pages whose content is still indexed by the prefix
//! registry park in a reclaim queue (LRU by default) and are evicted only
//! when the allocator runs dry, so a finished request's system prompt keeps
//! accelerating the next one for free.
//!
//! Capacity is explicit: `max_pages == 0` grows the arena on demand (the
//! offline path), a finite `max_pages` is a hard pool bound that the
//! scheduler reserves against via [`KvCache::worst_case_pages`] — replacing
//! the ring's silent sliding-window overwrite with up-front accounting.
//!
//! Write protocol per token: `advance(slot)` once (returns the absolute
//! position and makes its page writable), then `write_k`/`write_v` at that
//! position for every layer.

use std::collections::{HashMap, VecDeque};

/// Default tokens per page.
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// Order in which registry-cached (refcount-0) pages are reclaimed when the
/// allocator runs dry. Reclamation affects only *which* prefixes stay
/// shareable — never the bytes a live sequence reads — so greedy output is
/// identical across orders (asserted in `rust/tests/engine.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reclaim {
    /// Evict the least-recently-freed cached page first.
    Lru,
    /// Evict the most-recently-freed cached page first.
    Mru,
}

/// Pool tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvConfig {
    /// Tokens per page (≥ 1).
    pub page_tokens: usize,
    /// Hard cap on allocated pages; `0` = grow on demand.
    pub max_pages: usize,
    /// Enable prompt-prefix sharing (registry + copy-on-write).
    pub share: bool,
    /// Reclamation order for registry-cached pages.
    pub reclaim: Reclaim,
}

impl Default for KvConfig {
    fn default() -> KvConfig {
        KvConfig {
            page_tokens: DEFAULT_PAGE_TOKENS,
            max_pages: 0,
            share: true,
            reclaim: Reclaim::Lru,
        }
    }
}

/// Point-in-time pool occupancy + cumulative sharing counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvStats {
    pub page_tokens: usize,
    /// Pool bound (`0` = unbounded).
    pub max_pages: usize,
    /// Pages backed by arena memory.
    pub pages_allocated: usize,
    /// Pages referenced by at least one live sequence.
    pub pages_resident: usize,
    /// Refcount-0 pages kept alive by the prefix registry (reclaimable).
    pub pages_cached: usize,
    /// Immediately-allocatable pages (pool headroom when bounded).
    pub pages_free: usize,
    /// Pages referenced by two or more sequences right now.
    pub pages_shared: usize,
    /// Bytes sharing saves right now: Σ over pages of `(refs−1) ×
    /// page_bytes` — what duplicate copies would have cost.
    pub shared_bytes: usize,
    /// Bytes of K+V held by live sequences.
    pub resident_bytes: usize,
    /// Cumulative prompt tokens served from shared pages.
    pub shared_tokens_total: u64,
    /// Cumulative admissions that attached a non-empty shared prefix.
    pub prefix_hits: u64,
    /// Cumulative copy-on-write page copies at divergence points.
    pub cow_faults: u64,
}

/// One page-table entry: which pool page backs a block of
/// `page_tokens` consecutive token positions, and whether this slot may
/// append into it (`owned`) or holds it read-only (attached via sharing).
#[derive(Clone, Copy, Debug)]
struct PageRef {
    page: usize,
    owned: bool,
}

#[derive(Clone, Default)]
struct SlotState {
    /// Page table, front-trimmed: entry `i` backs block `trimmed + i`.
    pages: VecDeque<PageRef>,
    /// Whole pages released from the front by [`KvCache::trim`].
    trimmed: usize,
    /// Tokens ever appended (== the next absolute position).
    len: usize,
    /// Rolling prefix hash over the first `registered` prompt tokens.
    hash: (u64, u64),
    /// Prompt tokens already published to the prefix registry.
    registered: usize,
}

/// [`KvCache::worst_case_pages`] without a pool in hand — the server's
/// admission gate prices requests with the same formula the scheduler
/// reserves by, so the two layers can never disagree about what fits.
pub fn worst_case_pages_for(
    window: usize,
    page_tokens: usize,
    prompt_len: usize,
    max_new: usize,
    prefill_chunk: usize,
) -> usize {
    let chunk = match prefill_chunk {
        0 => prompt_len,
        c => c.min(prompt_len),
    };
    let peak = (prompt_len + max_new).min(window.saturating_sub(1) + chunk.max(1));
    peak.div_ceil(page_tokens) + 1
}

const H_SEED: (u64, u64) = (0xcbf2_9ce4_8422_2325, 0x6c62_272e_07bb_0142);

/// Fold one token into a 128-bit rolling prefix hash (two independent
/// multiply-xor chains; a collision needs both 64-bit halves to agree).
#[inline]
fn mix(h: (u64, u64), tok: i32) -> (u64, u64) {
    let t = (tok as u32 as u64) ^ 0x9e37_79b9_7f4a_7c15;
    let a = (h.0 ^ t).wrapping_mul(0x0000_0100_0000_01b3);
    let b = (h.1 ^ t.rotate_left(21)).wrapping_mul(0xc6a4_a793_5bd1_e995);
    (a.rotate_left(27), b.rotate_left(31))
}

#[derive(Clone)]
pub struct KvCache {
    pub n_slots: usize,
    pub n_layers: usize,
    /// Attention window: a slot's reads cover its last `min(len, window)`
    /// tokens (the old ring capacity).
    pub window: usize,
    pub d: usize,
    pub page_tokens: usize,
    max_pages: usize,
    share: bool,
    reclaim: Reclaim,
    /// Arenas, `pages_allocated × page_tokens × n_layers × d` each, grown
    /// lazily in page units. Layout:
    /// `((page · n_layers + layer) · page_tokens + offset) · d`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Live-sequence references per page.
    refs: Vec<u32>,
    /// Registry hashes published for each page; non-empty keeps a
    /// refcount-0 page reclaimable-but-cached instead of free.
    page_keys: Vec<Vec<(u64, u64)>>,
    /// Pages with no references and no registry entries.
    free: VecDeque<usize>,
    /// Refcount-0 registry-cached pages in release order (lazily pruned:
    /// entries whose page was re-attached or already drained are skipped).
    parked: VecDeque<usize>,
    /// `hash(prompt[..n]) → (page holding token n−1, n)`.
    registry: HashMap<(u64, u64), (usize, usize)>,
    slots: Vec<SlotState>,
    shared_tokens: u64,
    prefix_hits: u64,
    cow_faults: u64,
}

impl KvCache {
    /// Pool with default paging knobs (16-token pages, unbounded growth,
    /// sharing on). `window` is the attention window the old ring called
    /// `capacity`.
    pub fn new(n_slots: usize, n_layers: usize, window: usize, d: usize) -> KvCache {
        KvCache::with_options(n_slots, n_layers, window, d, KvConfig::default())
    }

    pub fn with_options(
        n_slots: usize,
        n_layers: usize,
        window: usize,
        d: usize,
        cfg: KvConfig,
    ) -> KvCache {
        assert!(n_slots > 0 && n_layers > 0 && window > 0 && d > 0);
        assert!(cfg.page_tokens > 0, "page_tokens must be at least 1");
        KvCache {
            n_slots,
            n_layers,
            window,
            d,
            page_tokens: cfg.page_tokens,
            max_pages: cfg.max_pages,
            share: cfg.share,
            reclaim: cfg.reclaim,
            k: Vec::new(),
            v: Vec::new(),
            refs: Vec::new(),
            page_keys: Vec::new(),
            free: VecDeque::new(),
            parked: VecDeque::new(),
            registry: HashMap::new(),
            slots: vec![SlotState::default(); n_slots],
            shared_tokens: 0,
            prefix_hits: 0,
            cow_faults: 0,
        }
    }

    /// Bytes currently backed by arena memory (grows lazily per page).
    pub fn mem_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// K+V bytes of one page.
    pub fn page_bytes(&self) -> usize {
        self.page_tokens * self.n_layers * self.d * 4 * 2
    }

    /// Pool bound in pages (`0` = unbounded).
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Tokens ever appended to a slot (== the next absolute position; may
    /// exceed `window` for sliding-window decode).
    pub fn len(&self, slot: usize) -> usize {
        self.slots[slot].len
    }

    /// Entries a slot's attention may read: `min(len, window)`.
    pub fn attn_len(&self, slot: usize) -> usize {
        self.slots[slot].len.min(self.window)
    }

    /// Upper bound on pages one request can hold at once, for admission
    /// reservation. Peak residency is the lesser of the whole sequence
    /// (`prompt + max_new`) and the trimmed window plus one in-flight
    /// prefill chunk; `+ 1` covers the partially-trimmed front page.
    pub fn worst_case_pages(
        &self,
        prompt_len: usize,
        max_new: usize,
        prefill_chunk: usize,
    ) -> usize {
        worst_case_pages_for(self.window, self.page_tokens, prompt_len, max_new, prefill_chunk)
    }

    // ------------------------------------------------------------ allocator

    fn grow(&mut self) -> usize {
        let page = self.refs.len();
        assert!(
            self.max_pages == 0 || page < self.max_pages,
            "kv page pool exhausted ({} pages) — admission reservation must prevent this",
            self.max_pages
        );
        let stride = self.page_tokens * self.n_layers * self.d;
        self.k.resize((page + 1) * stride, 0.0);
        self.v.resize((page + 1) * stride, 0.0);
        self.refs.push(0);
        self.page_keys.push(Vec::new());
        page
    }

    /// Next reclaimable registry-cached page in the configured order,
    /// skipping stale queue entries (re-attached or already drained pages).
    fn pop_reclaimable(&mut self) -> Option<usize> {
        loop {
            let p = match self.reclaim {
                Reclaim::Lru => self.parked.pop_front(),
                Reclaim::Mru => self.parked.pop_back(),
            }?;
            if self.refs[p] == 0 && !self.page_keys[p].is_empty() {
                return Some(p);
            }
        }
    }

    /// Drop every registry entry published for `page` (pre-reclaim).
    fn deregister(&mut self, page: usize) {
        for h in std::mem::take(&mut self.page_keys[page]) {
            if self.registry.get(&h).is_some_and(|e| e.0 == page) {
                self.registry.remove(&h);
            }
        }
    }

    /// Claim a page for a single owner: free list first, then reclaim a
    /// cached page, then grow the arena (bounded by `max_pages`).
    fn alloc_page(&mut self) -> usize {
        let page = if let Some(p) = self.free.pop_front() {
            p
        } else if let Some(p) = self.pop_reclaimable() {
            self.deregister(p);
            p
        } else {
            self.grow()
        };
        debug_assert!(self.refs[page] == 0 && self.page_keys[page].is_empty());
        self.refs[page] = 1;
        page
    }

    /// Drop one reference; a drained page parks in the reclaim queue while
    /// the registry still indexes it, otherwise returns to the free list.
    fn release_page(&mut self, page: usize) {
        assert!(self.refs[page] > 0, "double free of kv page {page}");
        self.refs[page] -= 1;
        if self.refs[page] == 0 {
            if self.page_keys[page].is_empty() {
                self.free.push_back(page);
            } else {
                self.parked.push_back(page);
            }
        }
    }

    // ------------------------------------------------------- slot lifecycle

    /// Drop a slot's history (sequence eviction / admission). Registered
    /// pages stay cached for future prefix hits until reclaimed.
    pub fn reset(&mut self, slot: usize) {
        let pages: Vec<PageRef> = self.slots[slot].pages.drain(..).collect();
        for pr in pages {
            self.release_page(pr.page);
        }
        self.slots[slot] = SlotState::default();
    }

    /// Release whole pages that fell out of the attention window. Must be
    /// called only at *step start* (decode does): mid-chunk, earlier rows
    /// of the same step still read the window anchored at their own
    /// position, which trimming for a later row could free.
    pub fn trim(&mut self, slot: usize) {
        let start = (self.slots[slot].len + 1).saturating_sub(self.window);
        while (self.slots[slot].trimmed + 1) * self.page_tokens <= start {
            let pr = self.slots[slot].pages.pop_front().expect("page table under-run");
            self.slots[slot].trimmed += 1;
            self.release_page(pr.page);
        }
    }

    /// Claim the next position for `slot` and make its page writable:
    /// allocates a fresh page at block boundaries, copy-on-writes a shared
    /// (non-owned) partial tail page at the divergence point. Returns the
    /// absolute position. Call exactly once per token, before the layers.
    pub fn advance(&mut self, slot: usize) -> usize {
        let pos = self.slots[slot].len;
        if pos % self.page_tokens == 0 {
            let page = self.alloc_page();
            self.slots[slot].pages.push_back(PageRef { page, owned: true });
        } else {
            let tail = *self.slots[slot].pages.back().expect("tail page");
            if !tail.owned {
                // diverging inside a shared page: copy the rows below the
                // divergence point into a page this slot owns
                let fresh = self.alloc_page();
                let filled = (pos % self.page_tokens) * self.d;
                for layer in 0..self.n_layers {
                    let src = (tail.page * self.n_layers + layer) * self.page_tokens * self.d;
                    let dst = (fresh * self.n_layers + layer) * self.page_tokens * self.d;
                    self.k.copy_within(src..src + filled, dst);
                    self.v.copy_within(src..src + filled, dst);
                }
                self.release_page(tail.page);
                *self.slots[slot].pages.back_mut().expect("tail page") =
                    PageRef { page: fresh, owned: true };
                self.cow_faults += 1;
            }
        }
        self.slots[slot].len = pos + 1;
        pos
    }

    // ------------------------------------------------------------- indexing

    #[inline]
    fn row_base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.n_slots && layer < self.n_layers);
        let st = &self.slots[slot];
        debug_assert!(pos < st.len, "position {pos} not yet appended");
        let block = pos / self.page_tokens;
        debug_assert!(block >= st.trimmed, "position {pos} trimmed out of the window");
        let page = st.pages[block - st.trimmed].page;
        ((page * self.n_layers + layer) * self.page_tokens + pos % self.page_tokens) * self.d
    }

    pub fn write_k(&mut self, slot: usize, layer: usize, pos: usize, row: &[f32]) {
        let b = self.row_base(slot, layer, pos);
        debug_assert!(
            self.slots[slot].pages[pos / self.page_tokens - self.slots[slot].trimmed].owned,
            "write into a shared page (copy-on-write should have claimed it)"
        );
        self.k[b..b + self.d].copy_from_slice(row);
    }

    pub fn write_v(&mut self, slot: usize, layer: usize, pos: usize, row: &[f32]) {
        let b = self.row_base(slot, layer, pos);
        self.v[b..b + self.d].copy_from_slice(row);
    }

    /// K row at absolute token position `pos` (page-table translated).
    #[inline]
    pub fn k_row(&self, slot: usize, layer: usize, pos: usize) -> &[f32] {
        let b = self.row_base(slot, layer, pos);
        &self.k[b..b + self.d]
    }

    /// V row at absolute token position `pos` (page-table translated).
    #[inline]
    pub fn v_row(&self, slot: usize, layer: usize, pos: usize) -> &[f32] {
        let b = self.row_base(slot, layer, pos);
        &self.v[b..b + self.d]
    }

    // ------------------------------------------------------- prefix sharing

    /// Attach the longest registered prefix of `prompt` to an empty slot:
    /// the matching pages are referenced read-only and their tokens skip
    /// prefill entirely. Returns the shared token count `s` (the slot's
    /// `len` afterwards), capped at `prompt.len() − 1` so the final prompt
    /// token is always fed through the model to produce logits. The shared
    /// K/V was computed from the identical token prefix by the same code,
    /// so reads through it are bit-identical to recomputing.
    pub fn attach_prefix(&mut self, slot: usize, prompt: &[i32]) -> usize {
        debug_assert!(
            self.slots[slot].len == 0 && self.slots[slot].pages.is_empty(),
            "attach_prefix requires a freshly reset slot"
        );
        if !self.share || prompt.len() < 2 {
            return 0;
        }
        let cap = prompt.len() - 1;
        let mut h = H_SEED;
        let mut matched = 0usize;
        let mut hash_at_match = H_SEED;
        // page per block covered by the match; a later entry in the same
        // block supersedes an earlier one (its page holds all rows below
        // its fill point, block-start included)
        let mut table: Vec<usize> = Vec::new();
        for (n, &tok) in prompt.iter().take(cap).enumerate() {
            h = mix(h, tok);
            let Some(&(page, _)) = self.registry.get(&h) else { break };
            let block = n / self.page_tokens;
            if block == table.len() {
                table.push(page);
            } else {
                table[block] = page;
            }
            matched = n + 1;
            hash_at_match = h;
        }
        if matched == 0 {
            return 0;
        }
        debug_assert_eq!(table.len(), matched.div_ceil(self.page_tokens));
        for &page in &table {
            self.refs[page] += 1;
            self.slots[slot].pages.push_back(PageRef { page, owned: false });
        }
        let st = &mut self.slots[slot];
        st.len = matched;
        st.registered = matched;
        st.hash = hash_at_match;
        self.prefix_hits += 1;
        self.shared_tokens += matched as u64;
        matched
    }

    /// Publish the first `prefix.len()` prompt tokens of `slot` to the
    /// registry so later admissions can attach them. Call only *after* the
    /// step that wrote those rows completed (content is then immutable —
    /// pages are append-only). Incremental: tokens already registered are
    /// skipped, existing entries are never overwritten.
    pub fn register_prefix(&mut self, slot: usize, prefix: &[i32]) {
        if !self.share {
            return;
        }
        debug_assert!(prefix.len() <= self.slots[slot].len);
        while self.slots[slot].registered < prefix.len() {
            let st = &self.slots[slot];
            let n = st.registered;
            let h = mix(st.hash, prefix[n]);
            let block = n / self.page_tokens;
            // a long chunk can outrun the window before the next trim; its
            // oldest pages are already released and cannot be published
            let page = if block >= st.trimmed {
                Some(st.pages[block - st.trimmed].page)
            } else {
                None
            };
            let st = &mut self.slots[slot];
            st.hash = h;
            st.registered = n + 1;
            if let Some(page) = page {
                if !self.registry.contains_key(&h) {
                    self.registry.insert(h, (page, n + 1));
                    self.page_keys[page].push(h);
                }
            }
        }
    }

    // ------------------------------------------------------- observability

    pub fn stats(&self) -> KvStats {
        let (mut resident, mut cached, mut shared, mut extra_refs) = (0usize, 0usize, 0usize, 0);
        for (p, &r) in self.refs.iter().enumerate() {
            if r > 0 {
                resident += 1;
                if r >= 2 {
                    shared += 1;
                    extra_refs += r as usize - 1;
                }
            } else if !self.page_keys[p].is_empty() {
                cached += 1;
            }
        }
        let pages_total = if self.max_pages > 0 { self.max_pages } else { self.refs.len() };
        KvStats {
            page_tokens: self.page_tokens,
            max_pages: self.max_pages,
            pages_allocated: self.refs.len(),
            pages_resident: resident,
            pages_cached: cached,
            pages_free: pages_total - resident - cached,
            pages_shared: shared,
            shared_bytes: extra_refs * self.page_bytes(),
            resident_bytes: resident * self.page_bytes(),
            shared_tokens_total: self.shared_tokens,
            prefix_hits: self.prefix_hits,
            cow_faults: self.cow_faults,
        }
    }

    /// Exhaustive bookkeeping check for the property suite: recomputes
    /// refcounts from the page tables and verifies free-list/registry
    /// consistency. Returns a description of the first violation.
    pub fn debug_validate(&self) -> Result<(), String> {
        let n = self.refs.len();
        let mut expect = vec![0u32; n];
        let mut owners = vec![0u32; n];
        for (slot, st) in self.slots.iter().enumerate() {
            for pr in &st.pages {
                if pr.page >= n {
                    return Err(format!("slot {slot} references unallocated page {}", pr.page));
                }
                expect[pr.page] += 1;
                if pr.owned {
                    owners[pr.page] += 1;
                }
            }
            if st.len.div_ceil(self.page_tokens) != st.trimmed + st.pages.len() {
                return Err(format!("slot {slot}: page table does not cover len {}", st.len));
            }
        }
        for p in 0..n {
            if self.refs[p] != expect[p] {
                return Err(format!(
                    "page {p}: refcount {} but {} table references",
                    self.refs[p], expect[p]
                ));
            }
            if owners[p] > 1 {
                return Err(format!("page {p} owned by {} slots", owners[p]));
            }
        }
        let mut in_free = vec![false; n];
        for &p in &self.free {
            if in_free[p] {
                return Err(format!("page {p} on the free list twice"));
            }
            in_free[p] = true;
            if self.refs[p] != 0 {
                return Err(format!("page {p} free with refcount {}", self.refs[p]));
            }
            if !self.page_keys[p].is_empty() {
                return Err(format!("page {p} free but still registered"));
            }
        }
        for (h, &(page, _)) in &self.registry {
            if page >= n || !self.page_keys[page].contains(h) {
                return Err(format!("registry entry points at page {page} without a back-key"));
            }
            if in_free[page] {
                return Err(format!("registry entry points at free page {page}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n_slots: usize, page_tokens: usize, window: usize) -> KvCache {
        KvCache::with_options(
            n_slots,
            1,
            window,
            2,
            KvConfig { page_tokens, ..KvConfig::default() },
        )
    }

    fn feed(c: &mut KvCache, slot: usize, tokens: std::ops::Range<usize>) {
        for t in tokens {
            c.trim(slot);
            let pos = c.advance(slot);
            assert_eq!(pos, t);
            c.write_k(slot, 0, pos, &[t as f32, 0.0]);
            c.write_v(slot, 0, pos, &[0.0, t as f32]);
        }
    }

    #[test]
    fn append_and_read_across_page_boundaries() {
        let mut c = pool(2, 2, 16);
        feed(&mut c, 0, 0..5);
        assert_eq!(c.len(0), 5);
        assert_eq!(c.len(1), 0);
        for pos in 0..5 {
            assert_eq!(c.k_row(0, 0, pos)[0], pos as f32);
            assert_eq!(c.v_row(0, 0, pos)[1], pos as f32);
        }
        // 5 tokens over 2-token pages → 3 pages resident
        assert_eq!(c.stats().pages_resident, 3);
        c.debug_validate().unwrap();
    }

    #[test]
    fn trim_releases_pages_outside_the_window() {
        let mut c = pool(1, 1, 3);
        feed(&mut c, 0, 0..7);
        // window 3 over 1-token pages: at most window + 1 pages survive a
        // trim/advance cycle, and the retained tail reads back exactly
        assert!(c.stats().pages_resident <= 4, "{:?}", c.stats());
        for pos in 4..7 {
            assert_eq!(c.k_row(0, 0, pos)[0], pos as f32);
        }
        assert_eq!(c.attn_len(0), 3);
        assert_eq!(c.len(0), 7);
        c.debug_validate().unwrap();
    }

    #[test]
    fn prefix_attach_shares_pages_then_cow_isolates_divergence() {
        let prompt: Vec<i32> = (0..7).map(|t| 100 + t).collect();
        let mut c = pool(2, 2, 16);
        feed(&mut c, 0, 0..7);
        c.register_prefix(0, &prompt);

        // same prompt on slot 1: shares min(7, len−1) = 6 tokens → 3 pages
        let s = c.attach_prefix(1, &prompt);
        assert_eq!(s, 6);
        assert_eq!(c.stats().pages_shared, 3);
        assert!(c.stats().shared_bytes > 0);
        for pos in 0..6 {
            assert_eq!(c.k_row(1, 0, pos)[0], pos as f32, "shared rows read the donor's bytes");
        }

        // slot 1 appends its token 6: lands mid-page in a shared page →
        // exactly one copy-on-write, and the donor's rows are untouched
        let before = c.stats().cow_faults;
        let pos = c.advance(1);
        assert_eq!(pos, 6);
        assert_eq!(c.stats().cow_faults, before + 1);
        c.write_k(1, 0, pos, &[999.0, 0.0]);
        assert_eq!(c.k_row(0, 0, 6)[0], 6.0, "donor must not see the writer's divergence");
        assert_eq!(c.k_row(1, 0, 6)[0], 999.0);
        assert_eq!(c.k_row(1, 0, 4)[0], 4.0, "CoW copies the rows below the divergence point");
        assert_eq!(c.stats().pages_shared, 2);
        c.debug_validate().unwrap();
    }

    #[test]
    fn reset_parks_registered_pages_for_reuse_and_reclaims_them() {
        let prompt: Vec<i32> = (0..4).map(|t| 7 * t + 1).collect();
        let mut c = KvCache::with_options(
            2,
            1,
            16,
            2,
            KvConfig { page_tokens: 2, max_pages: 4, ..KvConfig::default() },
        );
        feed(&mut c, 0, 0..4);
        c.register_prefix(0, &prompt);
        c.reset(0);
        let st = c.stats();
        assert_eq!(st.pages_resident, 0);
        assert_eq!(st.pages_cached, 2, "registered pages stay cached after reset");

        // a same-prefix admission revives the cached pages
        let s = c.attach_prefix(0, &prompt);
        assert_eq!(s, 3);
        assert_eq!(c.stats().pages_resident, 2);
        c.reset(0);

        // an unrelated workload fills the bounded pool: the cached pages
        // are reclaimed (refcount 0) instead of growth past max_pages
        feed(&mut c, 1, 0..8);
        assert_eq!(c.stats().pages_allocated, 4);
        assert_eq!(c.stats().pages_cached, 0, "cache evicted under pressure");
        // and the evicted prefix no longer matches
        assert_eq!(c.attach_prefix(0, &prompt), 0);
        c.debug_validate().unwrap();
    }

    #[test]
    fn worst_case_pages_bounds_actual_residency() {
        let c = pool(1, 4, 16);
        // short sequence: exact page count + straddle margin
        assert_eq!(c.worst_case_pages(5, 3, 1), 3);
        // long sequence: bounded by window + chunk, not prompt + max_new
        assert!(c.worst_case_pages(1000, 1000, 8) <= (15 + 8usize).div_ceil(4) + 1);
        // chunk 0 feeds the whole prompt in one step: the whole-sequence
        // bound (104 tokens) is tighter than window − 1 + chunk (115)
        assert_eq!(c.worst_case_pages(100, 4, 0), 104usize.div_ceil(4) + 1);
    }

    #[test]
    fn share_disabled_never_attaches() {
        let prompt: Vec<i32> = (0..6).collect();
        let mut c = KvCache::with_options(
            2,
            1,
            16,
            2,
            KvConfig { page_tokens: 2, share: false, ..KvConfig::default() },
        );
        feed(&mut c, 0, 0..6);
        c.register_prefix(0, &prompt);
        assert_eq!(c.attach_prefix(1, &prompt), 0);
        assert_eq!(c.stats().prefix_hits, 0);
    }
}
