//! Ring-buffer KV cache with per-sequence slots.
//!
//! One contiguous f32 arena holds `(slot, layer, ring_pos, d_model)` for K
//! and V. A *slot* is a serving sequence; the scheduler assigns each
//! admitted request a slot and resets it on eviction, so cache memory is
//! bounded by `max_batch × n_layers × capacity × d` regardless of how many
//! requests flow through. When a sequence outgrows `capacity` the ring
//! overwrites the oldest entries (sliding-window attention) — valid for
//! RoPE models; the decoder caps absolute positions for learned-positional
//! models before that can happen.
//!
//! Write protocol per generated token: `advance(slot)` once (returns the
//! ring index), then `write_k`/`write_v` at that index for every layer, so
//! all layers stay aligned on the same ring position.
//!
//! Chunked prefill pushes several tokens of one slot through a single step,
//! which means the ring head can move (and old entries can be overwritten)
//! *between* two rows of the same batch. Attention therefore never reads
//! through the live head: [`KvCache::k_row_at`]/[`v_row_at`] address a
//! window of `limit` entries ending at an explicit anchor ring index — the
//! snapshot the anchored row saw when it claimed its slot — so a row's
//! attention window is independent of how many later rows share its step.

#[derive(Clone)]
pub struct KvCache {
    pub n_slots: usize,
    pub n_layers: usize,
    pub capacity: usize,
    pub d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Valid entries per slot (≤ capacity).
    len: Vec<usize>,
    /// Next ring write index per slot.
    head: Vec<usize>,
}

impl KvCache {
    pub fn new(n_slots: usize, n_layers: usize, capacity: usize, d: usize) -> KvCache {
        assert!(n_slots > 0 && n_layers > 0 && capacity > 0 && d > 0);
        let total = n_slots * n_layers * capacity * d;
        KvCache {
            n_slots,
            n_layers,
            capacity,
            d,
            k: vec![0.0; total],
            v: vec![0.0; total],
            len: vec![0; n_slots],
            head: vec![0; n_slots],
        }
    }

    pub fn mem_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Number of retained entries for a slot.
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.len[slot] == 0
    }

    /// Drop a slot's history (sequence eviction / admission).
    pub fn reset(&mut self, slot: usize) {
        self.len[slot] = 0;
        self.head[slot] = 0;
    }

    /// Claim the ring index for the next token of `slot`. Evicts the oldest
    /// entry when full. Call exactly once per token, before the layer loop.
    pub fn advance(&mut self, slot: usize) -> usize {
        let idx = self.head[slot];
        self.head[slot] = (idx + 1) % self.capacity;
        if self.len[slot] < self.capacity {
            self.len[slot] += 1;
        }
        idx
    }

    fn base(&self, slot: usize, layer: usize, ring: usize) -> usize {
        debug_assert!(slot < self.n_slots && layer < self.n_layers && ring < self.capacity);
        ((slot * self.n_layers + layer) * self.capacity + ring) * self.d
    }

    pub fn write_k(&mut self, slot: usize, layer: usize, ring: usize, row: &[f32]) {
        let b = self.base(slot, layer, ring);
        self.k[b..b + self.d].copy_from_slice(row);
    }

    pub fn write_v(&mut self, slot: usize, layer: usize, ring: usize, row: &[f32]) {
        let b = self.base(slot, layer, ring);
        self.v[b..b + self.d].copy_from_slice(row);
    }

    /// Ring index of the `j`-th retained entry (temporal order, 0 = oldest).
    #[inline]
    pub fn ring_at(&self, slot: usize, j: usize) -> usize {
        debug_assert!(j < self.len[slot]);
        (self.head[slot] + self.capacity - self.len[slot] + j) % self.capacity
    }

    /// Ring index of the `t`-th entry (0 = oldest) of a window of `limit`
    /// entries ending at the anchor ring index `ring` — the cache snapshot
    /// seen by the row that claimed `ring` via [`advance`](Self::advance).
    /// Unlike [`ring_at`](Self::ring_at) this does not consult the live
    /// head, so it stays correct when later rows of the same step have
    /// advanced the ring past the anchor.
    #[inline]
    pub fn ring_in_window(&self, ring: usize, limit: usize, t: usize) -> usize {
        debug_assert!(limit >= 1 && limit <= self.capacity && t < limit);
        (ring + 1 + self.capacity - limit + t) % self.capacity
    }

    /// K row `t` (0 = oldest) of the window of `limit` entries ending at
    /// anchor index `ring`.
    #[inline]
    pub fn k_row_at(
        &self,
        slot: usize,
        layer: usize,
        ring: usize,
        limit: usize,
        t: usize,
    ) -> &[f32] {
        let b = self.base(slot, layer, self.ring_in_window(ring, limit, t));
        &self.k[b..b + self.d]
    }

    /// V row `t` (0 = oldest) of the window of `limit` entries ending at
    /// anchor index `ring`.
    #[inline]
    pub fn v_row_at(
        &self,
        slot: usize,
        layer: usize,
        ring: usize,
        limit: usize,
        t: usize,
    ) -> &[f32] {
        let b = self.base(slot, layer, self.ring_in_window(ring, limit, t));
        &self.v[b..b + self.d]
    }

    #[inline]
    pub fn k_row(&self, slot: usize, layer: usize, j: usize) -> &[f32] {
        let b = self.base(slot, layer, self.ring_at(slot, j));
        &self.k[b..b + self.d]
    }

    #[inline]
    pub fn v_row(&self, slot: usize, layer: usize, j: usize) -> &[f32] {
        let b = self.base(slot, layer, self.ring_at(slot, j));
        &self.v[b..b + self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_temporal_order() {
        let mut c = KvCache::new(2, 1, 4, 2);
        for t in 0..3 {
            let idx = c.advance(0);
            c.write_k(0, 0, idx, &[t as f32, 0.0]);
            c.write_v(0, 0, idx, &[0.0, t as f32]);
        }
        assert_eq!(c.len(0), 3);
        assert_eq!(c.len(1), 0);
        for j in 0..3 {
            assert_eq!(c.k_row(0, 0, j)[0], j as f32);
            assert_eq!(c.v_row(0, 0, j)[1], j as f32);
        }
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut c = KvCache::new(1, 1, 3, 1);
        for t in 0..5 {
            let idx = c.advance(0);
            c.write_k(0, 0, idx, &[t as f32]);
            c.write_v(0, 0, idx, &[t as f32]);
        }
        assert_eq!(c.len(0), 3);
        // retained window is the last 3 tokens, oldest first
        let got: Vec<f32> = (0..3).map(|j| c.k_row(0, 0, j)[0]).collect();
        assert_eq!(got, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn anchored_window_is_independent_of_the_live_head() {
        // cap-3 ring, tokens 0..5 → rings [0, 1, 2, 0, 1]. The window
        // anchored at token 3 (ring 0, limit 3) addresses rings {1, 2, 0} =
        // tokens {1, 2, 3} *at token 3's time*; it must keep resolving those
        // ring indices after token 4 moved the head (ring 1 now holds token
        // 4 — readers that must not see such overwrites order write→attend
        // per row, as decode.rs does).
        let mut c = KvCache::new(1, 1, 3, 1);
        let mut rings = Vec::new();
        for t in 0..5 {
            let idx = c.advance(0);
            rings.push(idx);
            c.write_k(0, 0, idx, &[t as f32]);
            c.write_v(0, 0, idx, &[10.0 + t as f32]);
        }
        assert_eq!(rings, vec![0, 1, 2, 0, 1]);
        let anchor = rings[3];
        assert_eq!(c.k_row_at(0, 0, anchor, 3, 0)[0], 4.0, "ring 1 was overwritten by token 4");
        assert_eq!(c.k_row_at(0, 0, anchor, 3, 1)[0], 2.0);
        assert_eq!(c.k_row_at(0, 0, anchor, 3, 2)[0], 3.0);
        assert_eq!(c.v_row_at(0, 0, anchor, 3, 2)[0], 13.0);
        // live-head addressing (ring_at) sees tokens {2, 3, 4}
        let got: Vec<f32> = (0..3).map(|j| c.k_row(0, 0, j)[0]).collect();
        assert_eq!(got, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn reset_clears_only_that_slot() {
        let mut c = KvCache::new(2, 2, 4, 1);
        for slot in 0..2 {
            let idx = c.advance(slot);
            for layer in 0..2 {
                c.write_k(slot, layer, idx, &[7.0]);
                c.write_v(slot, layer, idx, &[8.0]);
            }
        }
        c.reset(0);
        assert_eq!(c.len(0), 0);
        assert_eq!(c.len(1), 1);
        assert_eq!(c.k_row(1, 1, 0)[0], 7.0);
    }
}
