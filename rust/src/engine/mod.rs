//! Native packed-weight inference engine — the deployment path the paper's
//! "merge A into the weights, serve with no overhead" story promises.
//!
//! Consumes the integer codes `quant::pack_bits` produces (plus per-group
//! f16 scale/zero) and decodes tokens entirely on the host: no XLA, no
//! artifacts, no fake-quant matmuls. Sub-modules:
//!
//! * [`packed`] — `PackedLinear`/`PackedModel` deployment weight format +
//!   single-file serialization (jsonx header + raw blobs).
//! * [`gemm`]   — fused unpack→dequant→matmul microkernels (w2/w3/w4/w8,
//!   per-group and per-channel), column-striped `std::thread` workers.
//! * [`kernels`] — runtime-specialized stripe kernels: const-generic
//!   `(bits, group)` monomorphization stamped into per-ISA
//!   `#[target_feature]` entry points, selected once per model load by CPU
//!   feature detection (`AQ_KERNEL`/`--kernel` overridable).
//! * [`kv`]     — paged KV cache: refcounted fixed-size pages, per-slot
//!   page tables, copy-on-write prompt-prefix sharing, LRU reclamation.
//! * [`decode`] — host transformer forward (both families) + sampling;
//!   incremental steps are bit-identical to the full-context forward.
//! * [`sched`]  — continuous-batching request queue (admit/evict
//!   mid-decode, chunked prefill under a per-tick token budget).
//!
//! [`Engine`] ties them together behind a prompt-in/text-out API. See
//! `engine/README.md` for the format layout and the parity guarantees.

pub mod decode;
pub mod gemm;
pub mod kernels;
pub mod kv;
pub mod packed;
pub mod sched;

use anyhow::Result;

use crate::model::ParamStore;
use crate::quant::QuantSpec;
use crate::rngx::Pcg32;
use crate::telemetry::Recorder;

pub use decode::{
    forward_full, forward_window, hidden_full, probe_divergence, DivergenceProbe, Sampler,
};
pub use kernels::{KernelInfo, Variant as KernelVariant};
pub use kv::{worst_case_pages_for, KvConfig, KvStats, Reclaim, DEFAULT_PAGE_TOKENS};
pub use packed::{default_probe, LayerCalib, PackedLinear, PackedModel};
pub use sched::{
    Completion, FinishReason, Request, RunStats, SchedConfig, Scheduler, SubmitError,
};

use kv::KvCache;

/// The serving facade: a packed model + a paged KV pool.
pub struct Engine {
    pub model: PackedModel,
    pub max_batch: usize,
    /// Scheduler knobs (prefill chunking, per-tick token budget) applied to
    /// every [`generate`](Engine::generate) call. Greedy completions are
    /// bit-identical for any setting; only latency/throughput change.
    pub sched: SchedConfig,
    /// Telemetry handle cloned into every [`generate`](Engine::generate)
    /// scheduler session. Disabled by default; enabling it cannot change
    /// outputs (observation only — asserted by a parity test).
    pub recorder: Recorder,
    cache: KvCache,
    /// Lower-bit draft variant for cross-bit-width divergence probing
    /// (None = probing off). See [`Engine::enable_draft`].
    draft: Option<PackedModel>,
}

impl Engine {
    /// Build around an existing packed model. `max_batch` bounds the number
    /// of concurrently decoding sequences; KV memory grows lazily in pages
    /// as tokens arrive (bounded per sequence by the attention window
    /// `seq`, shared across sequences with identical prompt prefixes).
    pub fn new(model: PackedModel, max_batch: usize) -> Engine {
        Engine::with_config(model, max_batch, SchedConfig::default())
    }

    /// [`Engine::new`] with explicit scheduler tuning.
    pub fn with_config(model: PackedModel, max_batch: usize, sched: SchedConfig) -> Engine {
        Engine::with_kv_config(model, max_batch, sched, KvConfig::default())
    }

    /// [`Engine::with_config`] with explicit KV paging knobs (page size,
    /// pool bound, sharing, reclamation order). Greedy output is
    /// bit-identical for every setting; only memory/admission change.
    pub fn with_kv_config(
        model: PackedModel,
        max_batch: usize,
        sched: SchedConfig,
        kv: KvConfig,
    ) -> Engine {
        assert!(max_batch > 0);
        let cache = KvCache::with_options(
            max_batch,
            model.cfg.n_layers,
            model.cfg.seq.max(1),
            model.cfg.d_model,
            kv,
        );
        Engine { model, max_batch, sched, recorder: Recorder::default(), cache, draft: None }
    }

    /// Derive a lower-bit draft variant of the serving model (double
    /// quantization of the packed weights — no original f32 store needed)
    /// and turn on cross-bit-width divergence probing for sessions with a
    /// live recorder. Greedy outputs are bit-identical either way (the
    /// probe only observes); memory grows by the draft's packed bytes.
    pub fn enable_draft(&mut self, spec: QuantSpec) {
        self.draft = Some(self.model.requantized(spec));
    }

    /// The divergence-probe draft variant, when enabled.
    pub fn draft(&self) -> Option<&PackedModel> {
        self.draft.as_ref()
    }

    /// Swap the KV paging configuration (drops all cached state). Intended
    /// for construction-time tuning — e.g. the server bounding the pool —
    /// not for mid-flight reconfiguration.
    pub fn configure_kv(&mut self, kv: KvConfig) {
        self.cache = KvCache::with_options(
            self.max_batch,
            self.model.cfg.n_layers,
            self.model.cfg.seq.max(1),
            self.model.cfg.d_model,
            kv,
        );
    }

    /// Quantize + pack a (merged) `ParamStore` and serve it.
    pub fn from_store(ps: &ParamStore, spec: QuantSpec, max_batch: usize) -> Engine {
        Engine::new(PackedModel::from_store(ps, spec), max_batch)
    }

    /// Load a serialized packed model (`PackedModel::save`).
    pub fn load(path: &str, max_batch: usize) -> Result<Engine> {
        Ok(Engine::new(PackedModel::load(path)?, max_batch))
    }

    /// KV bytes currently backed by arena memory (pages are allocated
    /// lazily, so this is live usage, not a preallocated ceiling).
    pub fn kv_bytes(&self) -> usize {
        self.cache.mem_bytes()
    }

    /// Page-pool occupancy and sharing counters.
    pub fn kv_stats(&self) -> KvStats {
        self.cache.stats()
    }

    /// Serve a batch of requests to completion with continuous batching.
    /// Deterministic for a fixed `(requests, sampler, seed, sched)`; greedy
    /// sampling is additionally independent of `max_batch`, the prefill
    /// chunk size, and the token budget. Fails (instead of panicking) on a
    /// malformed request — empty prompt, `max_new == 0` — or a queue cap
    /// overflow, so callers holding user input can map errors to HTTP 4xx.
    pub fn generate(
        &mut self,
        requests: Vec<Request>,
        sampler: Sampler,
        seed: u64,
    ) -> Result<(Vec<Completion>, RunStats)> {
        let mut sched = Scheduler::with_config(self.max_batch, self.sched);
        sched.recorder = self.recorder.clone();
        self.recorder.numeric_install(
            self.model.envelopes(),
            self.model.spec.bits,
            self.draft.as_ref().map(|d| d.spec.bits),
        );
        for r in requests {
            let id = r.id;
            sched.submit(r).map_err(|e| anyhow::anyhow!("request {id}: {e}"))?;
        }
        let mut rng = Pcg32::seeded(seed);
        let out =
            sched.run_drafted(&self.model, self.draft.as_ref(), &mut self.cache, sampler, &mut rng);
        Ok((out, sched.stats))
    }

    /// Split-borrow the model, the divergence draft, and the KV arena — the
    /// serving loop drives its own long-lived [`Scheduler`] session over
    /// them (streaming tokens between ticks) instead of the
    /// run-to-completion `generate` path.
    pub fn parts(&mut self) -> (&PackedModel, Option<&PackedModel>, &mut KvCache) {
        (&self.model, self.draft.as_ref(), &mut self.cache)
    }

    /// Byte-level requests, one per prompt, ids in prompt order — the
    /// tokenizer [`generate_text`](Engine::generate_text) (and the
    /// `generate` CLI) uses.
    pub fn byte_requests(prompts: &[&str], max_new: usize) -> Vec<Request> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: i as u64,
                prompt: p.bytes().map(|b| b as i32).collect(),
                max_new,
                eos: None,
            })
            .collect()
    }

    /// Byte-level detokenization of a completion (lossy on invalid UTF-8) —
    /// the inverse of [`byte_requests`](Engine::byte_requests).
    pub fn completion_text(c: &Completion) -> String {
        let bytes: Vec<u8> = c.tokens.iter().map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Byte-level text convenience: one completion string per prompt.
    pub fn generate_text(
        &mut self,
        prompts: &[&str],
        max_new: usize,
        sampler: Sampler,
        seed: u64,
    ) -> Result<(Vec<String>, RunStats)> {
        let reqs = Engine::byte_requests(prompts, max_new);
        let (completions, stats) = self.generate(reqs, sampler, seed)?;
        Ok((completions.iter().map(Engine::completion_text).collect(), stats))
    }

    /// One-line memory summary: packed vs fp16 linear bytes + KV pool.
    pub fn memory_report(&self) -> String {
        let packed = self.model.packed_bytes();
        let fp16 = self.model.fp16_linear_bytes();
        let ks = self.kv_stats();
        format!(
            "{}: linears {} packed ({}) vs {} fp16 — {:.2}x smaller; \
             kv pool {} ({} pages × {} tokens)",
            self.model.cfg.name,
            crate::util::human_count(packed as f64),
            self.model.spec.label(16),
            crate::util::human_count(fp16 as f64),
            fp16 as f64 / packed as f64,
            crate::util::human_count(self.kv_bytes() as f64),
            ks.pages_allocated,
            ks.page_tokens,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn engine_generates_deterministically() {
        let ps = zoo::seeded_store("opt-s1", 42).unwrap();
        let mut e1 = Engine::from_store(&ps, QuantSpec::new(4, 128), 4);
        let mut e2 = Engine::from_store(&ps, QuantSpec::new(4, 128), 4);
        let (t1, s1) = e1.generate_text(&["the bani ", "a masi "], 8, Sampler::Greedy, 1).unwrap();
        let (t2, _) = e2.generate_text(&["the bani ", "a masi "], 8, Sampler::Greedy, 1).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), 2);
        // count tokens, not String bytes — non-ASCII byte-tokens widen lossily
        assert_eq!(s1.tokens_generated, 16);
        assert!(s1.peak_batch <= 2);
    }

    #[test]
    fn engine_eos_and_max_new() {
        let ps = zoo::seeded_store("ll-s1", 42).unwrap();
        let mut e = Engine::from_store(&ps, QuantSpec::new(4, 64), 2);
        // find what greedy produces first, then use it as eos
        let (c, _) = e
            .generate(
                vec![Request { id: 0, prompt: vec![10, 20, 30], max_new: 4, eos: None }],
                Sampler::Greedy,
                0,
            )
            .unwrap();
        assert_eq!(c[0].tokens.len(), 4);
        assert_eq!(c[0].finish, FinishReason::MaxNew);
        let first = c[0].tokens[0];
        let (c2, _) = e
            .generate(
                vec![Request { id: 0, prompt: vec![10, 20, 30], max_new: 4, eos: Some(first) }],
                Sampler::Greedy,
                0,
            )
            .unwrap();
        assert_eq!(c2[0].tokens, vec![first], "eos must stop generation early");
        assert_eq!(c2[0].finish, FinishReason::Eos);
    }

    #[test]
    fn opt_position_cap_enforced() {
        let ps = zoo::seeded_store("opt-s1", 42).unwrap();
        let mut e = Engine::from_store(&ps, QuantSpec::new(4, 128), 1);
        let seq = e.model.cfg.seq;
        // ask for more tokens than the positional table allows
        let (c, _) = e
            .generate(
                vec![Request { id: 7, prompt: vec![1, 2, 3], max_new: seq * 2, eos: None }],
                Sampler::Greedy,
                0,
            )
            .unwrap();
        assert_eq!(c.len(), 1);
        // positions 0..seq-1 are steppable; the first two steps are pure
        // prefill, every later one samples -> seq - 2 generated tokens
        assert_eq!(c[0].tokens.len(), seq - 2, "must stop at the table edge");
        assert_eq!(c[0].finish, FinishReason::PosCapacity, "truncation must be surfaced");
    }
}
