//! Deployment weight format: bit-packed integer codes + per-group f16
//! scale/zero-point, assembled from a `ParamStore` + a merged `QuantSpec`.
//!
//! This is the storage layout the paper's "no inference overhead" claim
//! cashes out to: after the affine matrix is merged into the weights, a
//! linear is just `pack_bits(codes)` + 2×f16 per (group, col) — the same
//! byte counts `quant::weight_bytes` models for the Pareto figure. A
//! `PackedModel` holds every quantized linear in that form plus the f32
//! leftovers (norm gains, biases, embeddings) and serializes to a single
//! file: jsonx header + raw little-endian blobs.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::jsonx::{self, Value};
use crate::model::{ModelConfig, ParamStore};
use crate::quant::{pack_bits, quantize_codes, QuantSpec};
use crate::tensor::{numel, Tensor};

use super::gemm::{packed_gemm_with, PackedWeight};
use super::kernels::{self, Kernel};

// ------------------------------------------------------------------- f16
// IEEE 754 binary16 conversion (the `half` crate is not vendored offline).
// Round-to-nearest-even, subnormals handled; validated bit-exact against
// numpy float16 over normal/subnormal/overflow ranges.

pub fn f16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 255 {
        // inf / nan (nan keeps a payload bit)
        return sign | 0x7c00 | if man != 0 { 0x200 } else { 0 };
    }
    let unb = exp - 127;
    if unb >= 16 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unb >= -14 {
        // normal half
        let mut hexp = (unb + 15) as u32;
        let mut hman = man >> 13;
        let rnd = man & 0x1fff;
        if rnd > 0x1000 || (rnd == 0x1000 && (hman & 1) == 1) {
            hman += 1;
            if hman == 0x400 {
                hman = 0;
                hexp += 1;
                if hexp >= 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((hexp as u16) << 10) | hman as u16;
    }
    if unb >= -25 {
        // subnormal half: value = (man|hidden) * 2^(unb-23); unit is 2^-24
        let man_full = man | 0x0080_0000;
        let s = (-unb - 1) as u32; // in [14, 24]
        let mut hman = man_full >> s;
        let rem = man_full & ((1u32 << s) - 1);
        let half = 1u32 << (s - 1);
        if rem > half || (rem == half && (hman & 1) == 1) {
            hman += 1; // may carry into the smallest normal — encoding is continuous
        }
        return sign | hman as u16;
    }
    sign // underflow to signed zero
}

pub fn f16_decode(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e: i32 = 113; // 127 - 15 + 1
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------- PackedLinear

/// One quantized `(din, dout)` linear in deployment form.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub name: String,
    pub din: usize,
    pub dout: usize,
    pub spec: QuantSpec,
    /// b-bit codes, `pack_bits` layout over the row-major (din, dout) grid.
    pub packed: Vec<u8>,
    /// f16 bits per (group, col) — the serialized truth.
    pub scales16: Vec<u16>,
    pub zps16: Vec<u16>,
    /// f32 decode of the params, kept hot for the GEMM.
    scales: Vec<f32>,
    zps: Vec<f32>,
    /// Dispatch kernel for this (bits, group) shape, resolved once at
    /// pack/load time under the process-wide ISA selection
    /// (`engine::kernels`) — the hot path never re-resolves.
    kernel: Kernel,
}

impl PackedLinear {
    /// Quantize + pack a weight tensor. The integer codes are exactly
    /// `quant::quantize_codes`; only the scale/zero storage narrows to f16.
    pub fn pack(name: &str, w: &Tensor, spec: QuantSpec) -> PackedLinear {
        let (din, dout) = w.dims2();
        let (codes, params, _) = quantize_codes(w, spec, None);
        let scales16: Vec<u16> = params.iter().map(|p| f16_encode(p.scale)).collect();
        let zps16: Vec<u16> = params.iter().map(|p| f16_encode(p.zp)).collect();
        let scales = scales16.iter().map(|&h| f16_decode(h)).collect();
        let zps = zps16.iter().map(|&h| f16_decode(h)).collect();
        PackedLinear {
            name: name.to_string(),
            din,
            dout,
            spec,
            packed: pack_bits(&codes, spec.bits),
            scales16,
            zps16,
            scales,
            zps,
            kernel: kernels::select(spec.bits, spec.group_len(din)),
        }
    }

    /// Rebuild from serialized parts (decodes the hot f32 params).
    pub fn from_parts(
        name: String,
        din: usize,
        dout: usize,
        spec: QuantSpec,
        packed: Vec<u8>,
        scales16: Vec<u16>,
        zps16: Vec<u16>,
    ) -> Result<PackedLinear> {
        let nparams = (din / spec.group_len(din)) * dout;
        if scales16.len() != nparams || zps16.len() != nparams {
            bail!("{name}: {} params, expected {nparams}", scales16.len());
        }
        let want_bytes = (din * dout * spec.bits as usize).div_ceil(8);
        if packed.len() != want_bytes {
            bail!("{name}: {} packed bytes, expected {want_bytes}", packed.len());
        }
        let scales = scales16.iter().map(|&h| f16_decode(h)).collect();
        let zps = zps16.iter().map(|&h| f16_decode(h)).collect();
        let kernel = kernels::select(spec.bits, spec.group_len(din));
        Ok(PackedLinear { name, din, dout, spec, packed, scales16, zps16, scales, zps, kernel })
    }

    /// The f16-decoded (scales, zero-points), row-major (ngroups, dout).
    pub fn params(&self) -> (&[f32], &[f32]) {
        (&self.scales, &self.zps)
    }

    fn weight(&self) -> PackedWeight<'_> {
        PackedWeight {
            packed: &self.packed,
            bits: self.spec.bits,
            din: self.din,
            dout: self.dout,
            group_len: self.spec.group_len(self.din),
            scales: &self.scales,
            zps: &self.zps,
        }
    }

    /// `y (m, dout) = x (m, din) @ dequant(W)` through the fused kernel
    /// this linear resolved at pack/load time.
    pub fn matmul(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * self.dout];
        packed_gemm_with(self.kernel, &self.weight(), x, &mut y, m);
        y
    }

    /// Accumulating variant: `y += x @ dequant(W)`.
    pub fn matmul_into(&self, x: &[f32], y: &mut [f32], m: usize) {
        packed_gemm_with(self.kernel, &self.weight(), x, y, m);
    }

    /// Name of the dispatch kernel the matmuls ride, e.g. `"avx2/w4g128"`.
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name
    }

    /// Re-resolve dispatch onto an explicit ISA variant (tests/tools; falls
    /// back to scalar when the variant is unavailable on this CPU, so the
    /// result is always runnable). Outputs are bit-identical either way.
    pub fn set_kernel(&mut self, variant: kernels::Variant) {
        self.kernel = kernels::select_for(variant, self.spec.bits, self.spec.group_len(self.din));
    }

    /// Quantization error vs the pre-quant reference weights: `(sum of
    /// squared error, max absolute error)` over all elements, streamed
    /// through the packed codes (pack-time calibration; not a serve path).
    pub fn quant_error(&self, reference: &[f32]) -> (f64, f32) {
        super::gemm::weight_error(&self.weight(), reference)
    }

    /// Dense f32 dequantization (reference/tests; never on the serve path).
    pub fn dequantize(&self) -> Tensor {
        let g = self.spec.group_len(self.din);
        let mut out = Tensor::zeros(&[self.din, self.dout]);
        let mut crow = vec![0u8; self.dout];
        for k in 0..self.din {
            super::gemm::unpack_seg(&self.packed, self.spec.bits, k * self.dout, &mut crow);
            let gi = k / g;
            for j in 0..self.dout {
                out.data[k * self.dout + j] =
                    (crow[j] as f32 - self.zps[gi * self.dout + j]) * self.scales[gi * self.dout + j];
            }
        }
        out
    }

    /// Deployment bytes (codes + f16 params) — matches `quant::weight_bytes`.
    pub fn bytes(&self) -> usize {
        self.packed.len() + 2 * (self.scales16.len() + self.zps16.len())
    }
}

// ----------------------------------------------------------- PackedModel

/// One transformer block: quantized linears + f32 leftovers (norm params,
/// biases) in block-layout order.
#[derive(Clone)]
pub struct PackedBlock {
    pub linears: Vec<PackedLinear>,
    pub f32s: Vec<(String, Vec<f32>)>,
    index: HashMap<String, usize>,
}

impl PackedBlock {
    fn new(linears: Vec<PackedLinear>, f32s: Vec<(String, Vec<f32>)>) -> PackedBlock {
        let index = linears.iter().enumerate().map(|(i, l)| (l.name.clone(), i)).collect();
        PackedBlock { linears, f32s, index }
    }

    pub fn linear(&self, name: &str) -> &PackedLinear {
        &self.linears[*self.index.get(name).unwrap_or_else(|| panic!("no linear {name:?}"))]
    }

    pub fn f32(&self, name: &str) -> &[f32] {
        self.f32s
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
            .unwrap_or_else(|| panic!("no f32 tensor {name:?}"))
    }
}

/// Per-layer calibration artifact baked into the AQPM header at pack time:
/// activation envelopes from a deterministic probe forward (the
/// residual-stream input of the block) plus the layer's aggregate weight
/// quantization error. The serving-time drift detector
/// (`telemetry/numeric.rs`) compares live sampled stats against these.
/// `act_count == 0` marks a missing envelope (e.g. a pre-calibration AQPM
/// file) — such layers report `no_data` rather than drift.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerCalib {
    /// Max |x| over the layer's input activations during calibration.
    pub act_absmax: f32,
    pub act_mean: f32,
    pub act_var: f32,
    /// Activation elements the calibration probe observed.
    pub act_count: u64,
    /// Mean squared dequant-vs-reference error over the layer's quantized
    /// linears (all elements pooled).
    pub weight_mse: f32,
    /// Max absolute dequant-vs-reference weight error in the layer.
    pub weight_max_abs: f32,
}

/// A whole model in deployment form: f32 globals (embeddings + final norm)
/// plus per-block packed linears. Built from a (merged) `ParamStore`.
#[derive(Clone)]
pub struct PackedModel {
    pub cfg: ModelConfig,
    pub spec: QuantSpec,
    pub globals: Vec<(String, Tensor)>,
    pub blocks: Vec<PackedBlock>,
    /// One [`LayerCalib`] per block (may be empty for legacy AQPM files).
    pub calib: Vec<LayerCalib>,
}

/// Deterministic calibration probe: a fixed short pseudo-sequence inside
/// the vocab (and the positional table, for the opt family). Every pack of
/// the same weights bakes identical envelopes.
pub fn default_probe(cfg: &ModelConfig) -> Vec<i32> {
    let v = cfg.vocab.min(256);
    let n = 48usize.min(cfg.seq.saturating_sub(1)).max(8);
    (0..n).map(|i| ((i * 37 + 11) % v) as i32).collect()
}

impl PackedModel {
    /// Quantize + pack every linear of `ps` under `spec`. `ps` is expected
    /// to be the *merged* store (affine transforms already folded into the
    /// weights) — packing is plain per-group RTN on whatever it holds,
    /// exactly mirroring the fake-quant the AOT graphs apply.
    pub fn from_store(ps: &ParamStore, spec: QuantSpec) -> PackedModel {
        let cfg = ps.cfg.clone();
        let qnames: Vec<&str> = cfg.quantized_weights().iter().map(|&(n, _, _)| n).collect();
        let globals = ps
            .globals_layout
            .entries
            .iter()
            .map(|(name, _, _)| (name.clone(), ps.globals_layout.tensor(ps.globals(), name)))
            .collect();
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        let mut calib = Vec::with_capacity(cfg.n_layers);
        for bi in 0..cfg.n_layers {
            let mut linears = Vec::new();
            let mut f32s = Vec::new();
            let (mut sum_sq, mut n_elems, mut max_abs) = (0f64, 0u64, 0f32);
            for (name, _, _) in &ps.block_layout.entries {
                let t = ps.block_tensor(bi, name);
                if qnames.contains(&name.as_str()) {
                    let pl = PackedLinear::pack(name, &t, spec);
                    let (sq, ma) = pl.quant_error(&t.data);
                    sum_sq += sq;
                    n_elems += t.data.len() as u64;
                    max_abs = max_abs.max(ma);
                    linears.push(pl);
                } else {
                    f32s.push((name.clone(), t.data));
                }
            }
            calib.push(LayerCalib {
                weight_mse: if n_elems > 0 { (sum_sq / n_elems as f64) as f32 } else { 0.0 },
                weight_max_abs: max_abs,
                ..Default::default()
            });
            blocks.push(PackedBlock::new(linears, f32s));
        }
        let mut pm = PackedModel { cfg, spec, globals, blocks, calib };
        let probe = default_probe(&pm.cfg);
        pm.bake_calibration(&probe);
        pm
    }

    /// Fill the activation-envelope half of [`PackedModel::calib`] by
    /// running a forward over `probe` and folding the residual-stream input
    /// of every layer into a streaming accumulator. Deterministic for a
    /// fixed probe; allocates its own scratch KV cache (no serving state).
    pub fn bake_calibration(&mut self, probe: &[i32]) {
        let stats = super::decode::layer_input_stats(self, probe);
        self.calib.resize(stats.len().max(self.calib.len()), LayerCalib::default());
        for (c, w) in self.calib.iter_mut().zip(&stats) {
            c.act_absmax = w.absmax();
            c.act_mean = w.mean() as f32;
            c.act_var = w.var() as f32;
            c.act_count = w.count();
        }
    }

    /// The baked calibration as telemetry envelopes (empty for legacy
    /// files) — what `Recorder::numeric_install` consumes at session start.
    pub fn envelopes(&self) -> Vec<crate::telemetry::numeric::Envelope> {
        self.calib
            .iter()
            .map(|c| crate::telemetry::numeric::Envelope {
                absmax: c.act_absmax,
                mean: c.act_mean,
                var: c.act_var,
                count: c.act_count,
                weight_mse: c.weight_mse,
                weight_max_abs: c.weight_max_abs,
            })
            .collect()
    }

    /// Re-quantize every packed linear at another spec from its
    /// *dequantized* weights (double quantization) — the self-contained way
    /// to derive a lower-bit draft variant from a deployed model, with no
    /// access to the original f32 store (works on loaded AQPM files too).
    /// Weight-error calib is recomputed against the serving dequant (i.e.
    /// it measures the *additional* error of the draft bit-width);
    /// activation envelopes are inherited.
    pub fn requantized(&self, spec: QuantSpec) -> PackedModel {
        let mut blocks = Vec::with_capacity(self.blocks.len());
        let mut calib = Vec::with_capacity(self.blocks.len());
        for (bi, b) in self.blocks.iter().enumerate() {
            let mut linears = Vec::with_capacity(b.linears.len());
            let (mut sum_sq, mut n_elems, mut max_abs) = (0f64, 0u64, 0f32);
            for l in &b.linears {
                let dq = l.dequantize();
                let pl = PackedLinear::pack(&l.name, &dq, spec);
                let (sq, ma) = pl.quant_error(&dq.data);
                sum_sq += sq;
                n_elems += dq.data.len() as u64;
                max_abs = max_abs.max(ma);
                linears.push(pl);
            }
            let base = self.calib.get(bi).copied().unwrap_or_default();
            calib.push(LayerCalib {
                weight_mse: if n_elems > 0 { (sum_sq / n_elems as f64) as f32 } else { 0.0 },
                weight_max_abs: max_abs,
                ..base
            });
            blocks.push(PackedBlock::new(linears, b.f32s.clone()));
        }
        PackedModel { cfg: self.cfg.clone(), spec, globals: self.globals.clone(), blocks, calib }
    }

    /// Dispatch kernel name of the serving linears (they share one spec, so
    /// one kernel), e.g. `"avx2/w4g128"`. Falls back to resolving the spec
    /// directly when the model has no quantized linears.
    pub fn kernel_name(&self) -> &'static str {
        self.blocks
            .iter()
            .find_map(|b| b.linears.first())
            .map(|l| l.kernel_name())
            .unwrap_or_else(|| kernels::select(self.spec.bits, self.spec.group).name)
    }

    /// Force every linear onto an explicit kernel variant (tests, `doctor`,
    /// benches; scalar fallback when unavailable). Greedy output is
    /// bit-identical across variants — asserted by the engine test suite.
    pub fn force_kernel(&mut self, variant: kernels::Variant) {
        for b in &mut self.blocks {
            for l in &mut b.linears {
                l.set_kernel(variant);
            }
        }
    }

    pub fn global(&self, name: &str) -> &Tensor {
        self.globals
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
            .unwrap_or_else(|| panic!("no global {name:?}"))
    }

    pub fn has_global(&self, name: &str) -> bool {
        self.globals.iter().any(|(n, _)| n == name)
    }

    /// Deployment bytes of the quantized linears.
    pub fn packed_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.linears.iter().map(|l| l.bytes()).sum::<usize>()).sum()
    }

    /// fp16 bytes the same linears would occupy unquantized.
    pub fn fp16_linear_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.linears.iter().map(|l| 2 * l.din * l.dout).sum::<usize>())
            .sum()
    }

    // ------------------------------------------------------ serialization
    // `AQPM1\n` + u32 header length + jsonx header + concatenated blobs.
    // The header lists every tensor with its blob offset/length; packed
    // linears carry (bits, group). All blobs little-endian.

    pub fn save(&self, path: &str) -> Result<()> {
        crate::util::ensure_parent(path)?;
        let mut blobs: Vec<u8> = Vec::new();
        let mut entries: Vec<Value> = Vec::new();
        let push_blob = |blobs: &mut Vec<u8>, bytes: &[u8]| -> (usize, usize) {
            let off = blobs.len();
            blobs.extend_from_slice(bytes);
            (off, bytes.len())
        };
        let tensor_entry =
            |name: &str, block: i64, kind: &str, shape: &[usize], off: usize, len: usize| {
                jsonx::obj(vec![
                    ("name", jsonx::s(name)),
                    ("block", jsonx::num(block as f64)),
                    ("kind", jsonx::s(kind)),
                    (
                        "shape",
                        Value::Arr(shape.iter().map(|&d| jsonx::num(d as f64)).collect()),
                    ),
                    ("offset", jsonx::num(off as f64)),
                    ("len", jsonx::num(len as f64)),
                ])
            };
        for (name, t) in &self.globals {
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            let (off, len) = push_blob(&mut blobs, &bytes);
            entries.push(tensor_entry(name, -1, "f32", &t.shape, off, len));
        }
        for (bi, block) in self.blocks.iter().enumerate() {
            for (name, data) in &block.f32s {
                let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
                let (off, len) = push_blob(&mut blobs, &bytes);
                entries.push(tensor_entry(name, bi as i64, "f32", &[data.len()], off, len));
            }
            for l in &block.linears {
                let (coff, clen) = push_blob(&mut blobs, &l.packed);
                let sbytes: Vec<u8> = l.scales16.iter().flat_map(|v| v.to_le_bytes()).collect();
                let (soff, slen) = push_blob(&mut blobs, &sbytes);
                let zbytes: Vec<u8> = l.zps16.iter().flat_map(|v| v.to_le_bytes()).collect();
                let (zoff, zlen) = push_blob(&mut blobs, &zbytes);
                entries.push(jsonx::obj(vec![
                    ("name", jsonx::s(&l.name)),
                    ("block", jsonx::num(bi as f64)),
                    ("kind", jsonx::s("packed")),
                    (
                        "shape",
                        Value::Arr(vec![
                            jsonx::num(l.din as f64),
                            jsonx::num(l.dout as f64),
                        ]),
                    ),
                    ("bits", jsonx::num(l.spec.bits as f64)),
                    ("group", jsonx::num(l.spec.group as f64)),
                    ("offset", jsonx::num(coff as f64)),
                    ("len", jsonx::num(clen as f64)),
                    ("scales_offset", jsonx::num(soff as f64)),
                    ("scales_len", jsonx::num(slen as f64)),
                    ("zps_offset", jsonx::num(zoff as f64)),
                    ("zps_len", jsonx::num(zlen as f64)),
                ]));
            }
        }
        let cfg = &self.cfg;
        let header = jsonx::obj(vec![
            ("format", jsonx::s("affinequant-packed-v1")),
            ("name", jsonx::s(&cfg.name)),
            ("family", jsonx::s(&cfg.family)),
            ("d_model", jsonx::num(cfg.d_model as f64)),
            ("n_heads", jsonx::num(cfg.n_heads as f64)),
            ("n_layers", jsonx::num(cfg.n_layers as f64)),
            ("d_ff", jsonx::num(cfg.d_ff as f64)),
            ("vocab", jsonx::num(cfg.vocab as f64)),
            ("seq", jsonx::num(cfg.seq as f64)),
            ("batch", jsonx::num(cfg.batch as f64)),
            ("train_batch", jsonx::num(cfg.train_batch as f64)),
            ("head_dim", jsonx::num(cfg.head_dim as f64)),
            ("params", jsonx::num(cfg.params as f64)),
            ("bits", jsonx::num(self.spec.bits as f64)),
            ("group", jsonx::num(self.spec.group as f64)),
            (
                "calib",
                Value::Arr(
                    self.calib
                        .iter()
                        .map(|c| {
                            jsonx::obj(vec![
                                ("act_absmax", jsonx::num(c.act_absmax as f64)),
                                ("act_mean", jsonx::num(c.act_mean as f64)),
                                ("act_var", jsonx::num(c.act_var as f64)),
                                ("act_count", jsonx::num(c.act_count as f64)),
                                ("weight_mse", jsonx::num(c.weight_mse as f64)),
                                ("weight_max_abs", jsonx::num(c.weight_max_abs as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("tensors", Value::Arr(entries)),
        ]);
        let htext = jsonx::emit(&header);
        let mut out = Vec::with_capacity(10 + htext.len() + blobs.len());
        out.extend_from_slice(b"AQPM1\n");
        out.extend_from_slice(&(htext.len() as u32).to_le_bytes());
        out.extend_from_slice(htext.as_bytes());
        out.extend_from_slice(&blobs);
        std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<PackedModel> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if !bytes.starts_with(b"AQPM1\n") {
            bail!("{path}: bad packed-model magic");
        }
        if bytes.len() < 10 {
            bail!("{path}: truncated packed-model header");
        }
        let hlen = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        if bytes.len() < 10 + hlen {
            bail!("{path}: header length {hlen} exceeds file size {}", bytes.len());
        }
        let header = jsonx::parse(
            std::str::from_utf8(&bytes[10..10 + hlen]).context("header utf8")?,
        )
        .map_err(|e| anyhow::anyhow!(e))?;
        let blobs = &bytes[10 + hlen..];
        let g = |k: &str| header.req(k).as_usize();
        let cfg = ModelConfig {
            name: header.req("name").as_str().to_string(),
            family: header.req("family").as_str().to_string(),
            d_model: g("d_model"),
            n_heads: g("n_heads"),
            n_layers: g("n_layers"),
            d_ff: g("d_ff"),
            vocab: g("vocab"),
            seq: g("seq"),
            batch: g("batch"),
            train_batch: g("train_batch"),
            head_dim: g("head_dim"),
            params: g("params"),
        };
        let spec = QuantSpec::new(g("bits") as u32, g("group"));
        // pre-calibration AQPM files have no "calib" array; load them with
        // empty calib (every layer reports no_data, never drift)
        let calib: Vec<LayerCalib> = match header.get("calib") {
            Some(arr) => arr
                .as_arr()
                .iter()
                .map(|c| LayerCalib {
                    act_absmax: c.req("act_absmax").as_f64() as f32,
                    act_mean: c.req("act_mean").as_f64() as f32,
                    act_var: c.req("act_var").as_f64() as f32,
                    act_count: c.req("act_count").as_f64() as u64,
                    weight_mse: c.req("weight_mse").as_f64() as f32,
                    weight_max_abs: c.req("weight_max_abs").as_f64() as f32,
                })
                .collect(),
            None => Vec::new(),
        };
        fn blob<'a>(blobs: &'a [u8], path: &str, off: usize, len: usize) -> Result<&'a [u8]> {
            let end = off.checked_add(len).filter(|&e| e <= blobs.len());
            match end {
                Some(e) => Ok(&blobs[off..e]),
                None => bail!("{path}: blob [{off}, {off}+{len}) out of range"),
            }
        }
        fn f32_blob(blobs: &[u8], path: &str, off: usize, len: usize) -> Result<Vec<f32>> {
            let b = blob(blobs, path, off, len)?;
            if len % 4 != 0 {
                bail!("{path}: f32 blob len {len} not a multiple of 4");
            }
            Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
        }
        fn u16_blob(blobs: &[u8], path: &str, off: usize, len: usize) -> Result<Vec<u16>> {
            let b = blob(blobs, path, off, len)?;
            if len % 2 != 0 {
                bail!("{path}: u16 blob len {len} not a multiple of 2");
            }
            Ok(b.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
        }

        let mut globals = Vec::new();
        let mut block_linears: Vec<Vec<PackedLinear>> = vec![Vec::new(); cfg.n_layers];
        let mut block_f32s: Vec<Vec<(String, Vec<f32>)>> = vec![Vec::new(); cfg.n_layers];
        for e in header.req("tensors").as_arr() {
            let name = e.req("name").as_str().to_string();
            let bi = e.req("block").as_f64() as i64;
            let kind = e.req("kind").as_str();
            let shape = e.req("shape").usize_arr();
            let off = e.req("offset").as_usize();
            let len = e.req("len").as_usize();
            match kind {
                "f32" => {
                    let data = f32_blob(blobs, path, off, len)?;
                    if data.len() != numel(&shape) {
                        bail!("{path}: {name} numel mismatch");
                    }
                    if bi < 0 {
                        globals.push((name, Tensor::new(shape, data)));
                    } else if (bi as usize) < cfg.n_layers {
                        block_f32s[bi as usize].push((name, data));
                    } else {
                        bail!("{path}: {name} bad block index {bi}");
                    }
                }
                "packed" => {
                    if bi < 0 || bi as usize >= cfg.n_layers {
                        bail!("{path}: {name} bad block index {bi}");
                    }
                    if shape.len() != 2 {
                        bail!("{path}: {name} packed shape must be 2-D, got {shape:?}");
                    }
                    let lspec = QuantSpec::new(
                        e.req("bits").as_usize() as u32,
                        e.req("group").as_usize(),
                    );
                    let packed = blob(blobs, path, off, len)?.to_vec();
                    let scales16 = u16_blob(
                        blobs,
                        path,
                        e.req("scales_offset").as_usize(),
                        e.req("scales_len").as_usize(),
                    )?;
                    let zps16 = u16_blob(
                        blobs,
                        path,
                        e.req("zps_offset").as_usize(),
                        e.req("zps_len").as_usize(),
                    )?;
                    block_linears[bi as usize].push(PackedLinear::from_parts(
                        name, shape[0], shape[1], lspec, packed, scales16, zps16,
                    )?);
                }
                other => bail!("{path}: unknown tensor kind {other:?}"),
            }
        }
        let blocks = block_linears
            .into_iter()
            .zip(block_f32s)
            .map(|(l, f)| PackedBlock::new(l, f))
            .collect();
        Ok(PackedModel { cfg, spec, globals, blocks, calib })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::quant::quant_dequant;
    use crate::rngx::Pcg32;

    #[test]
    fn f16_roundtrip_and_edges() {
        for &v in &[0.0f32, 1.0, -2.5, 0.000061, 65504.0, 1e-7, -1e-7, 3.14159] {
            let dec = f16_decode(f16_encode(v));
            let tol = (v.abs() * 1e-3).max(6.2e-8);
            assert!((dec - v).abs() <= tol, "{v} -> {dec}");
        }
        assert_eq!(f16_decode(f16_encode(0.0)), 0.0);
        assert_eq!(f16_encode(70000.0), 0x7c00); // overflow -> +inf
        assert_eq!(f16_encode(-70000.0), 0xfc00);
        assert_eq!(f16_encode(1e-12), 0); // underflow -> +0
        assert!(f16_decode(0x7c00).is_infinite());
        assert!(f16_decode(0x7e00).is_nan());
        // exact integers survive (zero-points are integer-valued <= 255)
        for i in 0..=255u16 {
            assert_eq!(f16_decode(f16_encode(i as f32)), i as f32);
        }
    }

    #[test]
    fn packed_linear_tracks_fake_quant() {
        let mut rng = Pcg32::seeded(11);
        for (bits, group) in [(2u32, 64usize), (3, 64), (4, 128), (4, 0)] {
            let spec = QuantSpec::new(bits, group);
            let w = Tensor::randn(&[128, 96], 1.0, &mut rng);
            let pl = PackedLinear::pack("w", &w, spec);
            let dq = pl.dequantize();
            let fq = quant_dequant(&w, spec, None);
            // only difference is f16 narrowing of scale/zp
            let qmax = spec.qmax();
            let (_, params, _) = crate::quant::quantize_codes(&w, spec, None);
            for i in 0..128 {
                for j in 0..96 {
                    let g = spec.group_len(128);
                    let s = params[(i / g) * 96 + j].scale;
                    let tol = s * qmax * 1.5e-3 + 1e-4;
                    let d = (dq.at2(i, j) - fq.at2(i, j)).abs();
                    assert!(d <= tol, "b{bits}g{group} ({i},{j}): {d} > {tol}");
                }
            }
        }
    }

    #[test]
    fn bytes_match_memory_model() {
        let mut rng = Pcg32::seeded(12);
        let w = Tensor::randn(&[256, 128], 1.0, &mut rng);
        for (bits, group) in [(2u32, 64usize), (3, 128), (4, 0)] {
            let spec = QuantSpec::new(bits, group);
            let pl = PackedLinear::pack("w", &w, spec);
            assert_eq!(pl.bytes(), crate::quant::weight_bytes(256, 128, spec));
        }
    }

    #[test]
    fn model_save_load_roundtrip() {
        let ps = zoo::seeded_store("ll-s1", 7).unwrap();
        let pm = PackedModel::from_store(&ps, QuantSpec::new(3, 64));
        let path = "/tmp/aq_test_packed.bin";
        pm.save(path).unwrap();
        let pm2 = PackedModel::load(path).unwrap();
        std::fs::remove_file(path).ok();
        assert_eq!(pm2.cfg.name, "ll-s1");
        assert_eq!(pm2.spec, pm.spec);
        // baked calibration roundtrips (floats travel through jsonx text,
        // so compare with a relative tolerance; counts are exact)
        assert_eq!(pm2.calib.len(), pm.calib.len());
        assert!(!pm.calib.is_empty(), "from_store must bake calibration");
        let close = |a: f32, b: f32| (a - b).abs() <= 1e-4 * a.abs().max(1.0);
        for (c1, c2) in pm.calib.iter().zip(&pm2.calib) {
            assert!(c1.act_count > 0, "probe forward must observe activations");
            assert_eq!(c1.act_count, c2.act_count);
            assert!(close(c1.act_absmax, c2.act_absmax));
            assert!(close(c1.act_mean, c2.act_mean));
            assert!(close(c1.act_var, c2.act_var));
            assert!(close(c1.weight_mse, c2.weight_mse));
            assert!(close(c1.weight_max_abs, c2.weight_max_abs));
            assert!(c1.weight_mse > 0.0, "3-bit quantization has nonzero weight error");
        }
        assert_eq!(pm2.globals.len(), pm.globals.len());
        for ((n1, t1), (n2, t2)) in pm.globals.iter().zip(&pm2.globals) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        for (b1, b2) in pm.blocks.iter().zip(&pm2.blocks) {
            assert_eq!(b1.f32s, b2.f32s);
            for (l1, l2) in b1.linears.iter().zip(&b2.linears) {
                assert_eq!(l1.name, l2.name);
                assert_eq!(l1.packed, l2.packed);
                assert_eq!(l1.scales16, l2.scales16);
                assert_eq!(l1.zps16, l2.zps16);
                // matmul output is bit-identical after a save/load cycle
                let mut rng = Pcg32::seeded(1);
                let x: Vec<f32> = (0..l1.din).map(|_| rng.normal() as f32).collect();
                assert_eq!(l1.matmul(&x, 1), l2.matmul(&x, 1));
            }
        }
        assert_eq!(pm.packed_bytes(), pm2.packed_bytes());
        assert!(pm.packed_bytes() * 4 < pm.fp16_linear_bytes(),
            "w3g64 must be >4x smaller than fp16");
    }
}
