//! Continuous-batching request scheduler (Orca-style token-level batching).
//!
//! Requests queue up, get admitted into free KV-cache slots *mid-decode*,
//! and are evicted the step they finish — the batch composition changes
//! every step, exactly like a multi-user serving loop. Prefill and decode
//! are unified: an admitted sequence first streams its prompt tokens
//! through [`decode::step`] (outputs ignored) one per scheduler tick, then
//! switches to feeding back sampled tokens.
//!
//! Because the fused GEMM and attention are row-independent, a sequence's
//! output stream does not depend on which other sequences share its steps —
//! `rust/tests/engine.rs` asserts completions are identical for
//! `max_batch = 1` and `max_batch = N`.

use std::collections::VecDeque;

use crate::rngx::Pcg32;

use super::decode::{self, sample_row, Sampler, StepInput};
use super::kv::KvCache;
use super::packed::PackedModel;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level; must be non-empty).
    pub prompt: Vec<i32>,
    /// Maximum generated tokens (beyond the prompt).
    pub max_new: usize,
    /// Stop early when this token is produced (it is kept in the output).
    pub eos: Option<i32>,
}

/// A finished request: the generated continuation (prompt excluded).
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler ticks this sequence was live for (prefill + decode).
    pub steps: usize,
}

struct Active {
    req: Request,
    slot: usize,
    /// Prompt tokens already fed.
    fed: usize,
    /// Next absolute position.
    pos: usize,
    generated: Vec<i32>,
    last_sampled: i32,
    steps: usize,
}

/// Aggregate serving statistics for one `run`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub scheduler_steps: usize,
    /// Total tokens pushed through the model (prefill + decode).
    pub tokens_processed: usize,
    /// Generated tokens only.
    pub tokens_generated: usize,
    pub peak_batch: usize,
}

pub struct Scheduler {
    max_batch: usize,
    pending: VecDeque<Request>,
    active: Vec<Option<Active>>,
    finished: Vec<Completion>,
    pub stats: RunStats,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        assert!(max_batch > 0);
        Scheduler {
            max_batch,
            pending: VecDeque::new(),
            active: (0..max_batch).map(|_| None).collect(),
            finished: Vec::new(),
            stats: RunStats::default(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        assert!(!req.prompt.is_empty(), "request {} has an empty prompt", req.id);
        assert!(req.max_new > 0, "request {} asks for zero tokens", req.id);
        self.pending.push_back(req);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.active.iter().any(Option::is_some)
    }

    /// Admit pending requests into free slots (resets their cache slots).
    fn admit(&mut self, cache: &mut KvCache) {
        for slot in 0..self.max_batch {
            if self.active[slot].is_some() {
                continue;
            }
            let Some(req) = self.pending.pop_front() else { break };
            cache.reset(slot);
            self.active[slot] = Some(Active {
                req,
                slot,
                fed: 0,
                pos: 0,
                generated: Vec::new(),
                last_sampled: 0,
                steps: 0,
            });
        }
    }

    /// Longest sequence length a slot can hold: the learned positional
    /// table bounds the opt family; RoPE models are bounded only by the
    /// cache ring (sliding window), i.e. effectively unbounded.
    fn max_len(model: &PackedModel) -> usize {
        if model.cfg.family == "opt" {
            model.cfg.seq
        } else {
            usize::MAX
        }
    }

    /// Retire a live sequence into `finished` and free its slot.
    fn finish(&mut self, slot: usize, cache: &mut KvCache) {
        let a = self.active[slot].take().expect("finish on empty slot");
        self.finished.push(Completion {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.generated,
            steps: a.steps,
        });
        cache.reset(slot);
    }

    /// One scheduler tick: admit, step every live sequence by one token,
    /// sample/finish. Returns false when no work remains.
    pub fn tick(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> bool {
        self.admit(cache);
        let hard_cap = Self::max_len(model);
        // evict sequences that cannot be stepped further (positional table
        // exhausted mid-prompt or mid-decode)
        for slot in 0..self.max_batch {
            if self.active[slot].as_ref().is_some_and(|a| a.pos >= hard_cap) {
                self.finish(slot, cache);
            }
        }
        let mut batch: Vec<StepInput> = Vec::new();
        let mut slots: Vec<usize> = Vec::new();
        let mut needs: Vec<bool> = Vec::new();
        for a in self.active.iter().flatten() {
            let token = if a.fed < a.req.prompt.len() {
                a.req.prompt[a.fed]
            } else {
                a.last_sampled
            };
            batch.push(StepInput { slot: a.slot, token, pos: a.pos });
            slots.push(a.slot);
            // mid-prefill rows discard their logits; skip the vocab head
            needs.push(a.fed + 1 >= a.req.prompt.len());
        }
        if batch.is_empty() {
            return self.has_work();
        }
        self.stats.scheduler_steps += 1;
        self.stats.tokens_processed += batch.len();
        self.stats.peak_batch = self.stats.peak_batch.max(batch.len());

        let logits = decode::step_select(model, &batch, cache, Some(&needs));

        for (row, slot) in slots.into_iter().enumerate() {
            let a = self.active[slot].as_mut().expect("active slot vanished");
            a.steps += 1;
            a.pos += 1;
            let mut done = false;
            if a.fed < a.req.prompt.len() {
                a.fed += 1;
                if a.fed < a.req.prompt.len() {
                    // still prefilling; ignore the logits
                    continue;
                }
            }
            // the step consumed the last prompt token or a fed-back sample:
            // this row's logits predict the next token
            let tok = sample_row(logits.row(row), sampler, rng);
            a.generated.push(tok);
            a.last_sampled = tok;
            self.stats.tokens_generated += 1;
            if a.generated.len() >= a.req.max_new {
                done = true;
            }
            if a.req.eos == Some(tok) {
                done = true;
            }
            if a.pos >= hard_cap {
                done = true;
            }
            if done {
                self.finish(slot, cache);
            }
        }
        self.has_work()
    }

    /// Drive to completion; returns completions sorted by request id.
    pub fn run(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> Vec<Completion> {
        while self.tick(model, cache, sampler, rng) {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|c| c.id);
        out
    }
}
