//! Continuous-batching request scheduler (Orca-style token-level batching).
//!
//! Requests queue up, get admitted into free KV-cache slots *mid-decode*,
//! and are evicted the step they finish — the batch composition changes
//! every step, exactly like a multi-user serving loop. Prefill and decode
//! are unified: an admitted sequence first streams its prompt tokens
//! through [`decode::step_select`] (outputs ignored) in chunks of up to
//! [`SchedConfig::prefill_chunk`] tokens per scheduler tick, then switches
//! to feeding back sampled tokens one per tick. A per-tick
//! [`SchedConfig::token_budget`] caps the total rows pushed through the
//! model in one step so a burst of long prompts cannot starve live decodes
//! (every live sequence is still guaranteed at least one row per tick).
//!
//! Because the fused GEMM and attention are row-independent — and chunk
//! rows replay the exact cache states token-at-a-time stepping produces —
//! a sequence's greedy output stream depends on neither the batch
//! composition nor the chunking: `rust/tests/engine.rs` asserts completions
//! are identical for `max_batch = 1` vs `N` and for every prefill chunk
//! size.

use std::collections::VecDeque;

use crate::rngx::Pcg32;

use super::decode::{self, sample_row, Sampler, StepInput};
use super::kv::KvCache;
use super::packed::PackedModel;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level; must be non-empty).
    pub prompt: Vec<i32>,
    /// Maximum generated tokens (beyond the prompt).
    pub max_new: usize,
    /// Stop early when this token is produced (it is kept in the output).
    pub eos: Option<i32>,
}

/// Why a sequence left the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its `eos` token (kept in the output).
    Eos,
    /// Hit its `max_new` generation budget.
    MaxNew,
    /// Evicted at the learned-positional-table edge. This can happen
    /// mid-prefill, in which case `tokens` is empty — without this marker
    /// such a truncation would be indistinguishable from a completion.
    PosCapacity,
}

impl FinishReason {
    /// Short human-readable label for CLI/exhibit output.
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxNew => "max_new",
            FinishReason::PosCapacity => "pos_capacity",
        }
    }
}

/// A finished request: the generated continuation (prompt excluded).
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler ticks this sequence was live for (prefill + decode).
    pub steps: usize,
    /// Why the sequence stopped.
    pub finish: FinishReason,
}

/// Scheduler tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedConfig {
    /// Maximum prompt tokens pushed through the model per sequence per
    /// tick. `0` means "the whole remaining prompt in one chunk".
    pub prefill_chunk: usize,
    /// Per-tick cap on total rows (prompt + decode) across the batch;
    /// every live sequence still gets at least one row per tick, so the
    /// effective floor is the live-sequence count. `0` means unlimited.
    pub token_budget: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig { prefill_chunk: 1, token_budget: 0 }
    }
}

struct Active {
    req: Request,
    slot: usize,
    /// Prompt tokens already fed.
    fed: usize,
    /// Next absolute position.
    pos: usize,
    generated: Vec<i32>,
    last_sampled: i32,
    steps: usize,
}

/// Aggregate serving statistics for one `run`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub scheduler_steps: usize,
    /// Total tokens pushed through the model (prefill + decode).
    pub tokens_processed: usize,
    /// Generated tokens only.
    pub tokens_generated: usize,
    /// Peak rows in one step (prompt chunks count each of their rows).
    pub peak_batch: usize,
    /// Ticks that stepped the model with a free slot while requests were
    /// queued — admission failing to use freed capacity. Should be 0; a
    /// regression test asserts it stays 0 across mid-tick evictions.
    pub starved_ticks: usize,
}

pub struct Scheduler {
    max_batch: usize,
    cfg: SchedConfig,
    pending: VecDeque<Request>,
    active: Vec<Option<Active>>,
    finished: Vec<Completion>,
    pub stats: RunStats,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        Scheduler::with_config(max_batch, SchedConfig::default())
    }

    pub fn with_config(max_batch: usize, cfg: SchedConfig) -> Scheduler {
        assert!(max_batch > 0);
        Scheduler {
            max_batch,
            cfg,
            pending: VecDeque::new(),
            active: (0..max_batch).map(|_| None).collect(),
            finished: Vec::new(),
            stats: RunStats::default(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        assert!(!req.prompt.is_empty(), "request {} has an empty prompt", req.id);
        assert!(req.max_new > 0, "request {} asks for zero tokens", req.id);
        self.pending.push_back(req);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.active.iter().any(Option::is_some)
    }

    /// Queued (not yet admitted) request count.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Slots without a live sequence.
    pub fn free_slots(&self) -> usize {
        self.active.iter().filter(|a| a.is_none()).count()
    }

    /// Admit pending requests into free slots (resets their cache slots).
    fn admit(&mut self, cache: &mut KvCache) {
        for slot in 0..self.max_batch {
            if self.active[slot].is_some() {
                continue;
            }
            let Some(req) = self.pending.pop_front() else { break };
            cache.reset(slot);
            self.active[slot] = Some(Active {
                req,
                slot,
                fed: 0,
                pos: 0,
                generated: Vec::new(),
                last_sampled: 0,
                steps: 0,
            });
        }
    }

    /// Longest sequence length a slot can hold: the learned positional
    /// table bounds the opt family; RoPE models are bounded only by the
    /// cache ring (sliding window), i.e. effectively unbounded.
    fn max_len(model: &PackedModel) -> usize {
        if model.cfg.family == "opt" {
            model.cfg.seq
        } else {
            usize::MAX
        }
    }

    /// Retire a live sequence into `finished` and free its slot.
    fn finish(&mut self, slot: usize, cache: &mut KvCache, finish: FinishReason) {
        let a = self.active[slot].take().expect("finish on empty slot");
        self.finished.push(Completion {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.generated,
            steps: a.steps,
            finish,
        });
        cache.reset(slot);
    }

    /// One scheduler tick: admit, push up to `token_budget` rows (decode
    /// sequences one each, prefilling sequences a chunk each), sample and
    /// finish. Returns false when no work remains.
    pub fn tick(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> bool {
        self.admit(cache);
        let hard_cap = Self::max_len(model);
        // evict sequences that cannot be stepped further (positional table
        // exhausted mid-prompt or mid-decode)
        let mut evicted = false;
        for slot in 0..self.max_batch {
            if self.active[slot].as_ref().is_some_and(|a| a.pos >= hard_cap) {
                self.finish(slot, cache, FinishReason::PosCapacity);
                evicted = true;
            }
        }
        // freed capacity must be usable the same tick — re-run admission
        // after the eviction sweep instead of letting slots idle a step
        if evicted {
            self.admit(cache);
        }
        if !self.pending.is_empty() && self.active.iter().any(Option::is_none) {
            self.stats.starved_ticks += 1;
        }

        let chunk = match self.cfg.prefill_chunk {
            0 => usize::MAX,
            c => c,
        };
        let mut budget_left = match self.cfg.token_budget {
            0 => usize::MAX,
            b => b,
        };
        let mut batch: Vec<StepInput> = Vec::new();
        // (slot, index of the slot's last row in `batch`, rows this tick)
        let mut groups: Vec<(usize, usize, usize)> = Vec::new();
        let mut needs: Vec<bool> = Vec::new();
        for a in self.active.iter().flatten() {
            let remaining_prompt = a.req.prompt.len() - a.fed;
            let want = if remaining_prompt > 0 {
                remaining_prompt.min(chunk).min(hard_cap - a.pos)
            } else {
                1
            };
            // every live sequence gets at least one row, so a tight budget
            // degrades to token-at-a-time rather than starving anyone
            let n = want.min(budget_left.max(1));
            budget_left = budget_left.saturating_sub(n);
            for t in 0..n {
                let token = if a.fed + t < a.req.prompt.len() {
                    a.req.prompt[a.fed + t]
                } else {
                    a.last_sampled
                };
                batch.push(StepInput { slot: a.slot, token, pos: a.pos + t });
                // mid-prefill rows discard their logits; skip the vocab head
                needs.push(a.fed + t + 1 >= a.req.prompt.len());
            }
            groups.push((a.slot, batch.len() - 1, n));
        }
        if batch.is_empty() {
            return self.has_work();
        }
        self.stats.scheduler_steps += 1;
        self.stats.tokens_processed += batch.len();
        self.stats.peak_batch = self.stats.peak_batch.max(batch.len());

        let logits = decode::step_select(model, &batch, cache, Some(&needs));

        for (slot, last_row, n) in groups {
            let a = self.active[slot].as_mut().expect("active slot vanished");
            a.steps += 1;
            let prompt_rows = n.min(a.req.prompt.len() - a.fed);
            a.fed += prompt_rows;
            a.pos += n;
            if !needs[last_row] {
                // still prefilling; no logits were produced for this chunk
                continue;
            }
            // the last row consumed the final prompt token or a fed-back
            // sample: its logits predict the next token
            let tok = sample_row(logits.row(last_row), sampler, rng);
            a.generated.push(tok);
            a.last_sampled = tok;
            self.stats.tokens_generated += 1;
            let finish = if a.req.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if a.generated.len() >= a.req.max_new {
                Some(FinishReason::MaxNew)
            } else if a.pos >= hard_cap {
                Some(FinishReason::PosCapacity)
            } else {
                None
            };
            if let Some(f) = finish {
                self.finish(slot, cache, f);
            }
        }
        self.has_work()
    }

    /// Drive to completion; returns completions sorted by request id.
    pub fn run(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> Vec<Completion> {
        while self.tick(model, cache, sampler, rng) {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|c| c.id);
        out
    }
}
