//! Continuous-batching request scheduler (Orca-style token-level batching).
//!
//! Requests queue up, get admitted into free KV-cache slots *mid-decode*,
//! and are evicted the step they finish — the batch composition changes
//! every step, exactly like a multi-user serving loop. Admission reasons
//! in KV *pages*, not just slots: a request enters only when its
//! worst-case page count is reservable against the pool bound, and a
//! prompt prefix already resident in shared pages skips prefill entirely
//! (see `kv.rs`). Prefill and decode
//! are unified: an admitted sequence first streams its prompt tokens
//! through [`decode::step_select`] (outputs ignored) in chunks of up to
//! [`SchedConfig::prefill_chunk`] tokens per scheduler tick, then switches
//! to feeding back sampled tokens one per tick. A per-tick
//! [`SchedConfig::token_budget`] caps the total rows pushed through the
//! model in one step so a burst of long prompts cannot starve live decodes
//! (every live sequence is still guaranteed at least one row per tick).
//!
//! Because the fused GEMM and attention are row-independent — and chunk
//! rows replay the exact cache states token-at-a-time stepping produces —
//! a sequence's greedy output stream depends on neither the batch
//! composition nor the chunking: `rust/tests/engine.rs` asserts completions
//! are identical for `max_batch = 1` vs `N` and for every prefill chunk
//! size.

use std::collections::VecDeque;
use std::time::Instant;

use crate::rngx::Pcg32;
use crate::telemetry::numeric::{PROBE_EVERY, PROBE_GROUPS, PROBE_WARMUP, PROBE_WINDOW};
use crate::telemetry::Recorder;

use super::decode::{self, sample_row, Sampler, StepInput};
use super::kv::KvCache;
use super::packed::PackedModel;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Prompt tokens (byte-level; must be non-empty).
    pub prompt: Vec<i32>,
    /// Maximum generated tokens (beyond the prompt).
    pub max_new: usize,
    /// Stop early when this token is produced (it is kept in the output).
    pub eos: Option<i32>,
}

/// Why a sequence left the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its `eos` token (kept in the output).
    Eos,
    /// Hit its `max_new` generation budget.
    MaxNew,
    /// Evicted at the learned-positional-table edge. This can happen
    /// mid-prefill, in which case `tokens` is empty — without this marker
    /// such a truncation would be indistinguishable from a completion.
    PosCapacity,
    /// Evicted because its deadline passed — while queued (no tokens) or
    /// mid-generation (partial tokens). The serving front-end maps this to
    /// a timeout status instead of passing the truncation off as done.
    Deadline,
}

impl FinishReason {
    /// Short human-readable label for CLI/exhibit output.
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxNew => "max_new",
            FinishReason::PosCapacity => "pos_capacity",
            FinishReason::Deadline => "deadline",
        }
    }
}

/// Why [`Scheduler::submit`] refused a request. Malformed requests used to
/// be `assert!`s — fatal for a serving process, where a bad network payload
/// must become HTTP 400/429, not a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The prompt has no tokens.
    EmptyPrompt,
    /// `max_new == 0`: the request could never produce anything.
    ZeroMaxNew,
    /// The pending queue is at [`SchedConfig::queue_cap`]; the caller
    /// should shed load (HTTP 429) rather than queue unboundedly.
    QueueFull { cap: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EmptyPrompt => write!(f, "empty prompt"),
            SubmitError::ZeroMaxNew => write!(f, "max_new must be at least 1"),
            SubmitError::QueueFull { cap } => write!(f, "pending queue full (cap {cap})"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A finished request: the generated continuation (prompt excluded).
#[derive(Clone, Debug, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Scheduler ticks this sequence was live for (prefill + decode).
    pub steps: usize,
    /// Why the sequence stopped.
    pub finish: FinishReason,
}

/// Scheduler tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedConfig {
    /// Maximum prompt tokens pushed through the model per sequence per
    /// tick. `0` means "the whole remaining prompt in one chunk".
    pub prefill_chunk: usize,
    /// Per-tick cap on total rows (prompt + decode) across the batch;
    /// every live sequence still gets at least one row per tick, so the
    /// effective floor is the live-sequence count. `0` means unlimited.
    pub token_budget: usize,
    /// Hard cap on the pending (admitted-to-queue, not yet slotted)
    /// request count: `submit` returns [`SubmitError::QueueFull`] beyond
    /// it, so the deque can never grow unboundedly under overload.
    /// `0` means unbounded (the offline `generate` path).
    pub queue_cap: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig { prefill_chunk: 1, token_budget: 0, queue_cap: 0 }
    }
}

/// A queued request plus its serving metadata.
struct Pending {
    req: Request,
    deadline: Option<Instant>,
    /// Submit time — `Some` only when telemetry is live, so the offline
    /// path never reads the clock.
    t_submit: Option<Instant>,
}

struct Active {
    req: Request,
    slot: usize,
    /// Prompt tokens already fed (attached shared-prefix tokens count as
    /// fed: their K/V already exists, so prefill skips them).
    fed: usize,
    /// Next absolute position.
    pos: usize,
    /// KV pages reserved against the pool bound at admission
    /// (worst case for prompt + max_new; released on finish).
    pages_reserved: usize,
    generated: Vec<i32>,
    last_sampled: i32,
    steps: usize,
    /// Wall-clock eviction point (serving requests only).
    deadline: Option<Instant>,
    /// Telemetry timestamps (`Some` only when telemetry is live): submit
    /// time and the previous emitted token, for TTFT / inter-token gaps.
    t_submit: Option<Instant>,
    t_last: Option<Instant>,
}

/// Aggregate serving statistics for one `run`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub scheduler_steps: usize,
    /// Total tokens pushed through the model (prefill + decode).
    pub tokens_processed: usize,
    /// Generated tokens only.
    pub tokens_generated: usize,
    /// Peak rows in one step (prompt chunks count each of their rows).
    pub peak_batch: usize,
    /// Ticks that stepped the model with a free slot while requests were
    /// queued — admission failing to use freed capacity. Should be 0; a
    /// regression test asserts it stays 0 across mid-tick evictions.
    pub starved_ticks: usize,
    /// Requests refused at submit because the pending queue was at
    /// [`SchedConfig::queue_cap`] — each one is an HTTP 429 upstream.
    pub shed_requests: usize,
    /// Sequences evicted (queued or live) because their deadline passed.
    pub deadline_evictions: usize,
    /// Sequences dropped via [`Scheduler::cancel`] — e.g. the client
    /// disconnected mid-stream, so the slot was reclaimed with no
    /// completion to deliver.
    pub cancelled: usize,
    /// Peak KV pages referenced by live sequences in any one tick.
    pub kv_pages_peak: usize,
    /// Peak bytes prefix sharing saved in any one tick (duplicate copies
    /// the attached pages replaced).
    pub kv_shared_bytes_peak: usize,
    /// Copy-on-write page copies at prefix divergence points (cumulative
    /// over the cache's lifetime).
    pub kv_cow_faults: u64,
    /// Admissions that attached a non-empty shared prompt prefix
    /// (cumulative over the cache's lifetime).
    pub kv_prefix_hits: u64,
}

pub struct Scheduler {
    max_batch: usize,
    cfg: SchedConfig,
    pending: VecDeque<Pending>,
    active: Vec<Option<Active>>,
    /// KV pages reserved by live sequences against a bounded pool.
    reserved_pages: usize,
    finished: Vec<Completion>,
    /// `(request id, token)` pairs sampled by the most recent `tick` —
    /// the incremental stream a serving front-end forwards to clients.
    /// Cleared at the start of every tick.
    emitted: Vec<(u64, i32)>,
    /// Decode-bearing ticks so far — the divergence-probe cadence clock
    /// (deterministic: counts ticks, never wall time).
    decode_ticks: u64,
    pub stats: RunStats,
    /// Telemetry handle; `Default` is disabled, in which case every
    /// recording call is an inline no-op and no clock is ever read — the
    /// scheduled work itself is identical either way (observation only).
    pub recorder: Recorder,
}

impl Scheduler {
    pub fn new(max_batch: usize) -> Scheduler {
        Scheduler::with_config(max_batch, SchedConfig::default())
    }

    pub fn with_config(max_batch: usize, cfg: SchedConfig) -> Scheduler {
        assert!(max_batch > 0);
        Scheduler {
            max_batch,
            cfg,
            pending: VecDeque::new(),
            active: (0..max_batch).map(|_| None).collect(),
            reserved_pages: 0,
            finished: Vec::new(),
            emitted: Vec::new(),
            decode_ticks: 0,
            stats: RunStats::default(),
            recorder: Recorder::default(),
        }
    }

    /// Queue a request. Refuses (instead of panicking) on malformed input
    /// or a full queue — a serving process must survive bad payloads.
    pub fn submit(&mut self, req: Request) -> Result<(), SubmitError> {
        self.submit_at(req, None)
    }

    /// [`submit`](Scheduler::submit) with a wall-clock deadline: past it
    /// the sequence is evicted (queued or mid-generation) with
    /// [`FinishReason::Deadline`].
    pub fn submit_at(
        &mut self,
        req: Request,
        deadline: Option<Instant>,
    ) -> Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt);
        }
        if req.max_new == 0 {
            return Err(SubmitError::ZeroMaxNew);
        }
        if self.cfg.queue_cap > 0 && self.pending.len() >= self.cfg.queue_cap {
            self.stats.shed_requests += 1;
            let id = req.id;
            self.recorder.event("shed", || format!("req {id}: pending queue full"));
            return Err(SubmitError::QueueFull { cap: self.cfg.queue_cap });
        }
        let prompt_len = req.prompt.len();
        let max_new = req.max_new;
        self.recorder.span(req.id, |s| {
            s.prompt_len = prompt_len;
            s.max_new = max_new;
        });
        self.pending.push_back(Pending { req, deadline, t_submit: self.recorder.now() });
        Ok(())
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || self.active.iter().any(Option::is_some)
    }

    /// Queued (not yet admitted) request count.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Live (slotted) sequence count.
    pub fn active_len(&self) -> usize {
        self.active.iter().filter(|a| a.is_some()).count()
    }

    /// Slots without a live sequence.
    pub fn free_slots(&self) -> usize {
        self.active.iter().filter(|a| a.is_none()).count()
    }

    /// `(request id, token)` pairs sampled by the most recent
    /// [`tick`](Scheduler::tick) — the per-tick stream a serving layer
    /// forwards to clients while sequences are still running.
    pub fn emitted(&self) -> &[(u64, i32)] {
        &self.emitted
    }

    /// Drain completions finished so far (any order); lets a serving loop
    /// deliver results incrementally instead of waiting for
    /// [`run`](Scheduler::run) to return.
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// Evict every sequence — queued or live — whose deadline is at or
    /// before `now`, finishing it with [`FinishReason::Deadline`].
    /// `tick` calls this automatically; it is public so serving loops and
    /// tests can drive it with an explicit clock (deterministically).
    pub fn evict_expired(&mut self, now: Instant, cache: &mut KvCache) {
        for slot in 0..self.max_batch {
            let expired = self.active[slot]
                .as_ref()
                .is_some_and(|a| a.deadline.is_some_and(|d| d <= now));
            if expired {
                self.finish(slot, cache, FinishReason::Deadline);
                self.stats.deadline_evictions += 1;
            }
        }
        // expired queue entries finish without ever touching a slot
        let mut kept = VecDeque::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            if p.deadline.is_some_and(|d| d <= now) {
                self.recorder.finished(
                    p.req.id,
                    FinishReason::Deadline.label(),
                    0,
                    p.t_submit.map(|t| now.duration_since(t)),
                );
                let id = p.req.id;
                self.recorder.event("deadline", || format!("req {id}: expired while queued"));
                self.finished.push(Completion {
                    id: p.req.id,
                    prompt_len: p.req.prompt.len(),
                    tokens: Vec::new(),
                    steps: 0,
                    finish: FinishReason::Deadline,
                });
                self.stats.deadline_evictions += 1;
            } else {
                kept.push_back(p);
            }
        }
        self.pending = kept;
    }

    /// Drop a request (queued or live) without producing a completion —
    /// the disconnect path: the client is gone, so the slot is reclaimed
    /// and there is nobody to deliver to. Returns whether `id` was found.
    pub fn cancel(&mut self, id: u64, cache: &mut KvCache) -> bool {
        for slot in 0..self.max_batch {
            if self.active[slot].as_ref().is_some_and(|a| a.req.id == id) {
                let a = self.active[slot].take().expect("checked is_some");
                self.reserved_pages -= a.pages_reserved;
                cache.reset(slot);
                self.stats.cancelled += 1;
                self.recorder.finished(
                    id,
                    "cancelled",
                    a.generated.len(),
                    a.t_submit.map(|t| t.elapsed()),
                );
                self.recorder.event("cancel", || format!("req {id}: cancelled while live"));
                return true;
            }
        }
        if let Some(i) = self.pending.iter().position(|p| p.req.id == id) {
            let p = self.pending.remove(i).expect("checked position");
            self.stats.cancelled += 1;
            self.recorder.finished(id, "cancelled", 0, p.t_submit.map(|t| t.elapsed()));
            self.recorder.event("cancel", || format!("req {id}: cancelled while queued"));
            return true;
        }
        false
    }

    /// Admit pending requests into free slots. A request is admissible iff
    /// a slot is free *and* its worst-case KV pages (prompt + max_new) are
    /// reservable against the pool bound — explicit capacity accounting
    /// where the old ring silently overwrote its window. FIFO order is
    /// kept: a page-blocked queue head waits rather than being bypassed.
    /// Returns whether admission stopped because of page reservation (so
    /// the starvation counter does not misread pool pressure as a bug).
    fn admit(&mut self, cache: &mut KvCache) -> bool {
        for slot in 0..self.max_batch {
            if self.active[slot].is_some() {
                continue;
            }
            // a request that could never fit the pool even when idle must
            // not deadlock the queue head: finish it as a capacity
            // truncation (no tokens), mirroring the positional-table cap
            while self.pending.front().is_some_and(|p| {
                let need =
                    cache.worst_case_pages(p.req.prompt.len(), p.req.max_new, self.cfg.prefill_chunk);
                cache.max_pages() > 0 && need > cache.max_pages()
            }) {
                let p = self.pending.pop_front().expect("front checked");
                let id = p.req.id;
                self.recorder.finished(
                    id,
                    FinishReason::PosCapacity.label(),
                    0,
                    p.t_submit.map(|t| t.elapsed()),
                );
                self.recorder
                    .event("shed", || format!("req {id}: needs more kv pages than the pool"));
                self.finished.push(Completion {
                    id,
                    prompt_len: p.req.prompt.len(),
                    tokens: Vec::new(),
                    steps: 0,
                    finish: FinishReason::PosCapacity,
                });
            }
            let Some(p) = self.pending.front() else { return false };
            let need =
                cache.worst_case_pages(p.req.prompt.len(), p.req.max_new, self.cfg.prefill_chunk);
            if cache.max_pages() > 0 && self.reserved_pages + need > cache.max_pages() {
                return true;
            }
            let p = self.pending.pop_front().expect("front checked");
            cache.reset(slot);
            // skip prefill for whatever prompt prefix is already resident
            // in shared pages (bit-identical K/V by construction)
            let shared = cache.attach_prefix(slot, &p.req.prompt);
            if let Some(t0) = p.t_submit {
                self.recorder.queue_wait(p.req.id, t0.elapsed());
            }
            self.reserved_pages += need;
            self.active[slot] = Some(Active {
                req: p.req,
                slot,
                fed: shared,
                pos: shared,
                pages_reserved: need,
                generated: Vec::new(),
                last_sampled: 0,
                steps: 0,
                deadline: p.deadline,
                t_submit: p.t_submit,
                t_last: None,
            });
        }
        false
    }

    /// Longest sequence length a slot can hold: the learned positional
    /// table bounds the opt family; RoPE models are bounded only by the
    /// cache ring (sliding window), i.e. effectively unbounded.
    fn max_len(model: &PackedModel) -> usize {
        if model.cfg.family == "opt" {
            model.cfg.seq
        } else {
            usize::MAX
        }
    }

    /// Retire a live sequence into `finished` and free its slot (and its
    /// page reservation).
    fn finish(&mut self, slot: usize, cache: &mut KvCache, finish: FinishReason) {
        let a = self.active[slot].take().expect("finish on empty slot");
        self.reserved_pages -= a.pages_reserved;
        self.recorder.finished(
            a.req.id,
            finish.label(),
            a.generated.len(),
            a.t_submit.map(|t| t.elapsed()),
        );
        self.finished.push(Completion {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.generated,
            steps: a.steps,
            finish,
        });
        cache.reset(slot);
    }

    /// One scheduler tick: admit, push up to `token_budget` rows (decode
    /// sequences one each, prefilling sequences a chunk each), sample and
    /// finish. Returns false when no work remains.
    pub fn tick(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> bool {
        self.tick_drafted(model, None, cache, sampler, rng)
    }

    /// [`tick`](Scheduler::tick) with an optional lower-bit `draft` variant
    /// of `model`: when the recorder is live, one live decode sequence is
    /// periodically re-run through the draft ([`PROBE_WARMUP`] /
    /// [`PROBE_EVERY`] cadence in decode-bearing ticks) and the top-1
    /// agreement + logit/hidden deltas are recorded as cross-bit-width
    /// divergence. The probe uses scratch KV caches and no RNG, so
    /// scheduling, serving state, and sampled outputs are untouched —
    /// greedy streams are bit-identical with or without a draft.
    pub fn tick_drafted(
        &mut self,
        model: &PackedModel,
        draft: Option<&PackedModel>,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> bool {
        self.emitted.clear();
        // telemetry tick clock: one read at tick start (None when disabled)
        let t_tick = self.recorder.now();
        // deadline sweep first, so an expired sequence never costs a step;
        // the clock is only read when a deadline actually exists, keeping
        // the offline `generate` path free of wall-clock dependence
        let any_deadline = self.active.iter().flatten().any(|a| a.deadline.is_some())
            || self.pending.iter().any(|p| p.deadline.is_some());
        if any_deadline {
            self.evict_expired(Instant::now(), cache);
        }
        let mut page_blocked = self.admit(cache);
        let hard_cap = Self::max_len(model);
        // evict sequences that cannot be stepped further (positional table
        // exhausted mid-prompt or mid-decode)
        let mut evicted = false;
        for slot in 0..self.max_batch {
            if self.active[slot].as_ref().is_some_and(|a| a.pos >= hard_cap) {
                self.finish(slot, cache, FinishReason::PosCapacity);
                evicted = true;
            }
        }
        // freed capacity must be usable the same tick — re-run admission
        // after the eviction sweep instead of letting slots idle a step
        if evicted {
            page_blocked = self.admit(cache);
        }
        // a queue head waiting on page reservation is deliberate capacity
        // accounting, not admission failing to use freed slots
        if !self.pending.is_empty() && self.active.iter().any(Option::is_none) && !page_blocked {
            self.stats.starved_ticks += 1;
        }

        let chunk = match self.cfg.prefill_chunk {
            0 => usize::MAX,
            c => c,
        };
        let mut budget_left = match self.cfg.token_budget {
            0 => usize::MAX,
            b => b,
        };
        let mut batch: Vec<StepInput> = Vec::new();
        // (slot, index of the slot's last row in `batch`, rows this tick)
        let mut groups: Vec<(usize, usize, usize)> = Vec::new();
        let mut needs: Vec<bool> = Vec::new();
        // phase classification for tick telemetry
        let (mut prefill_rows, mut decode_rows) = (0usize, 0usize);
        for a in self.active.iter().flatten() {
            let remaining_prompt = a.req.prompt.len() - a.fed;
            let want = if remaining_prompt > 0 {
                remaining_prompt.min(chunk).min(hard_cap - a.pos)
            } else {
                1
            };
            // every live sequence gets at least one row, so a tight budget
            // degrades to token-at-a-time rather than starving anyone
            let n = want.min(budget_left.max(1));
            budget_left = budget_left.saturating_sub(n);
            if remaining_prompt > 0 {
                prefill_rows += n;
            } else {
                decode_rows += n;
            }
            for t in 0..n {
                let token = if a.fed + t < a.req.prompt.len() {
                    a.req.prompt[a.fed + t]
                } else {
                    a.last_sampled
                };
                batch.push(StepInput { slot: a.slot, token, pos: a.pos + t });
                // mid-prefill rows discard their logits; skip the vocab head
                needs.push(a.fed + t + 1 >= a.req.prompt.len());
            }
            groups.push((a.slot, batch.len() - 1, n));
        }
        if batch.is_empty() {
            return self.has_work();
        }
        self.stats.scheduler_steps += 1;
        self.stats.tokens_processed += batch.len();
        self.stats.peak_batch = self.stats.peak_batch.max(batch.len());

        let logits =
            decode::step_observed(model, &batch, cache, Some(&needs), self.recorder.numeric());
        // one clock read per tick covers every TTFT/gap sample below
        let t_now = self.recorder.now();

        for (slot, last_row, n) in groups {
            let a = self.active[slot].as_mut().expect("active slot vanished");
            a.steps += 1;
            let prompt_rows = n.min(a.req.prompt.len() - a.fed);
            a.fed += prompt_rows;
            a.pos += n;
            if prompt_rows > 0 {
                // the chunk's rows are written and immutable now (pages are
                // append-only), so the prefix is safe to share from
                cache.register_prefix(slot, &a.req.prompt[..a.fed]);
            }
            if !needs[last_row] {
                // still prefilling; no logits were produced for this chunk
                continue;
            }
            // the last row consumed the final prompt token or a fed-back
            // sample: its logits predict the next token
            let tok = sample_row(logits.row(last_row), sampler, rng);
            a.generated.push(tok);
            a.last_sampled = tok;
            if let Some(now) = t_now {
                if a.generated.len() == 1 {
                    if let Some(t0) = a.t_submit {
                        self.recorder.ttft(a.req.id, now.duration_since(t0));
                    }
                } else if let Some(prev) = a.t_last {
                    self.recorder.gap(a.req.id, now.duration_since(prev));
                }
                a.t_last = Some(now);
            }
            self.emitted.push((a.req.id, tok));
            self.stats.tokens_generated += 1;
            let finish = if a.req.eos == Some(tok) {
                Some(FinishReason::Eos)
            } else if a.generated.len() >= a.req.max_new {
                Some(FinishReason::MaxNew)
            } else if a.pos >= hard_cap {
                Some(FinishReason::PosCapacity)
            } else {
                None
            };
            if let Some(f) = finish {
                self.finish(slot, cache, f);
            }
        }
        let ks = cache.stats();
        self.stats.kv_pages_peak = self.stats.kv_pages_peak.max(ks.pages_resident);
        self.stats.kv_shared_bytes_peak = self.stats.kv_shared_bytes_peak.max(ks.shared_bytes);
        self.stats.kv_cow_faults = ks.cow_faults;
        self.stats.kv_prefix_hits = ks.prefix_hits;
        if decode_rows > 0 {
            self.decode_ticks += 1;
            self.maybe_probe_divergence(model, draft, cache);
        }
        self.recorder.tick(t_tick, prefill_rows, decode_rows);
        self.has_work()
    }

    /// Cross-bit-width divergence sampling: on cadence, pick the live
    /// fully-prefilled sequence with the longest history (deterministic
    /// tie-break: lowest slot) and re-run its trailing token window through
    /// both the serving model and the draft. Observation only — scratch KV,
    /// no RNG, nothing of the serving state touched.
    fn maybe_probe_divergence(
        &self,
        model: &PackedModel,
        draft: Option<&PackedModel>,
        cache: &KvCache,
    ) {
        let Some(draft) = draft else { return };
        if self.recorder.numeric().is_none() {
            return;
        }
        let due = self.decode_ticks == PROBE_WARMUP
            || (self.decode_ticks > PROBE_WARMUP
                && (self.decode_ticks - PROBE_WARMUP) % PROBE_EVERY == 0);
        if !due {
            return;
        }
        let cand = self
            .active
            .iter()
            .flatten()
            .filter(|a| !a.generated.is_empty() && a.fed == a.req.prompt.len())
            .max_by_key(|a| (a.fed + a.generated.len(), std::cmp::Reverse(a.slot)));
        let Some(a) = cand else { return };
        let mut toks: Vec<i32> = Vec::with_capacity(a.fed + a.generated.len());
        toks.extend_from_slice(&a.req.prompt[..a.fed]);
        toks.extend_from_slice(&a.generated);
        let window = PROBE_WINDOW.min(cache.window).min(toks.len());
        if window == 0 {
            return;
        }
        let tail = &toks[toks.len() - window..];
        let probe = decode::probe_divergence(model, draft, tail, PROBE_GROUPS);
        self.recorder.numeric_divergence(probe.agree, probe.max_logit_delta, &probe.group_delta);
    }

    /// Drive to completion; returns completions sorted by request id.
    pub fn run(
        &mut self,
        model: &PackedModel,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> Vec<Completion> {
        self.run_drafted(model, None, cache, sampler, rng)
    }

    /// [`run`](Scheduler::run) with an optional divergence-probe draft
    /// variant (see [`tick_drafted`](Scheduler::tick_drafted)).
    pub fn run_drafted(
        &mut self,
        model: &PackedModel,
        draft: Option<&PackedModel>,
        cache: &mut KvCache,
        sampler: Sampler,
        rng: &mut Pcg32,
    ) -> Vec<Completion> {
        while self.tick_drafted(model, draft, cache, sampler, rng) {}
        let mut out = std::mem::take(&mut self.finished);
        out.sort_by_key(|c| c.id);
        out
    }
}
