//! Micro-benchmark harness (criterion is not vendored offline).
//!
//! `cargo bench` targets are `harness = false` binaries built on this:
//! warmup, fixed-iteration timing, median/p10/p90 statistics, and a
//! markdown-ish line printer consistent across all bench targets.

use crate::util::Timer;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations, then `iters` timed.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.secs());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    let res = BenchResult {
        name: name.to_string(),
        iters,
        median_s: pick(0.5),
        p10_s: pick(0.1),
        p90_s: pick(0.9),
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
    };
    println!(
        "bench {:<42} {:>10} median  [{} .. {}]  ({} iters)",
        res.name,
        fmt_s(res.median_s),
        fmt_s(res.p10_s),
        fmt_s(res.p90_s),
        iters
    );
    res
}

/// Time a single long-running closure (end-to-end benches).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    let secs = t.secs();
    println!("bench {:<42} {:>10} (single run)", name, fmt_s(secs));
    (out, secs)
}

pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Markdown table printer used by the table-reproduction benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&line(&self.header));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    /// Print the most recent row (progress feedback during long sweeps).
    pub fn print_last(&self) {
        if let Some(r) = self.rows.last() {
            println!("  {}", r.join(" | "));
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_quantiles() {
        let mut x = 0u64;
        let r = bench("noop", 2, 20, || {
            x = x.wrapping_add(1);
        });
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
        assert_eq!(r.iters, 20);
    }

    #[test]
    fn table_markdown() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | bb |"));
        assert!(md.contains("| 1 | 2  |"));
        assert_eq!(t.to_csv(), "a,bb\n1,2\n");
    }
}
