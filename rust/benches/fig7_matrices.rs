//! Bench: paper Figure 7 / Appendix A.6 — affine-matrix heat-map dumps and
//! the strictly-diagonally-dominant property across epochs. Runs one
//! block's optimization with SDD recording, dumps the final A matrices per
//! site as CSV and the per-epoch minimum SDD margin.

use affinequant::cli::parse_config;
use affinequant::coordinator::block_opt::{optimize_block, CalibOptions};
use affinequant::coordinator::stream;
use affinequant::harness::{env_list, Ctx};
use affinequant::report::{save_series, save_table};
use affinequant::benchx::Table;

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let config = env_list("AQ_CONFIGS", &["w3a16"]).remove(0);
    let (spec, act_bits) = parse_config(&config)?;
    let mut ctx = Ctx::load()?;
    let (rt, fp) = ctx.model(&model)?;
    let opts = CalibOptions::affinequant(spec, act_bits);

    let batches = stream::calib_batches(&rt.cfg, opts.n_calib, opts.seed);
    let xs = stream::embed_stream(&rt, fp.globals(), &batches)?;
    let wb = fp.block(0).to_vec();
    let (yfp, stats) = stream::capture_block(&rt, &wb, &xs)?;
    let res = optimize_block(&rt, &opts, &wb, &xs, &yfp, &stats, true)?;

    // per-epoch min SDD margin (must stay positive — Levy-Desplanques)
    let rows: Vec<(f64, f64)> = res
        .sdd_margins
        .iter()
        .enumerate()
        .map(|(e, &m)| ((e + 1) as f64, m as f64))
        .collect();
    save_series(&format!("fig7_sdd_margin_{model}_{config}"), "epoch,min_margin", &rows)?;
    let all_positive = res.sdd_margins.iter().all(|&m| m > 0.0);
    println!("SDD margin positive at every epoch: {all_positive}");

    // final matrices as CSV heat-map dumps
    let t = res.transforms;
    for (site, m) in [("qkv", t.a_qkv.as_ref()), ("fc1", t.a_fc1.as_ref())] {
        if let Some(a) = m {
            let n = a.shape[0];
            let mut tab = Table::new(
                &format!("A_{site} final ({model} {config})"),
                &(0..n).map(|_| "v").collect::<Vec<_>>(),
            );
            for i in 0..n {
                tab.row((0..n).map(|j| format!("{:.5}", a.data[i * n + j])).collect());
            }
            save_table(&tab, &format!("fig7_A_{site}_{model}_{config}"))?;
            let margin = affinequant::linalg::sdd_margin(&a.data, n);
            println!("A_{site}: sdd margin {margin:.4}");
        }
    }
    Ok(())
}
