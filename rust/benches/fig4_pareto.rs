//! Bench: paper Figure 4 — PPL vs weighted-memory Pareto points
//! (AffineQuant vs OmniQuant across bit configs). Full sweep in
//! `examples/pareto_frontier.rs`.

use affinequant::cli::parse_config;
use affinequant::eval::weighted_memory_bytes;
use affinequant::harness::{env_list, method_ppl, Ctx};
use affinequant::report::save_series;

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let configs = env_list("AQ_CONFIGS", &["w2a16g64", "w4a16"]);
    let mut ctx = Ctx::load()?;
    for method in ["omniquant", "affinequant"] {
        let mut pts = Vec::new();
        for config in &configs {
            let (spec, act_bits) = parse_config(config)?;
            let ppl = method_ppl(&mut ctx, &model, method, spec, act_bits)?;
            let (_, fp) = ctx.model(&model)?;
            let mem = weighted_memory_bytes(&fp, spec, method == "affinequant");
            println!("{model} {config} {method}: {mem} bytes, ppl {:.3}", ppl["wt2s"]);
            pts.push((mem as f64, ppl["wt2s"]));
        }
        save_series(&format!("fig4_pareto_{model}_{method}"), "memory_bytes,ppl_wt2s", &pts)?;
    }
    Ok(())
}
