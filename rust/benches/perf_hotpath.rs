//! Perf bench (§Perf in EXPERIMENTS.md): times the L3 hot paths — PJRT
//! entry executions (eval block forward, calibration step, train step),
//! host-side merge/GPTQ kernels, and the end-to-end PPL eval — and verifies
//! the paper's "no inference overhead" claim by comparing merged-model vs
//! FP eval latency.

use affinequant::benchx::{bench, Table};
use affinequant::coordinator::stream;
use affinequant::data::CorpusKind;
use affinequant::eval;
use affinequant::harness::{env_list, Ctx};
use affinequant::quant::QuantSpec;
use affinequant::report::save_table;
use affinequant::rngx::Pcg32;
use affinequant::runtime::Arg;
use affinequant::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let mut ctx = Ctx::load()?;
    let (rt, fp) = ctx.model(&model)?;
    let cfg = rt.cfg.clone();
    let mut t = Table::new(&format!("hot-path timings — {model}"), &["path", "median_ms"]);
    let mut push = |name: &str, r: &affinequant::benchx::BenchResult| {
        t.row(vec![name.into(), format!("{:.2}", r.median_s * 1e3)]);
    };

    // PJRT entries
    let batches = stream::calib_batches(&cfg, 16, 1);
    let x = stream::embed_stream(&rt, fp.globals(), &batches)?.remove(0);
    let wb = fp.block(0).to_vec();
    let r = bench("block_fp", 2, 10, || {
        let _ = rt.block_fp(&x, &wb).unwrap();
    });
    push("block_fp", &r);
    let r = bench("block_a4", 2, 10, || {
        let _ = rt.block_a4(&x, &wb, 15.0).unwrap();
    });
    push("block_a4", &r);

    let playout = rt.phi_layouts["w_g0"].clone();
    let phi = vec![0.01f32; playout.size];
    let mphi = vec![1.0f32; playout.size];
    let qmax = [7.0f32];
    let r = bench("calib_w_g0 step", 1, 5, || {
        let _ = rt
            .call(
                "calib_w_g0",
                &[
                    Arg::F32(&x.data),
                    Arg::F32(&x.data),
                    Arg::F32(&wb),
                    Arg::F32(&phi),
                    Arg::F32(&mphi),
                    Arg::F32(&qmax),
                ],
            )
            .unwrap();
    });
    push("calib_w_g0", &r);

    // host-side kernels
    let d = cfg.d_model;
    let mut rng = Pcg32::seeded(3);
    let a = {
        let mut a = Tensor::randn(&[d, d], 0.001, &mut rng);
        for i in 0..d {
            a.data[i * d + i] = 1.0;
        }
        a
    };
    let w = Tensor::randn(&[d, d], 0.02, &mut rng);
    let r = bench("merge inverse_prec f32/f64", 2, 10, || {
        let _ = affinequant::model::merge::inverse_prec(
            &a,
            affinequant::model::merge::MergePrecision::F32InvF64,
        );
    });
    push("inverse_prec(f64)", &r);
    let r = bench("host matmul d^3", 2, 10, || {
        let _ = a.matmul(&w);
    });
    push("host_matmul", &r);
    let xact = Tensor::randn(&[1024, d], 1.0, &mut rng);
    // Hessian accumulation: scalar reference vs blocked-matmul path (§Perf)
    let r = bench("hessian scalar (before)", 1, 5, || {
        let mut h = vec![0.0f64; d * d];
        for rr in 0..1024 {
            let row = xact.row(rr);
            for a in 0..d {
                let va = row[a] as f64;
                let hrow = &mut h[a * d..(a + 1) * d];
                for b in a..d {
                    hrow[b] += va * row[b] as f64;
                }
            }
        }
        std::hint::black_box(h);
    });
    push("hessian_scalar", &r);
    let r = bench("hessian matmul_at (after)", 1, 5, || {
        let g = xact.matmul_at(&xact);
        let h: Vec<f64> = g.data.iter().map(|&v| v as f64).collect();
        std::hint::black_box(h);
    });
    push("hessian_matmul", &r);
    let h: Vec<f64> = {
        let ht = xact.matmul_at(&xact);
        ht.data.iter().map(|&v| v as f64).collect()
    };
    let r = bench("gptq_weight d x d", 1, 3, || {
        let _ = affinequant::baselines::gptq::gptq_weight(&w, &h, QuantSpec::new(4, 0)).unwrap();
    });
    push("gptq_weight", &r);

    // end-to-end PPL eval: FP vs merged (paper's zero-overhead claim)
    let qps = affinequant::baselines::rtn::quantize(&rt, &fp, QuantSpec::new(4, 0))?;
    let r_fp = bench("ppl eval (fp)", 1, 3, || {
        let _ = eval::perplexity(&rt, &fp, CorpusKind::Wt2s, 2, None).unwrap();
    });
    push("ppl_eval_fp", &r_fp);
    let r_q = bench("ppl eval (merged w4)", 1, 3, || {
        let _ = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, 2, None).unwrap();
    });
    push("ppl_eval_merged", &r_q);
    let overhead = (r_q.median_s / r_fp.median_s - 1.0) * 100.0;
    println!("merged-vs-fp eval overhead: {overhead:+.2}% (claim: ≈0)");
    t.row(vec!["merged_overhead_pct".into(), format!("{overhead:.2}")]);

    // per-entry PJRT accounting
    println!("\nPJRT entry totals:");
    for (entry, n, secs) in rt.stats() {
        println!("  {entry:<16} {n:>5} calls  {secs:8.2}s total");
    }
    t.print();
    save_table(&t, "perf_hotpath")?;
    Ok(())
}
