//! Bench: paper Table 7 — AffineQuant vs FlexRound at w4a16 on the
//! zero-shot suite.

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, zeroshot_table, Ctx};

fn main() -> anyhow::Result<()> {
    let models = env_list("AQ_MODELS", &["opt-s1"]);
    let methods = env_list("AQ_METHODS", &["fp16", "flexround", "affinequant"]);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table7 flexround vs affinequant (w4a16 zero-shot)", || {
        zeroshot_table(&mut ctx, &models, &methods, "w4a16", "table7_flexround")
    });
    t?.print();
    Ok(())
}
