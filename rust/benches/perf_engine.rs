//! Packed-engine perf: fused unpack→dequant GEMM vs the f32 fake-quant
//! matmul baseline (what the AOT graphs do on every forward), across
//! batch {1, 4, 16} and w4g128 / w3g128 / w2g64 — plus end-to-end decode
//! tokens/sec through the continuous-batching engine and time-to-first-
//! token across prefill chunk sizes (chunked prefill acceptance: >=3x
//! lower TTFT on a 256-token prompt at chunk 16 vs chunk 1).
//!
//! Pure host: runs with `--no-default-features` and no artifacts. With the
//! `pjrt` feature *and* `artifacts/` present it also prints the harness
//! engine exhibit (parity + PJRT-baseline throughput).
//!
//!     cargo bench --bench perf_engine [--no-default-features]
//!
//! Acceptance target: ≥4× tokens/sec for w4g128 packed GEMM over the
//! fake-quant baseline at batch 16 on the same thread count.

use affinequant::benchx::{bench, Table};
use affinequant::engine::gemm::{
    packed_gemm, packed_gemm_with, packed_matvec_grouped, PackedWeight,
};
use affinequant::engine::kernels;
use affinequant::engine::kv::KvCache;
use affinequant::engine::packed::PackedLinear;
use affinequant::engine::{Engine, KvConfig, Request, Sampler, SchedConfig, Scheduler};
use affinequant::jsonx::{self, Value};
use affinequant::model::zoo;
use affinequant::quant::{quant_dequant, QuantSpec};
use affinequant::report::{save_json, save_table};
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

/// The perf-trajectory snapshot this bench persists (`BENCH_10.json`): the
/// ROADMAP asks every PR to leave a machine-readable record so the next
/// re-anchor can see regressions, not just today's stdout. Anchored to the
/// manifest dir (the repo root) so it lands there regardless of cwd.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_10.json");

fn main() -> anyhow::Result<()> {
    let mut json_gemm: Vec<Value> = Vec::new();
    let mut json_decode: Vec<Value> = Vec::new();
    let mut json_ttft: Vec<Value> = Vec::new();
    let mut rng = Pcg32::seeded(1);
    let (din, dout) = (1024usize, 1024usize);
    let w = Tensor::randn(&[din, dout], 0.02, &mut rng);

    let mut t = Table::new(
        "packed GEMM vs f32 fake-quant matmul (1024x1024)",
        &["config", "batch", "fakequant_ms", "dense_ms", "packed_ms", "speedup_vs_fq"],
    );
    let mut w4b16_speedup = 0.0f64;

    for (label, spec) in [
        ("w4g128", QuantSpec::new(4, 128)),
        ("w3g128", QuantSpec::new(3, 128)),
        ("w2g64", QuantSpec::new(2, 64)),
    ] {
        let pl = PackedLinear::pack("w", &w, spec);
        let dense = pl.dequantize();
        for m in [1usize, 4, 16] {
            let x = Tensor::randn(&[m, din], 1.0, &mut rng);
            // baseline: fake-quantize in f32 on every call, then matmul —
            // the AOT serving graphs' per-forward cost shape
            let r_fq = bench(&format!("{label} b{m} fakequant+matmul"), 2, 8, || {
                let dq = quant_dequant(&w, spec, None);
                std::hint::black_box(x.matmul(&dq));
            });
            // floor: pre-dequantized dense f32 matmul only
            let r_dense = bench(&format!("{label} b{m} dense matmul"), 2, 8, || {
                std::hint::black_box(x.matmul(&dense));
            });
            // fused packed path
            let r_packed = bench(&format!("{label} b{m} packed fused"), 2, 8, || {
                std::hint::black_box(pl.matmul(&x.data, m));
            });
            let speedup = r_fq.median_s / r_packed.median_s;
            if label == "w4g128" && m == 16 {
                w4b16_speedup = speedup;
            }
            json_gemm.push(jsonx::obj(vec![
                ("config", jsonx::s(label)),
                ("batch", jsonx::num(m as f64)),
                ("fakequant_ms", jsonx::num(r_fq.median_s * 1e3)),
                ("dense_ms", jsonx::num(r_dense.median_s * 1e3)),
                ("packed_ms", jsonx::num(r_packed.median_s * 1e3)),
                ("speedup_vs_fq", jsonx::num(speedup)),
            ]));
            t.row(vec![
                label.to_string(),
                m.to_string(),
                format!("{:.3}", r_fq.median_s * 1e3),
                format!("{:.3}", r_dense.median_s * 1e3),
                format!("{:.3}", r_packed.median_s * 1e3),
                format!("{speedup:.2}x"),
            ]);
            t.print_last();
        }
    }
    println!(
        "\nw4g128 batch-16 packed-vs-fakequant speedup: {w4b16_speedup:.2}x (target: >=4x)"
    );

    // group-factored matvec kernel (batch-1 decode special case)
    {
        let spec = QuantSpec::new(4, 128);
        let pl = PackedLinear::pack("w", &w, spec);
        let x: Vec<f32> = (0..din).map(|_| rng.normal() as f32).collect();
        let (scales, zps) = pl.params();
        let pw = PackedWeight {
            packed: &pl.packed,
            bits: spec.bits,
            din,
            dout,
            group_len: spec.group_len(din),
            scales,
            zps,
        };
        bench("w4g128 b1 matvec_grouped", 2, 8, || {
            let mut y = vec![0.0f32; dout];
            packed_matvec_grouped(&pw, &x, &mut y);
            std::hint::black_box(y);
        });
        bench("w4g128 b1 gemm stripe", 2, 8, || {
            let mut y = vec![0.0f32; dout];
            packed_gemm(&pw, &x, &mut y, 1);
            std::hint::black_box(y);
        });
    }

    // --------------------- kernel dispatch sweep: specialization per variant
    // For each bit width, run the threaded packed GEMM (batch 16) through
    // the runtime-generic scalar baseline (the pre-dispatch loop) and every
    // ISA variant the host can actually run. tok/s counts batch rows per
    // call; GB/s counts the packed-weight + activation + output traffic.
    // Every variant's output is asserted bit-identical to the baseline —
    // the dispatch layer's acceptance invariant.
    let mut kt = Table::new(
        "kernel dispatch GEMM sweep (1024x1024, batch 16)",
        &["config", "kernel", "tok_s", "gb_s", "vs_generic"],
    );
    let mut json_kernel: Vec<Value> = Vec::new();
    let kernel_sel = kernels::info();
    let mut w4_best_tok_s = 0.0f64;
    let mut w4_generic_tok_s = 0.0f64;
    {
        let m = 16usize;
        let xk = Tensor::randn(&[m, din], 1.0, &mut rng);
        for (label, spec) in [
            ("w2g64", QuantSpec::new(2, 64)),
            ("w3g128", QuantSpec::new(3, 128)),
            ("w4g128", QuantSpec::new(4, 128)),
            ("w8g128", QuantSpec::new(8, 128)),
        ] {
            let pl = PackedLinear::pack("w", &w, spec);
            let (scales, zps) = pl.params();
            let pw = PackedWeight {
                packed: &pl.packed,
                bits: spec.bits,
                din,
                dout,
                group_len: spec.group_len(din),
                scales,
                zps,
            };
            let bytes = (pl.packed.len() + (xk.data.len() + m * dout) * 4) as f64;
            let mut base = vec![0.0f32; m * dout];
            packed_gemm_with(kernels::reference_kernel(), &pw, &xk.data, &mut base, m);

            let mut row_kernels = vec![("generic", kernels::reference_kernel())];
            for v in kernels::available() {
                row_kernels.push((v.name(), kernels::select_for(v, spec.bits, pw.group_len)));
            }
            let mut generic_tok_s = 0.0f64;
            for (vname, k) in row_kernels {
                let r = bench(&format!("{label} kernel {}", k.name), 2, 8, || {
                    let mut y = vec![0.0f32; m * dout];
                    packed_gemm_with(k, &pw, &xk.data, &mut y, m);
                    std::hint::black_box(y);
                });
                let mut y = vec![0.0f32; m * dout];
                packed_gemm_with(k, &pw, &xk.data, &mut y, m);
                assert_eq!(y, base, "kernel {} diverges from the generic baseline", k.name);
                let tok_s = m as f64 / r.median_s;
                let gb_s = bytes / r.median_s / 1e9;
                if vname == "generic" {
                    generic_tok_s = tok_s;
                }
                let vs_generic = tok_s / generic_tok_s.max(1e-12);
                if label == "w4g128" {
                    if vname == "generic" {
                        w4_generic_tok_s = tok_s;
                    } else {
                        w4_best_tok_s = w4_best_tok_s.max(tok_s);
                    }
                }
                json_kernel.push(jsonx::obj(vec![
                    ("config", jsonx::s(label)),
                    ("bits", jsonx::num(spec.bits as f64)),
                    ("variant", jsonx::s(vname)),
                    ("kernel", jsonx::s(k.name)),
                    ("tok_s", jsonx::num(tok_s)),
                    ("gb_s", jsonx::num(gb_s)),
                    ("speedup_vs_generic", jsonx::num(vs_generic)),
                ]));
                kt.row(vec![
                    label.to_string(),
                    k.name.to_string(),
                    format!("{tok_s:.0}"),
                    format!("{gb_s:.2}"),
                    format!("{vs_generic:.2}x"),
                ]);
                kt.print_last();
            }
        }
    }
    println!(
        "\nselected kernel: {} ({}); w4g128 b16 specialized {:.0} tok/s vs generic {:.0} \
         ({:.2}x)",
        kernel_sel.selected,
        kernel_sel.source,
        w4_best_tok_s,
        w4_generic_tok_s,
        w4_best_tok_s / w4_generic_tok_s.max(1e-12),
    );

    // ---------------------------------------- end-to-end engine decode
    // Each batch point runs twice: telemetry off (the zero-cost default)
    // and telemetry on with sampled kernel timing — the on-run must stay
    // within a few % tokens/s AND produce identical greedy tokens, which
    // is the serving-overhead acceptance the telemetry layer signed up
    // for. The ratio and the latency percentiles land in BENCH_10.json.
    let mut dt = Table::new(
        "engine decode throughput (opt-s2, w4g128, greedy)",
        &["batch", "tok_s_off", "tok_s_on", "on_off_ratio", "ttft_p50_ms", "it_p50_ms", "it_p99_ms", "kv_mb"],
    );
    let ps = zoo::seeded_store("opt-s2", 42).expect("zoo model");
    for batch in [1usize, 4, 16] {
        let reqs: Vec<Request> = (0..batch)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![(i * 17 % 256) as i32, 5, 9],
                max_new: 64,
                eos: None,
            })
            .collect();

        affinequant::telemetry::kernel::enable(false);
        let mut engine = Engine::from_store(&ps, QuantSpec::new(4, 128), batch);
        let timer = affinequant::util::Timer::start();
        let (base, stats) = engine.generate(reqs.clone(), Sampler::Greedy, 0)?;
        let tok_s_off = stats.tokens_processed as f64 / timer.secs();

        let mut engine_on = Engine::from_store(&ps, QuantSpec::new(4, 128), batch);
        engine_on.recorder = affinequant::telemetry::Recorder::new_enabled();
        affinequant::telemetry::kernel::enable(true);
        let timer = affinequant::util::Timer::start();
        let (got, stats_on) = engine_on.generate(reqs, Sampler::Greedy, 0)?;
        let tok_s_on = stats_on.tokens_processed as f64 / timer.secs();
        affinequant::telemetry::kernel::enable(false);
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.tokens, b.tokens, "telemetry changed greedy output at batch {batch}");
        }
        let tele = engine_on.recorder.telemetry().expect("recorder enabled");
        let ratio = tok_s_on / tok_s_off.max(1e-12);

        json_decode.push(jsonx::obj(vec![
            ("batch", jsonx::num(batch as f64)),
            ("tok_s", jsonx::num(tok_s_off)),
            ("tok_s_telemetry_on", jsonx::num(tok_s_on)),
            ("telemetry_on_off_ratio", jsonx::num(ratio)),
            ("ttft_p50_ms", jsonx::num(tele.ttft.percentile_ms(0.50))),
            ("ttft_p90_ms", jsonx::num(tele.ttft.percentile_ms(0.90))),
            ("ttft_p99_ms", jsonx::num(tele.ttft.percentile_ms(0.99))),
            ("inter_token_p50_ms", jsonx::num(tele.inter_token.percentile_ms(0.50))),
            ("inter_token_p90_ms", jsonx::num(tele.inter_token.percentile_ms(0.90))),
            ("inter_token_p99_ms", jsonx::num(tele.inter_token.percentile_ms(0.99))),
            ("scheduler_steps", jsonx::num(stats.scheduler_steps as f64)),
            ("kv_mb", jsonx::num(engine.kv_bytes() as f64 / 1e6)),
        ]));
        dt.row(vec![
            batch.to_string(),
            format!("{tok_s_off:.0}"),
            format!("{tok_s_on:.0}"),
            format!("{ratio:.3}"),
            format!("{:.3}", tele.ttft.percentile_ms(0.50)),
            format!("{:.3}", tele.inter_token.percentile_ms(0.50)),
            format!("{:.3}", tele.inter_token.percentile_ms(0.99)),
            format!("{:.1}", engine.kv_bytes() as f64 / 1e6),
        ]);
        dt.print_last();
    }
    println!("{}", engine_memory_line(&ps));

    // ------------------------------- chunked prefill: time-to-first-token
    // 256-token prompt through the RoPE model (the ring slides, so the
    // prompt may exceed the KV capacity); TTFT ≈ the full generate() time
    // at max_new = 1. Acceptance target: >=3x lower TTFT at chunk 16 vs
    // the token-at-a-time chunk 1.
    let mut tt = Table::new(
        "prefill TTFT (ll-s1, 256-token prompt, w4g128, greedy, max_new=1)",
        &["prefill_chunk", "ttft_ms", "speedup_vs_chunk1"],
    );
    let ps_ll = zoo::seeded_store("ll-s1", 42).expect("zoo model");
    let pm_ll = affinequant::engine::PackedModel::from_store(&ps_ll, QuantSpec::new(4, 128));
    let long_prompt: Vec<i32> = (0..256).map(|i| ((i * 13 + 7) % 256) as i32).collect();
    let mut ttft_chunk1 = 0.0f64;
    let mut ttft_chunk16 = 0.0f64;
    for chunk in [1usize, 4, 16, 64, 0] {
        let sched = SchedConfig { prefill_chunk: chunk, ..SchedConfig::default() };
        let mut engine = Engine::with_config(pm_ll.clone(), 1, sched);
        let label = if chunk == 0 { "full".to_string() } else { chunk.to_string() };
        let r = bench(&format!("ttft chunk {label}"), 1, 5, || {
            let reqs =
                vec![Request { id: 0, prompt: long_prompt.clone(), max_new: 1, eos: None }];
            let (c, _) = engine.generate(reqs, Sampler::Greedy, 0).expect("bench request");
            std::hint::black_box(c);
        });
        if chunk == 1 {
            ttft_chunk1 = r.median_s;
        }
        if chunk == 16 {
            ttft_chunk16 = r.median_s;
        }
        let speedup = if chunk == 1 { 1.0 } else { ttft_chunk1 / r.median_s };
        json_ttft.push(jsonx::obj(vec![
            ("prefill_chunk", jsonx::num(chunk as f64)),
            ("ttft_ms", jsonx::num(r.median_s * 1e3)),
            ("speedup_vs_chunk1", jsonx::num(speedup)),
        ]));
        tt.row(vec![
            label,
            format!("{:.3}", r.median_s * 1e3),
            format!("{speedup:.2}x"),
        ]);
        tt.print_last();
    }
    println!(
        "\nchunk-16 vs chunk-1 TTFT speedup: {:.2}x (target: >=3x)",
        ttft_chunk1 / ttft_chunk16.max(1e-12)
    );

    // ------------------------------ paged KV: prefix-sharing memory sweep
    // N clients share a P-token system prompt over 2-token pages: a donor
    // request registers the prefix, then every follower attaches the shared
    // pages instead of re-prefilling them. Acceptance (N=32, P=128): peak
    // resident KV while all followers decode stays under 2x a single
    // request's prompt footprint (vs ~Nx with sharing off), with greedy
    // output bit-identical either way.
    let mut sh = Table::new(
        "kv prefix sharing (ll-s1, N clients x P-token shared prefix, w4g128)",
        &["clients", "prefix", "share", "peak_kv_kb", "one_prompt_kb", "ratio", "hits", "cow", "tok_s"],
    );
    let mut json_share: Vec<Value> = Vec::new();
    for (clients, plen) in [(8usize, 32usize), (8, 128), (32, 32), (32, 128)] {
        let prefix: Vec<i32> = (0..plen).map(|i| ((i * 29 + 3) % 256) as i32).collect();
        let req = |id: u64| {
            let mut p = prefix.clone();
            p.push(200 + id as i32); // unique tail token per client
            Request { id, prompt: p, max_new: if id == 0 { 2 } else { 1 }, eos: None }
        };
        let mut per_share: Vec<Vec<(u64, Vec<i32>)>> = Vec::new();
        for share in [true, false] {
            let kv = KvConfig { page_tokens: 2, share, ..KvConfig::default() };
            let mut cache =
                KvCache::with_options(clients, pm_ll.cfg.n_layers, 256, pm_ll.cfg.d_model, kv);
            let mut sched = Scheduler::with_config(
                clients,
                SchedConfig { prefill_chunk: 16, ..SchedConfig::default() },
            );
            let mut rng = Pcg32::seeded(0);
            // donor: registers the prefix, then finishes and frees its slot
            sched.submit(req(0)).map_err(|e| anyhow::anyhow!("donor: {e}"))?;
            while sched.tick(&pm_ll, &mut cache, Sampler::Greedy, &mut rng) {}
            let single_prompt_bytes = (plen + 1).div_ceil(2) * cache.page_bytes();

            for id in 1..=clients as u64 {
                sched.submit(req(id)).map_err(|e| anyhow::anyhow!("follower: {e}"))?;
            }
            let processed_before = sched.stats.tokens_processed;
            let mut peak_bytes = 0usize;
            let timer = affinequant::util::Timer::start();
            loop {
                let more = sched.tick(&pm_ll, &mut cache, Sampler::Greedy, &mut rng);
                peak_bytes = peak_bytes.max(cache.stats().resident_bytes);
                if !more {
                    break;
                }
            }
            let secs = timer.secs().max(1e-12);
            let tok_s = (sched.stats.tokens_processed - processed_before) as f64 / secs;
            let ratio = peak_bytes as f64 / single_prompt_bytes as f64;
            let st = cache.stats();
            let mut done: Vec<(u64, Vec<i32>)> =
                sched.take_finished().into_iter().map(|c| (c.id, c.tokens)).collect();
            done.sort_by_key(|(id, _)| *id);
            assert_eq!(done.len(), clients + 1, "all requests must complete");
            if share {
                assert!(
                    st.prefix_hits >= clients as u64,
                    "every follower must attach the shared prefix"
                );
                if clients == 32 && plen == 128 {
                    assert!(
                        ratio < 2.0,
                        "32 shared-prefix clients must stay under 2x one prompt \
                         footprint (got {ratio:.2}x)"
                    );
                }
            }
            per_share.push(done);
            json_share.push(jsonx::obj(vec![
                ("clients", jsonx::num(clients as f64)),
                ("shared_prefix_tokens", jsonx::num(plen as f64)),
                ("share", jsonx::num(if share { 1.0 } else { 0.0 })),
                ("peak_resident_bytes", jsonx::num(peak_bytes as f64)),
                ("single_prompt_bytes", jsonx::num(single_prompt_bytes as f64)),
                ("resident_over_single_prompt", jsonx::num(ratio)),
                ("kv_pages_peak", jsonx::num(sched.stats.kv_pages_peak as f64)),
                ("kv_shared_bytes_peak", jsonx::num(sched.stats.kv_shared_bytes_peak as f64)),
                ("prefix_hits", jsonx::num(st.prefix_hits as f64)),
                ("cow_faults", jsonx::num(st.cow_faults as f64)),
                ("tok_s", jsonx::num(tok_s)),
            ]));
            sh.row(vec![
                clients.to_string(),
                plen.to_string(),
                share.to_string(),
                format!("{:.1}", peak_bytes as f64 / 1e3),
                format!("{:.1}", single_prompt_bytes as f64 / 1e3),
                format!("{ratio:.2}x"),
                st.prefix_hits.to_string(),
                st.cow_faults.to_string(),
                format!("{tok_s:.0}"),
            ]);
            sh.print_last();
        }
        assert_eq!(per_share[0], per_share[1], "prefix sharing must not change greedy output");
    }

    // -------------------------- numeric-health sampling: overhead + parity
    // Three identical greedy workloads: recorder off, recorder on (numeric
    // sampling live at 1-in-16 decode rows), and recorder on + the w2
    // divergence sampler. Acceptance: numeric sampling costs <= 2% tok/s
    // and never changes a greedy token; both land in BENCH_10.json.
    let mut nt = Table::new(
        "numeric-health sampling overhead (opt-s2, w4g128, batch 8, greedy)",
        &["mode", "tok_s", "vs_off", "sampled_rows", "probes", "w2_agree_pct"],
    );
    let json_numeric = {
        let reqs = |n: usize| -> Vec<Request> {
            (0..n)
                .map(|i| Request {
                    id: i as u64,
                    prompt: vec![(i * 17 % 256) as i32, 5, 9],
                    max_new: 64,
                    eos: None,
                })
                .collect()
        };
        affinequant::telemetry::kernel::enable(false);
        let mut e_off = Engine::from_store(&ps, QuantSpec::new(4, 128), 8);
        let timer = affinequant::util::Timer::start();
        let (base, stats_off) = e_off.generate(reqs(8), Sampler::Greedy, 0)?;
        let tok_s_off = stats_off.tokens_processed as f64 / timer.secs();

        let mut e_num = Engine::from_store(&ps, QuantSpec::new(4, 128), 8);
        e_num.recorder = affinequant::telemetry::Recorder::new_enabled();
        let timer = affinequant::util::Timer::start();
        let (got_num, stats_num) = e_num.generate(reqs(8), Sampler::Greedy, 0)?;
        let tok_s_num = stats_num.tokens_processed as f64 / timer.secs();

        let mut e_div = Engine::from_store(&ps, QuantSpec::new(4, 128), 8);
        e_div.recorder = affinequant::telemetry::Recorder::new_enabled();
        e_div.enable_draft(QuantSpec::new(2, 64));
        let timer = affinequant::util::Timer::start();
        let (got_div, stats_div) = e_div.generate(reqs(8), Sampler::Greedy, 0)?;
        let tok_s_div = stats_div.tokens_processed as f64 / timer.secs();

        for (mode, got) in [("numeric sampling", &got_num), ("divergence probes", &got_div)] {
            for (a, b) in base.iter().zip(got) {
                assert_eq!(a.tokens, b.tokens, "{mode} changed greedy output");
            }
        }
        let snap = |e: &Engine| e.recorder.telemetry().expect("enabled").numeric.snapshot();
        let s_num = snap(&e_num);
        let s_div = snap(&e_div);
        let rows_num: u64 = s_num.layers.iter().map(|l| l.rows).sum();
        let rows_div: u64 = s_div.layers.iter().map(|l| l.rows).sum();
        assert!(rows_num > 0, "numeric sampling must observe rows when the recorder is on");
        assert!(s_div.div.probes > 0, "the divergence sampler must fire on a 64-token decode");
        let overhead = tok_s_num / tok_s_off.max(1e-12);
        println!(
            "\nnumeric sampling on/off tok/s ratio: {overhead:.3} (target: >=0.98); \
             w2 top-1 agree {:.1}% over {} probes",
            s_div.div.agree_pct(),
            s_div.div.probes,
        );
        for (mode, tok_s, rows, probes, agree) in [
            ("off", tok_s_off, 0u64, 0u64, f64::NAN),
            ("numeric", tok_s_num, rows_num, 0, f64::NAN),
            ("numeric+w2", tok_s_div, rows_div, s_div.div.probes, s_div.div.agree_pct()),
        ] {
            nt.row(vec![
                mode.to_string(),
                format!("{tok_s:.0}"),
                format!("{:.3}", tok_s / tok_s_off.max(1e-12)),
                rows.to_string(),
                probes.to_string(),
                if agree.is_nan() { "-".to_string() } else { format!("{agree:.1}") },
            ]);
            nt.print_last();
        }
        jsonx::obj(vec![
            ("tok_s_off", jsonx::num(tok_s_off)),
            ("tok_s_numeric_on", jsonx::num(tok_s_num)),
            ("tok_s_numeric_divergence_on", jsonx::num(tok_s_div)),
            ("numeric_on_off_ratio", jsonx::num(overhead)),
            ("sampled_rows", jsonx::num(rows_num as f64)),
            ("divergence_probes", jsonx::num(s_div.div.probes as f64)),
            ("w2_top1_agree_pct", jsonx::num(s_div.div.agree_pct())),
            ("w2_max_logit_delta", jsonx::num(s_div.div.max_logit_delta as f64)),
        ])
    };

    t.print();
    kt.print();
    dt.print();
    tt.print();
    sh.print();
    nt.print();
    save_table(&t, "perf_engine_gemm")?;
    save_table(&kt, "perf_engine_kernels")?;
    save_table(&dt, "perf_engine_decode")?;
    save_table(&tt, "perf_engine_ttft")?;
    save_table(&sh, "perf_engine_sharing")?;
    save_table(&nt, "perf_engine_numeric")?;
    save_json(
        BENCH_JSON,
        &jsonx::obj(vec![
            ("pr", jsonx::num(10.0)),
            ("bench", jsonx::s("perf_engine")),
            ("threads", jsonx::num(std::thread::available_parallelism()?.get() as f64)),
            (
                "kernel",
                jsonx::obj(vec![
                    ("selected", jsonx::s(kernel_sel.selected.name())),
                    ("source", jsonx::s(kernel_sel.source)),
                    ("w4g128_b16_best_tok_s", jsonx::num(w4_best_tok_s)),
                    ("w4g128_b16_generic_tok_s", jsonx::num(w4_generic_tok_s)),
                    (
                        "w4g128_b16_speedup_vs_generic",
                        jsonx::num(w4_best_tok_s / w4_generic_tok_s.max(1e-12)),
                    ),
                ]),
            ),
            ("kernel_gemm_sweep_1024x1024_b16", Value::Arr(json_kernel)),
            ("gemm_1024x1024", Value::Arr(json_gemm)),
            ("decode_opt_s2_w4g128", Value::Arr(json_decode)),
            ("ttft_ll_s1_256tok_w4g128", Value::Arr(json_ttft)),
            ("kv_prefix_sharing_ll_s1", Value::Arr(json_share)),
            ("numeric_sampling_opt_s2_w4g128_b8", json_numeric),
            ("w4g128_b16_speedup_vs_fakequant", jsonx::num(w4b16_speedup)),
        ]),
    )?;

    // PJRT comparison when the artifacts exist (skipped silently otherwise)
    #[cfg(feature = "pjrt")]
    {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let mut ctx = affinequant::harness::Ctx::load()?;
            affinequant::harness::engine_table(
                &mut ctx,
                "opt-s1",
                &["w4a16g128".into(), "w3a16g128".into(), "w2a16g64".into()],
                "perf_engine_pjrt",
            )?;
        } else {
            println!("(artifacts/ missing — skipping the PJRT comparison table)");
        }
    }
    Ok(())
}

fn engine_memory_line(ps: &affinequant::model::ParamStore) -> String {
    let engine = Engine::from_store(ps, QuantSpec::new(4, 128), 16);
    engine.memory_report()
}
