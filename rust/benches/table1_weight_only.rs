//! Bench: paper Tables 1/8/9 — OPT-family weight-only PPL on the three
//! corpora (wt2s/ptbs/c4s ≈ WikiText2/PTB/C4), method set M1.
//! Scale with `AQ_MODELS` / `AQ_CONFIGS` / `AQ_METHODS` env lists.

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, weight_only_tables, Ctx};

fn main() -> anyhow::Result<()> {
    let models = env_list("AQ_MODELS", &["opt-s1"]);
    let configs = env_list("AQ_CONFIGS", &["w3a16", "w4a16g128"]);
    let methods = env_list("AQ_METHODS", &["rtn", "gptq", "awq", "omniquant", "affinequant"]);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table1/8/9 weight-only sweep", || {
        weight_only_tables(&mut ctx, &models, &configs, &methods, "table1_weight_only")
    });
    t?.print();
    Ok(())
}
