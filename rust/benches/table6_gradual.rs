//! Bench: paper Table 6 — gradual-mask contribution (with vs without the
//! gradual release of off-diagonal elements).

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, gradual_ablation, Ctx};

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let config = env_list("AQ_CONFIGS", &["w3a16"]).remove(0);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table6 gradual mask ablation", || {
        gradual_ablation(&mut ctx, &model, &config, "table6_gradual")
    });
    t?.print();
    Ok(())
}
