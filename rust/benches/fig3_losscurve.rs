//! Bench: paper Figure 3 — last-transformer-block MSE loss curves,
//! AffineQuant vs OmniQuant, under two weight-only configs.

use affinequant::cli::parse_config;
use affinequant::coordinator::{calibrate, CalibOptions};
use affinequant::harness::{env_list, Ctx};
use affinequant::report::save_series;

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let configs = env_list("AQ_CONFIGS", &["w2a16", "w3a16g128"]);
    let mut ctx = Ctx::load()?;
    let (rt, fp) = ctx.model(&model)?;
    for config in &configs {
        let (spec, act_bits) = parse_config(config)?;
        for (method, opts) in [
            ("affinequant", CalibOptions::affinequant(spec, act_bits)),
            ("omniquant", CalibOptions::omniquant(spec, act_bits)),
        ] {
            let (_, rep) = calibrate(&rt, &fp, &opts, false)?;
            let curve = &rep.blocks.last().unwrap().loss_curve;
            let rows: Vec<(f64, f64)> =
                curve.iter().enumerate().map(|(e, &l)| ((e + 1) as f64, l)).collect();
            save_series(&format!("fig3_loss_{model}_{config}_{method}"), "epoch,loss", &rows)?;
            println!(
                "fig3 {model} {config} {method}: {:.3e} -> {:.3e}",
                curve.first().unwrap(),
                curve.last().unwrap()
            );
        }
    }
    Ok(())
}
