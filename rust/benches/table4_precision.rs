//! Bench: paper Table 4 — numerical-precision ablation. The merge-error
//! protocol (random SDD affine + random activations, mean output MSE over
//! repeated runs) plus a timed calibration per precision scheme lives in
//! `examples/ablations.rs --what precision`; this bench times the
//! inverse+merge kernels themselves across schemes.

use affinequant::benchx::{bench, Table};
use affinequant::model::merge::{inverse_prec, mm_prec, MergePrecision};
use affinequant::report::save_table;
use affinequant::rngx::Pcg32;
use affinequant::tensor::Tensor;

fn sdd(d: usize, seed: u64) -> Tensor {
    let mut rng = Pcg32::seeded(seed);
    let mut a = Tensor::randn(&[d, d], 1.0 / d as f32, &mut rng);
    for i in 0..d {
        let off: f32 = (0..d).filter(|&j| j != i).map(|j| a.data[i * d + j].abs()).sum();
        a.data[i * d + i] = 1.2 * (off + 0.05);
    }
    a
}

fn main() -> anyhow::Result<()> {
    let d = std::env::var("AQ_DIM").map(|v| v.parse().unwrap()).unwrap_or(256);
    let a = sdd(d, 1);
    let mut rng = Pcg32::seeded(2);
    let w = Tensor::randn(&[d, d], 0.05, &mut rng);
    let mut t = Table::new(
        &format!("Merge kernel timings at d={d} (Table 4 companion)"),
        &["scheme", "inverse_ms", "merge_mm_ms", "residual"],
    );
    for (scheme, prec) in [
        ("float", MergePrecision::F32),
        ("double", MergePrecision::F64),
        ("float-double", MergePrecision::F32InvF64),
    ] {
        let rinv = bench(&format!("inverse[{scheme}] d={d}"), 1, 5, || {
            let _ = inverse_prec(&a, prec);
        });
        let rmm = bench(&format!("merge_mm[{scheme}] d={d}"), 1, 5, || {
            let _ = mm_prec(&a, &w, prec);
        });
        let inv = inverse_prec(&a, prec);
        let a64: Vec<f64> = a.data.iter().map(|&v| v as f64).collect();
        let i64v: Vec<f64> = inv.data.iter().map(|&v| v as f64).collect();
        let res = affinequant::linalg::inverse_residual(&a64, &i64v, d);
        t.row(vec![
            scheme.into(),
            format!("{:.2}", rinv.median_s * 1e3),
            format!("{:.2}", rmm.median_s * 1e3),
            format!("{res:.3e}"),
        ]);
    }
    t.print();
    save_table(&t, "table4_precision_kernels")?;
    Ok(())
}
