//! Bench: paper Table 3 — w4a4 PPL across {FP16, SmoothQuant, OmniQuant,
//! AffineQuant} on the WikiText2/C4 analogues.

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, w4a4_ppl_table, Ctx};

fn main() -> anyhow::Result<()> {
    let models = env_list("AQ_MODELS", &["opt-s1", "ll-s1"]);
    let methods = env_list("AQ_METHODS", &["fp16", "smoothquant", "omniquant", "affinequant"]);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table3 w4a4 ppl", || {
        w4a4_ppl_table(&mut ctx, &models, &methods, "table3_w4a4")
    });
    t?.print();
    Ok(())
}
