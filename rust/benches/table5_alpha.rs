//! Bench: paper Table 5 — stability-factor sweep. The full 1e0..1e-8 grid
//! lives in `examples/ablations.rs`; the bench default covers the shape
//! (large-alpha instability, small-alpha OmniQuant convergence).

use affinequant::benchx::time_once;
use affinequant::harness::{alpha_sweep, env_list, Ctx};

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let alphas: Vec<f32> = match std::env::var("AQ_ALPHAS") {
        Ok(v) => v.split(',').map(|s| s.parse().unwrap()).collect(),
        Err(_) => vec![1.0, 0.1, 1e-2, 1e-4, 1e-8],
    };
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table5 alpha sweep", || {
        alpha_sweep(&mut ctx, &model, "w2a16g128", &alphas, "table5_alpha")
    });
    t?.print();
    Ok(())
}
