//! Bench: paper Figures 5/6 — last-block quantization loss vs model PPL
//! scatter (sampled over stability factors) and the Pearson correlation.

use affinequant::coordinator::{calibrate, CalibOptions};
use affinequant::data::CorpusKind;
use affinequant::eval::{self, pearson};
use affinequant::harness::{env_list, Ctx, EVAL_BATCHES};
use affinequant::quant::QuantSpec;
use affinequant::report::save_series;

fn main() -> anyhow::Result<()> {
    let model = env_list("AQ_MODELS", &["opt-s1"]).remove(0);
    let alphas: Vec<f32> = match std::env::var("AQ_ALPHAS") {
        Ok(v) => v.split(',').map(|s| s.parse().unwrap()).collect(),
        Err(_) => vec![1.0, 0.1, 0.01, 1e-3],
    };
    let mut ctx = Ctx::load()?;
    let (rt, fp) = ctx.model(&model)?;
    let mut pts = Vec::new();
    for &alpha in &alphas {
        let mut opts = CalibOptions::affinequant(QuantSpec::new(4, 0), 4);
        opts.alpha = alpha;
        let (qps, rep) = calibrate(&rt, &fp, &opts, false)?;
        if rep.any_diverged() {
            continue;
        }
        let ppl = eval::perplexity(&rt, &qps, CorpusKind::Wt2s, EVAL_BATCHES, eval::act_qmax(4))?;
        println!("alpha {alpha:.0e}: loss {:.3e} ppl {ppl:.3}", rep.last_block_loss());
        pts.push((rep.last_block_loss(), ppl));
    }
    save_series(&format!("fig56_scatter_{model}"), "last_block_loss,ppl_wt2s", &pts)?;
    let r = pearson(
        &pts.iter().map(|p| p.0).collect::<Vec<_>>(),
        &pts.iter().map(|p| p.1).collect::<Vec<_>>(),
    );
    println!("Pearson r = {r:.3} (paper ≈ 0.95)");
    Ok(())
}
