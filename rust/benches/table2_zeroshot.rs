//! Bench: paper Table 2 — zero-shot accuracy on the six synthetic tasks at
//! w4a4, OmniQuant vs AffineQuant vs FP16.

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, zeroshot_table, Ctx};

fn main() -> anyhow::Result<()> {
    let models = env_list("AQ_MODELS", &["opt-s1"]);
    let methods = env_list("AQ_METHODS", &["fp16", "omniquant", "affinequant"]);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table2 zero-shot w4a4", || {
        zeroshot_table(&mut ctx, &models, &methods, "w4a4", "table2_zeroshot")
    });
    t?.print();
    Ok(())
}
