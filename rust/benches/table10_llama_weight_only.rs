//! Bench: paper Tables 10/11 — LLaMA-family weight-only PPL (C4 +
//! WikiText2 analogues come out as corpus columns of one sweep), including
//! the w2a16 configs where the paper's gaps are largest.

use affinequant::benchx::time_once;
use affinequant::harness::{env_list, weight_only_tables, Ctx};

fn main() -> anyhow::Result<()> {
    let models = env_list("AQ_MODELS", &["ll-s1"]);
    let configs = env_list("AQ_CONFIGS", &["w2a16", "w3a16"]);
    let methods = env_list("AQ_METHODS", &["rtn", "gptq", "awq", "omniquant", "affinequant"]);
    let mut ctx = Ctx::load()?;
    let (t, _) = time_once("table10/11 llama weight-only sweep", || {
        weight_only_tables(&mut ctx, &models, &configs, &methods, "table10_llama_weight_only")
    });
    t?.print();
    Ok(())
}
