#!/usr/bin/env python3
"""Merge the root BENCH_*.json snapshots into results/bench_trend.md.

Each PR's perf bench (`cargo bench --bench perf_engine` /
`cargo run --release --bin perf_engine`) writes one `BENCH_<pr>.json` at
the repo root. This script folds every snapshot found there into a single
markdown trend report so throughput regressions are visible across the
stacked PR sequence without opening each JSON by hand.

Stdlib only — no third-party imports. Safe to run with zero snapshots
(emits a stub report saying so).

Usage: python3 scripts/bench_trend.py
"""

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "results" / "bench_trend.md"


def load_snapshots():
    """[(order, filename, parsed)] sorted by the number in the filename."""
    snaps = []
    for path in sorted(ROOT.glob("BENCH_*.json")):
        m = re.search(r"BENCH_(\d+)", path.name)
        order = int(m.group(1)) if m else -1
        try:
            snaps.append((order, path.name, json.loads(path.read_text())))
        except (json.JSONDecodeError, OSError) as e:
            print(f"warning: skipping {path.name}: {e}", file=sys.stderr)
    snaps.sort(key=lambda s: (s[0], s[1]))
    return snaps


def flatten(value, prefix=""):
    """Dotted-path scalars from nested dicts/lists; non-numbers dropped."""
    out = {}
    if isinstance(value, dict):
        for k, v in value.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(value, list):
        for i, v in enumerate(value):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(value, bool):
        pass  # bools are ints in python; keep them out of numeric trends
    elif isinstance(value, (int, float)):
        out[prefix.rstrip(".")] = value
    return out


def fmt(v):
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    return str(int(v)) if isinstance(v, float) else str(v)


def main():
    snaps = load_snapshots()
    OUT.parent.mkdir(parents=True, exist_ok=True)

    lines = ["# Bench trend", ""]
    if not snaps:
        lines += [
            "No `BENCH_*.json` snapshots found at the repo root yet.",
            "Run the perf bench to produce one, then re-run this script.",
            "",
        ]
        OUT.write_text("\n".join(lines))
        print(f"wrote {OUT.relative_to(ROOT)} (no snapshots)")
        return

    names = [name for _, name, _ in snaps]
    lines += [
        f"{len(snaps)} snapshot(s) merged, oldest to newest: "
        + ", ".join(f"`{n}`" for n in names),
        "",
    ]

    flat = [flatten(data) for _, _, data in snaps]

    # headline row: per-snapshot metadata that is present in every file.
    # `kernel` (the GEMM dispatch variant the bench host selected, PR 10+)
    # and its best-variant w4 throughput come from the raw snapshot — the
    # flattener drops strings.
    lines += [
        "| snapshot | pr | threads | kernel | w4_best_tok_s |",
        "|---|---|---|---|---|",
    ]
    for (name, f), (_, _, raw) in zip(zip(names, flat), snaps):
        pr = fmt(f["pr"]) if "pr" in f else "-"
        threads = fmt(f["threads"]) if "threads" in f else "-"
        kinfo = raw.get("kernel") if isinstance(raw, dict) else None
        kinfo = kinfo if isinstance(kinfo, dict) else {}
        kernel = kinfo.get("selected") or "-"
        best = kinfo.get("w4g128_b16_best_tok_s")
        best = fmt(float(best)) if isinstance(best, (int, float)) else "-"
        lines.append(f"| `{name}` | {pr} | {threads} | {kernel} | {best} |")
    lines.append("")

    # one table per top-level section, metrics as rows, snapshots as
    # columns — a metric missing from an older snapshot renders as "-"
    sections = []
    for f in flat:
        for key in f:
            section = key.split(".", 1)[0]
            if section not in ("pr", "threads") and section not in sections:
                sections.append(section)

    for section in sections:
        keys = []
        for f in flat:
            for key in f:
                if key.split(".", 1)[0] == section and key not in keys:
                    keys.append(key)
        lines += [f"## {section}", ""]
        header = "| metric | " + " | ".join(f"`{n}`" for n in names) + " |"
        lines += [header, "|---" * (len(names) + 1) + "|"]
        for key in keys:
            short = key.split(".", 1)[1] if "." in key else key
            cells = [fmt(f[key]) if key in f else "-" for f in flat]
            lines.append(f"| {short} | " + " | ".join(cells) + " |")
        lines.append("")

    OUT.write_text("\n".join(lines))
    print(f"wrote {OUT.relative_to(ROOT)} ({len(snaps)} snapshot(s))")


if __name__ == "__main__":
    main()
