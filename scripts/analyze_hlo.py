#!/usr/bin/env python
"""L2 perf analysis: op census over the lowered HLO artifacts.

Checks the §Perf L2 targets: zero custom-calls (the rust runtime cannot
execute them), no transpose/reshape explosions, dot count consistent with
the model structure (redundant-recomputation smell test). Run after
`make artifacts`:

    python scripts/analyze_hlo.py [artifacts]
"""

import os
import re
import sys
from collections import Counter


def census(path):
    ops = Counter()
    with open(path) as f:
        for line in f:
            line = line.strip()
            m = re.match(r"(?:ROOT )?%?[\w.\-]+ = \S+ ([a-z\-]+)\(", line)
            if m:
                ops[m.group(1)] += 1
    return ops


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "artifacts"
    total = Counter()
    print(f"{'entry':<28} {'ops':>6} {'dot':>5} {'transp':>6} {'reshape':>7} "
          f"{'custom':>6} {'KB':>8}")
    for model in sorted(os.listdir(root)):
        mdir = os.path.join(root, model)
        if not os.path.isdir(mdir):
            continue
        for fn in sorted(os.listdir(mdir)):
            if not fn.endswith(".hlo.txt"):
                continue
            path = os.path.join(mdir, fn)
            ops = census(path)
            total += ops
            n = sum(ops.values())
            kb = os.path.getsize(path) / 1e3
            print(f"{model}/{fn.removesuffix('.hlo.txt'):<{28-len(model)-1}} "
                  f"{n:>6} {ops['dot']:>5} {ops['transpose']:>6} "
                  f"{ops['reshape']:>7} {ops['custom-call']:>6} {kb:>8.1f}")
    print("\ntop ops overall:")
    for op, n in total.most_common(12):
        print(f"  {op:<18} {n}")
    assert total["custom-call"] == 0, "custom-calls present — rust cannot run these!"
    print("\nOK: zero custom-calls across all artifacts")


if __name__ == "__main__":
    main()
