#!/usr/bin/env bash
# CI gate: formatting, clippy lints, + the offline-safe (no-XLA) build and
# test paths.
#
# The default feature set (`pjrt`) needs the vendored xla crate closure and
# the AOT artifacts; this script enforces that the pure-host subset — the
# substrate modules plus the packed-weight engine — always builds and
# passes its tests with `--no-default-features`, so the deployment path
# never regresses even where XLA is unavailable.
#
# Usage: scripts/ci.sh [--with-pjrt]
#   --with-pjrt  additionally run the default-feature build + tests
#                (requires the vendored xla closure; runtime tests skip
#                themselves when artifacts/ is missing).

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy --no-default-features -- -D warnings"
cargo clippy --no-default-features -- -D warnings

echo "== cargo build --release --no-default-features"
cargo build --release --no-default-features

echo "== cargo test -q --no-default-features"
cargo test -q --no-default-features

# the serving front-end must keep working without the PJRT stack: drive
# the HTTP server over a real socket in the pure-host build
echo "== server socket smoke (no-default-features)"
cargo test -q --no-default-features --test server

# observability gate: /metrics must serve parseable Prometheus text with
# live TTFT/inter-token histograms after a streamed completion
echo "== /metrics smoke (no-default-features)"
cargo test -q --no-default-features --test server metrics_

# paged-KV sharing gate: two clients streaming the same prompt must share
# KV pages (/v1/stats reports kv_pages_shared > 0) while their greedy
# token prefixes stay identical to offline generate
echo "== shared-prompt KV paging smoke (no-default-features)"
cargo test -q --no-default-features --test server shared_

# numeric-health gate: /v1/health/numeric must serve per-layer drift
# verdicts + cross-bit-width divergence over a real socket, and /metrics
# must expose the aq_numeric_* families as valid Prometheus text
echo "== numeric-health smoke (no-default-features)"
cargo test -q --no-default-features --test server numeric_

# kernel-dispatch gate, both halves:
#  1. the engine suite re-runs with the dispatch pinned to the scalar
#     baseline via the AQ_KERNEL env override — greedy outputs and every
#     GEMM property must hold on the non-specialized path too;
#  2. /v1/stats + /metrics must report the active kernel over a real socket
echo "== engine tests with AQ_KERNEL=scalar (no-default-features)"
AQ_KERNEL=scalar cargo test -q --no-default-features --test engine

echo "== kernel dispatch stats smoke (no-default-features)"
cargo test -q --no-default-features --test server kernel_

if [[ "${1:-}" == "--with-pjrt" ]]; then
    echo "== cargo build --release (default features)"
    cargo build --release
    echo "== cargo test -q (default features)"
    cargo test -q
fi

echo "ci.sh: OK"
